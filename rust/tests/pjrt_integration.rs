//! End-to-end integration over the REAL runtime: artifacts → PJRT → engine.
//!
//! These tests need the `pjrt` cargo feature (default-on; requires the
//! vendored `xla` crate — build with `--no-default-features` on machines
//! without it) plus `artifacts/` (run `make artifacts` first); they skip
//! gracefully when the artifacts are missing.

#![cfg(feature = "pjrt")]

use std::path::Path;

use das::config::preset;
use das::model::TargetModel;
use das::rollout::{GenJob, RolloutEngine};
use das::runtime::PjrtModel;
use das::tokens::Rollout;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("meta.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn decode_executes_and_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let mut m = PjrtModel::load(dir).unwrap();
    let b = m.batch_capacity();
    let s = m.meta.max_seq_len;
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % 17) as i32 % 63).collect();
    let q_start: Vec<i32> = (0..b as i32).collect();
    let a = m.decode_raw(&tokens, &q_start).unwrap();
    let bb = m.decode_raw(&tokens, &q_start).unwrap();
    assert_eq!(a.len(), b * m.meta.spec_block * m.meta.vocab_size);
    assert_eq!(a, bb, "decode must be deterministic");
    assert!(a.iter().all(|x| x.is_finite()));
    assert_eq!(m.forward_passes(), 2);
}

#[test]
fn padding_after_block_does_not_change_logits() {
    // The runtime right-pads contexts; causality must make that safe.
    let Some(dir) = artifacts() else { return };
    let mut m = PjrtModel::load(dir).unwrap();
    let b = m.batch_capacity();
    let s = m.meta.max_seq_len;
    let kp1 = m.meta.spec_block;
    let mut tokens: Vec<i32> = vec![1; b * s];
    let q_start: Vec<i32> = vec![4; b];
    let a = m.decode_raw(&tokens, &q_start).unwrap();
    // Scramble everything after position 4 + spec_block in every row.
    for r in 0..b {
        for j in (4 + kp1)..s {
            tokens[r * s + j] = ((j * 7 + r) % 60) as i32;
        }
    }
    let c = m.decode_raw(&tokens, &q_start).unwrap();
    for (x, y) in a.iter().zip(&c) {
        assert!((x - y).abs() < 1e-4, "padding leaked into block logits");
    }
}

#[test]
fn train_step_runs_and_moves_weights() {
    let Some(dir) = artifacts() else { return };
    let mut m = PjrtModel::load(dir).unwrap();
    let b = m.batch_capacity();
    let s = m.meta.max_seq_len;
    let before = m.params_to_host().unwrap();
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % 50) as i32).collect();
    let mask: Vec<f32> = (0..b * s).map(|i| if i % s > 2 { 1.0 } else { 0.0 }).collect();
    let adv: Vec<f32> = (0..b).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let loss1 = m.train_step(&tokens, &mask, &adv, 0.05).unwrap();
    let after = m.params_to_host().unwrap();
    assert!(loss1.is_finite());
    let moved = before
        .iter()
        .zip(&after)
        .any(|(x, y)| x.iter().zip(y).any(|(a, b)| (a - b).abs() > 1e-9));
    assert!(moved, "weights must change");
    assert_eq!(m.train_steps, 1);
}

#[test]
fn train_overfit_increases_sequence_probability() {
    // REINFORCE sanity on the real stack: repeatedly rewarding one sequence
    // must increase its per-token logprob under decode.
    let Some(dir) = artifacts() else { return };
    let mut m = PjrtModel::load(dir).unwrap();
    let b = m.batch_capacity();
    let s = m.meta.max_seq_len;
    let v = m.meta.vocab_size;
    let seq: Vec<i32> = (0..12).map(|i| ((i * 5 + 3) % 60) as i32).collect();
    let mut tokens = vec![0i32; b * s];
    let mut mask = vec![0f32; b * s];
    for r in 0..b {
        for (j, &t) in seq.iter().enumerate() {
            tokens[r * s + j] = t;
            if j > 0 {
                mask[r * s + j] = 1.0;
            }
        }
    }
    let adv = vec![1.0f32; b];
    let prob_of_target = |m: &mut PjrtModel| -> f32 {
        // logits at q_start=0 predict token at position 1 == seq[1].
        let q = vec![0i32; b];
        let logits = m.decode_raw(&tokens.clone(), &q).unwrap();
        let row = &logits[..v];
        let mx = row.iter().cloned().fold(f32::MIN, f32::max);
        let exps: Vec<f32> = row.iter().map(|x| (x - mx).exp()).collect();
        let sum: f32 = exps.iter().sum();
        exps[seq[1] as usize] / sum
    };
    let p0 = prob_of_target(&mut m);
    for _ in 0..10 {
        m.train_step(&tokens, &mask, &adv, 0.3).unwrap();
    }
    let p1 = prob_of_target(&mut m);
    assert!(
        p1 > p0 * 1.2,
        "rewarded sequence should become more likely: {p0} -> {p1}"
    );
}

#[test]
fn engine_generates_on_pjrt_and_greedy_is_lossless() {
    let Some(dir) = artifacts() else { return };
    let cfg = {
        let mut c = preset("tiny_pjrt").unwrap();
        c.rollout.temperature = 0.0;
        c.rollout.max_new_tokens = 24;
        c
    };
    let jobs: Vec<GenJob> = (0..4)
        .map(|p| GenJob {
            problem: p,
            prompt: vec![p + 1, 2 * p + 3, 5],
            samples: 2,
        })
        .collect();
    let run = |drafter: &str| -> Vec<Rollout> {
        let mut c = cfg.clone();
        c.spec.drafter = drafter.into();
        let mut model = PjrtModel::load(dir).unwrap();
        let mut engine = RolloutEngine::new(&c, das::drafter::from_config(&c));
        let mut all = Vec::new();
        for step in 0..2 {
            let rep = engine.generate_step(&mut model, &jobs, step);
            all.extend(rep.rollouts);
        }
        all
    };
    let base = run("none");
    let das_out = run("das");
    let key = |r: &Rollout| (r.problem, r.step, r.tokens.clone());
    let mut a: Vec<_> = base.iter().map(key).collect();
    let mut b: Vec<_> = das_out.iter().map(key).collect();
    a.sort();
    b.sort();
    assert_eq!(a, b, "greedy DAS must equal greedy baseline on the real model");
    assert_eq!(a.len(), 16);
}

#[test]
fn calibration_fits_linear_model() {
    let Some(dir) = artifacts() else { return };
    let mut m = PjrtModel::load(dir).unwrap();
    let rep = m.calibrate(5).unwrap();
    assert!(rep.model.c_tok > 0.0, "per-token cost must be positive");
    assert!(rep.mre < 0.5, "fit should be reasonable, mre={}", rep.mre);
    assert!(rep.n_points >= 9);
}

#[test]
fn checkpoint_roundtrip_restores_weights() {
    let Some(dir) = artifacts() else { return };
    let mut m = PjrtModel::load(dir).unwrap();
    // Perturb weights with one train step, save, perturb again, restore.
    let b = m.batch_capacity();
    let s = m.meta.max_seq_len;
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % 50) as i32).collect();
    let mask: Vec<f32> = vec![1.0; b * s];
    let adv: Vec<f32> = vec![1.0; b];
    m.train_step(&tokens, &mask, &adv, 0.1).unwrap();
    let saved = m.params_to_host().unwrap();
    let ckpt_dir = std::env::temp_dir().join("das_ckpt_test");
    das::runtime::save_checkpoint(
        &m,
        &ckpt_dir,
        &das::runtime::CheckpointMeta { step: 5, epoch: 1, train_steps: 1 },
    )
    .unwrap();
    m.train_step(&tokens, &mask, &adv, 0.1).unwrap();
    assert_ne!(m.params_to_host().unwrap(), saved, "weights moved after save");
    let meta = das::runtime::load_checkpoint(&mut m, &ckpt_dir).unwrap();
    assert_eq!(meta.step, 5);
    assert_eq!(meta.train_steps, 1);
    let restored = m.params_to_host().unwrap();
    assert_eq!(restored, saved, "checkpoint restore must be exact");
}

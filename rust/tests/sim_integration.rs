//! Integration tests over the simulated stack: cross-module invariants the
//! unit tests can't see — engine × drafter × budget × trainer.

use das::config::DasConfig;
use das::drafter;
use das::model::sim::{SimModel, SimModelConfig};
use das::model::TargetModel;
use das::rl::Trainer;
use das::rollout::{GenJob, RolloutEngine};
use das::tokens::Rollout;

fn cfg(drafter: &str, policy: &str, temp: f64) -> DasConfig {
    let mut c = DasConfig::default();
    c.model.vocab_size = 128;
    c.workload.n_problems = 10;
    c.workload.len_mu = 3.6;
    c.workload.len_sigma = 0.5;
    c.rollout.max_new_tokens = 160;
    c.rollout.max_batch = 8;
    c.rollout.samples_per_problem = 4;
    c.train.problems_per_step = 5;
    c.rollout.temperature = temp;
    c.spec.drafter = drafter.into();
    c.spec.budget_policy = policy.into();
    c
}

fn jobs(n: u32, samples: usize) -> Vec<GenJob> {
    (0..n)
        .map(|p| GenJob {
            problem: p,
            prompt: vec![p + 1, 7, 9],
            samples,
        })
        .collect()
}

/// Greedy equivalence across EVERY budget policy — the losslessness anchor
/// at the integration level.
#[test]
fn greedy_equivalence_across_all_policies() {
    let reference: Vec<Rollout> = {
        let c = cfg("none", "length_aware", 0.0);
        let mut m = SimModel::new(SimModelConfig::from_das(&c));
        let mut e = RolloutEngine::new(&c, drafter::from_config(&c));
        (0..3)
            .flat_map(|s| {
                let rep = e.generate_step(&mut m, &jobs(10, 2), s);
                m.policy_update(1.0);
                e.roll_epoch(s + 1);
                rep.rollouts
            })
            .collect()
    };
    let key = |r: &Rollout| (r.step, r.problem, r.tokens.clone());
    let mut want: Vec<_> = reference.iter().map(key).collect();
    want.sort();
    for policy in ["length_aware", "optimal", "uniform", "unlimited"] {
        let c = cfg("das", policy, 0.0);
        let mut m = SimModel::new(SimModelConfig::from_das(&c));
        let mut e = RolloutEngine::new(&c, drafter::from_config(&c));
        let got: Vec<Rollout> = (0..3)
            .flat_map(|s| {
                let rep = e.generate_step(&mut m, &jobs(10, 2), s);
                m.policy_update(1.0);
                e.roll_epoch(s + 1);
                rep.rollouts
            })
            .collect();
        let mut got: Vec<_> = got.iter().map(key).collect();
        got.sort();
        assert_eq!(got, want, "policy {policy} broke greedy losslessness");
    }
}

/// Stochastic losslessness: with T > 0 the REWARD DISTRIBUTION must match
/// between baseline and DAS (not the exact streams). We compare mean
/// rewards across many steps — they share the same expectation.
#[test]
fn stochastic_reward_distribution_preserved() {
    let run = |drafter_kind: &str, seed: u64| -> f64 {
        let mut c = cfg(drafter_kind, "length_aware", 0.8);
        c.seed = seed;
        let mut model = SimModel::new(SimModelConfig::from_das(&c));
        let mut t = Trainer::new(c);
        let stats = t.run_sim(&mut model, 12);
        stats.iter().map(|s| s.reward).sum::<f64>() / stats.len() as f64
    };
    // Average across seeds to tighten the comparison.
    let seeds = [11u64, 22, 33, 44];
    let base: f64 = seeds.iter().map(|&s| run("none", s)).sum::<f64>() / 4.0;
    let das: f64 = seeds.iter().map(|&s| run("das", s)).sum::<f64>() / 4.0;
    assert!(
        (base - das).abs() < 0.08,
        "reward distributions diverged: baseline {base:.4} vs DAS {das:.4}"
    );
}

/// The speedup ordering the whole paper rests on:
/// baseline ≥ das_unlimited ≥ das (in steady-state generation time).
#[test]
fn budget_policy_ordering_holds() {
    let run = |drafter_kind: &str, policy: &str| -> f64 {
        let c = cfg(drafter_kind, policy, 0.6);
        let mut model = SimModel::new(SimModelConfig::from_das(&c));
        let mut t = Trainer::new(c);
        let stats = t.run_sim(&mut model, 10);
        stats[2..].iter().map(|s| s.metrics.gen_time).sum()
    };
    let baseline = run("none", "length_aware");
    let unlimited = run("das", "unlimited");
    let das = run("das", "length_aware");
    assert!(das < baseline, "das {das:.2} !< baseline {baseline:.2}");
    assert!(unlimited < baseline, "unlimited {unlimited:.2} !< baseline {baseline:.2}");
    assert!(
        das <= unlimited * 1.05,
        "length-aware {das:.2} should not lose to unlimited {unlimited:.2}"
    );
}

/// Failure injection: a drafter that proposes GARBAGE must never corrupt
/// outputs (losslessness) — it can only waste budget.
#[test]
fn adversarial_drafter_cannot_corrupt_outputs() {
    struct GarbageDrafter(u64);
    impl das::drafter::Drafter for GarbageDrafter {
        fn name(&self) -> &'static str {
            "garbage"
        }
        fn draft(
            &mut self,
            _r: u64,
            _p: u32,
            _c: &[u32],
            budget: usize,
        ) -> das::drafter::Draft {
            // Deterministic junk tokens.
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1);
            let tokens: Vec<u32> = (0..budget)
                .map(|i| ((self.0 >> (i % 48)) % 120) as u32)
                .collect();
            let confidence = vec![0.5; tokens.len()];
            das::drafter::Draft {
                tokens,
                confidence,
                match_len: 4,
            }
        }
    }
    let c = cfg("none", "uniform", 0.0);
    let mut m1 = SimModel::new(SimModelConfig::from_das(&c));
    let mut m2 = SimModel::new(SimModelConfig::from_das(&c));
    let mut clean = RolloutEngine::new(&c, Box::new(das::drafter::NoneDrafter));
    let mut dirty = RolloutEngine::new(&c, Box::new(GarbageDrafter(42)));
    let a = clean.generate_step(&mut m1, &jobs(10, 2), 0);
    let b = dirty.generate_step(&mut m2, &jobs(10, 2), 0);
    let key = |r: &Rollout| (r.problem, r.tokens.clone());
    let mut ka: Vec<_> = a.rollouts.iter().map(key).collect();
    let mut kb: Vec<_> = b.rollouts.iter().map(key).collect();
    ka.sort();
    kb.sort();
    assert_eq!(ka, kb, "garbage drafts corrupted greedy outputs");
    // And the garbage was indeed rejected.
    assert!(b.metrics.proposed > 0);
    assert!(b.metrics.accept_rate() < 0.1);
}

/// Empty-prompt and single-token jobs must not break the engine.
#[test]
fn degenerate_jobs_handled() {
    let c = cfg("das", "length_aware", 0.6);
    let mut m = SimModel::new(SimModelConfig::from_das(&c));
    let mut e = RolloutEngine::new(&c, drafter::from_config(&c));
    let jobs = vec![
        GenJob {
            problem: 0,
            prompt: vec![1],
            samples: 1,
        },
        GenJob {
            problem: 1,
            prompt: vec![2, 3],
            samples: 0, // zero samples: contributes nothing
        },
    ];
    let rep = e.generate_step(&mut m, &jobs, 0);
    assert_eq!(rep.rollouts.len(), 1);
    assert!(!rep.rollouts[0].tokens.is_empty());
}

/// Long-run trainer stability: many steps, windows evicting, no panics,
/// monotone epoch counter, bounded memory proxy (drafter token count).
#[test]
fn long_run_stability_with_window_eviction() {
    let mut c = cfg("das", "length_aware", 0.7);
    c.spec.window = 3;
    let mut model = SimModel::new(SimModelConfig::from_das(&c));
    let mut t = Trainer::new(c);
    let stats = t.run_sim(&mut model, 40);
    for w in stats.windows(2) {
        assert!(w[1].epoch >= w[0].epoch);
    }
    assert_eq!(stats.len(), 40);
    // Rewards end up meaningfully positive (training works through all the
    // machinery for 40 steps).
    let late: f64 = stats[32..].iter().map(|s| s.reward).sum::<f64>() / 8.0;
    assert!(late > 0.2, "late reward {late}");
}

/// Effective batch trace is well-formed: starts at the cap (while the queue
/// is full), never exceeds it, ends at 1 for the straggler.
#[test]
fn eff_batch_trace_well_formed() {
    let c = cfg("das", "length_aware", 0.6);
    let mut m = SimModel::new(SimModelConfig::from_das(&c));
    let mut e = RolloutEngine::new(&c, drafter::from_config(&c));
    let rep = e.generate_step(&mut m, &jobs(10, 4), 0);
    let t = &rep.metrics.eff_batch;
    assert_eq!(t[0], 8);
    assert!(t.iter().all(|&v| v >= 1 && v <= 8));
    assert_eq!(*t.last().unwrap(), 1);
}

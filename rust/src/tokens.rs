//! Core token / identifier types shared by every layer of the coordinator.

/// Token id in the policy's vocabulary. `u32` everywhere — the suffix
/// structures index token *sequences*, never text.
pub type TokenId = u32;

/// Stable identifier of a *problem* (a prompt in the RL dataset). The same
/// problem is revisited every epoch (paper Insight-2), which is what makes
/// per-problem suffix-tree shards work.
pub type ProblemId = u32;

/// Identifier of a single rollout request (one sample of one problem in one
/// step). Unique within a training run.
pub type RequestId = u64;

/// Training epoch index (one full pass over the dataset).
pub type Epoch = u32;

/// One completed rollout: the generated token sequence plus provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct Rollout {
    pub problem: ProblemId,
    pub epoch: Epoch,
    pub step: u32,
    pub tokens: Vec<TokenId>,
    pub reward: f64,
}

impl Rollout {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rollout_len() {
        let r = Rollout {
            problem: 1,
            epoch: 0,
            step: 0,
            tokens: vec![1, 2, 3],
            reward: 1.0,
        };
        assert_eq!(r.len(), 3);
        assert!(!r.is_empty());
    }
}

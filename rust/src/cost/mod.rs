//! Rollout latency model (§4.2.1, Eq. 1–2, Fig. 8).
//!
//! The paper models one target-model forward pass as
//! `t_fwd = c_base + c_tok · n_toks` (mean relative error ≈ 12% on their
//! hardware) and total rollout latency as
//! `t_total = c_base·N_fwd + c_tok·N_toks + C`.
//!
//! [`LatencyModel`] carries the fitted coefficients; [`fit`] recovers them
//! by least squares from `(n_toks, seconds)` profiles — either real PJRT
//! timings (`das calibrate`, Fig. 8) or the simulator's configured truth.
//! The same model powers the simulator's virtual clock, so scaled benches
//! and the budget optimizer share one latency vocabulary.

use crate::util::stats;

/// Fitted linear forward-pass latency model. Units: seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyModel {
    /// Per-forward-pass overhead (kernel launches, weight/activation
    /// movement) — `c_base` in Eq. 1.
    pub c_base: f64,
    /// Per-token compute cost — `c_tok` in Eq. 1.
    pub c_tok: f64,
    /// Non-forward overhead per rollout step (scheduling, formatting) — `C`
    /// in Eq. 2.
    pub c_step: f64,
}

impl LatencyModel {
    /// A default shaped like the paper's H100 measurements scaled to a
    /// single device: ~20ms base per forward, ~0.15ms per token.
    pub fn paper_like() -> Self {
        LatencyModel {
            c_base: 20e-3,
            c_tok: 0.15e-3,
            c_step: 50e-3,
        }
    }

    /// Latency of one forward pass over `n_toks` processed tokens (Eq. 1).
    #[inline]
    pub fn t_fwd(&self, n_toks: usize) -> f64 {
        self.c_base + self.c_tok * n_toks as f64
    }

    /// Total latency for `n_fwd` passes over `n_toks` total tokens (Eq. 2).
    #[inline]
    pub fn t_total(&self, n_fwd: usize, n_toks: usize) -> f64 {
        self.c_base * n_fwd as f64 + self.c_tok * n_toks as f64 + self.c_step
    }

    /// The base-cost-dominant regime of §4.2.2 Obs. 4 — when true, the
    /// optimal policy prioritizes cutting `N_fwd`.
    pub fn base_dominant(&self, typical_batch_tokens: usize) -> bool {
        self.c_base > self.c_tok * typical_batch_tokens as f64
    }
}

/// Result of fitting the linear model to profile points.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub model: LatencyModel,
    pub r_squared: f64,
    /// Mean relative error — the paper reports ≈ 12% (Fig. 8 caption).
    pub mre: f64,
    pub n_points: usize,
    /// The raw `(tokens, seconds)` profile points (Fig. 8 scatter).
    pub samples: Vec<(usize, f64)>,
}

/// Least-squares fit of `(tokens_processed, seconds)` samples.
pub fn fit(samples: &[(usize, f64)]) -> CalibrationReport {
    let xs: Vec<f64> = samples.iter().map(|(n, _)| *n as f64).collect();
    let ys: Vec<f64> = samples.iter().map(|(_, t)| *t).collect();
    let (a, b) = stats::linreg(&xs, &ys);
    // Clamp to physical values: latency can't be negative.
    let c_base = a.max(0.0);
    let c_tok = b.max(0.0);
    CalibrationReport {
        model: LatencyModel {
            c_base,
            c_tok,
            c_step: 0.0,
        },
        r_squared: stats::r_squared(&xs, &ys, a, b),
        mre: stats::mean_relative_error(&xs, &ys, a, b),
        n_points: samples.len(),
        samples: samples.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn t_fwd_linear() {
        let m = LatencyModel {
            c_base: 0.01,
            c_tok: 0.001,
            c_step: 0.0,
        };
        assert!((m.t_fwd(0) - 0.01).abs() < 1e-12);
        assert!((m.t_fwd(100) - 0.11).abs() < 1e-12);
        assert!((m.t_total(10, 100) - (0.1 + 0.1)).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_exact_model() {
        let truth = LatencyModel {
            c_base: 0.02,
            c_tok: 0.00015,
            c_step: 0.0,
        };
        let samples: Vec<(usize, f64)> = (1..200).map(|n| (n * 8, truth.t_fwd(n * 8))).collect();
        let rep = fit(&samples);
        assert!((rep.model.c_base - truth.c_base).abs() < 1e-9);
        assert!((rep.model.c_tok - truth.c_tok).abs() < 1e-12);
        assert!(rep.mre < 1e-9);
        assert!(rep.r_squared > 0.999999);
    }

    #[test]
    fn fit_with_noise_has_paperlike_mre() {
        // Multiplicative noise around a linear truth: the fit should land
        // near the truth with a small mean relative error, like Fig. 8.
        let truth = LatencyModel::paper_like();
        let mut rng = Rng::seed_from_u64(8);
        let samples: Vec<(usize, f64)> = (1..300)
            .map(|n| {
                let toks = n * 4;
                let noise = 1.0 + 0.12 * rng.normal();
                (toks, truth.t_fwd(toks) * noise.max(0.2))
            })
            .collect();
        let rep = fit(&samples);
        assert!(rep.mre < 0.25, "mre={}", rep.mre);
        assert!((rep.model.c_tok - truth.c_tok).abs() / truth.c_tok < 0.15);
    }

    #[test]
    fn base_dominant_regime() {
        let m = LatencyModel {
            c_base: 0.02,
            c_tok: 0.0001,
            c_step: 0.0,
        };
        assert!(m.base_dominant(50)); // 0.02 > 0.005
        assert!(!m.base_dominant(500)); // 0.02 < 0.05
    }

    #[test]
    fn fit_clamps_negative_intercept() {
        // Degenerate data sloping through negative intercept.
        let rep = fit(&[(10, 0.0005), (20, 0.0015), (30, 0.0025)]);
        assert!(rep.model.c_base >= 0.0);
    }
}

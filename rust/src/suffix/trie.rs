//! Depth-capped counting suffix trie — the drafter's production index.
//!
//! [`super::tree::SuffixTree`] gives exact O(m) longest-match with retrieval
//! drafting ("copy what followed one occurrence"). For *frequency-weighted*
//! drafting (propose the continuation that followed the context MOST OFTEN —
//! the high-frequency suffix-match walk of Fig. 3 right), we need per-path
//! occurrence counts. Maintaining exact subtree-leaf counts online in a
//! Ukkonen tree costs an ancestor walk per update; instead we follow the
//! SuffixDecoding implementation strategy: a suffix *trie* capped at depth D
//! (D = max match length + max draft budget), inserting the D-bounded
//! suffixes of every new rollout and bumping counts along each path.
//!
//! Insert cost is O(len·D) — sub-millisecond for RL rollout lengths — and the
//! cap makes total space O(corpus·D) worst case but far smaller in practice
//! due to sharing. Queries are O(m); the greedy draft walk is O(budget).

use std::collections::HashMap;

use crate::tokens::TokenId;

#[derive(Debug, Clone, Default)]
struct TrieNode {
    children: HashMap<TokenId, usize>,
    /// Number of (bounded) suffixes whose path passes through this node,
    /// i.e. occurrences of the path-string in the indexed corpus.
    count: u64,
}

#[derive(Debug, Clone)]
pub struct SuffixTrieIndex {
    nodes: Vec<TrieNode>,
    max_depth: usize,
    tokens_indexed: usize,
    rollouts: usize,
}

impl SuffixTrieIndex {
    pub fn new(max_depth: usize) -> Self {
        SuffixTrieIndex {
            nodes: vec![TrieNode::default()],
            max_depth: max_depth.max(2),
            tokens_indexed: 0,
            rollouts: 0,
        }
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn tokens_indexed(&self) -> usize {
        self.tokens_indexed
    }

    pub fn rollouts(&self) -> usize {
        self.rollouts
    }

    /// Index one rollout: insert every suffix, truncated at `max_depth`.
    pub fn insert(&mut self, tokens: &[TokenId]) {
        for start in 0..tokens.len() {
            let end = (start + self.max_depth).min(tokens.len());
            let mut node = 0usize;
            self.nodes[0].count += 1;
            for &tok in &tokens[start..end] {
                let next = match self.nodes[node].children.get(&tok) {
                    Some(&n) => n,
                    None => {
                        let id = self.nodes.len();
                        self.nodes.push(TrieNode::default());
                        self.nodes[node].children.insert(tok, id);
                        id
                    }
                };
                node = next;
                self.nodes[node].count += 1;
            }
        }
        self.tokens_indexed += tokens.len();
        self.rollouts += 1;
    }

    /// Walk a pattern from the root; returns the node if fully matched.
    fn locate(&self, pattern: &[TokenId]) -> Option<usize> {
        let mut node = 0usize;
        for tok in pattern {
            node = *self.nodes[node].children.get(tok)?;
        }
        Some(node)
    }

    /// Occurrence count of `pattern` in the indexed corpus (patterns longer
    /// than `max_depth` report 0).
    pub fn count(&self, pattern: &[TokenId]) -> u64 {
        if pattern.len() > self.max_depth {
            return 0;
        }
        self.locate(pattern).map(|n| self.nodes[n].count).unwrap_or(0)
    }

    pub fn contains(&self, pattern: &[TokenId]) -> bool {
        self.count(pattern) > 0
    }

    /// Longest suffix of `context` (≤ `max_len`) with at least `min_count`
    /// occurrences. Returns (match_len, node).
    fn longest_suffix_node(
        &self,
        context: &[TokenId],
        max_len: usize,
        min_count: u64,
    ) -> (usize, usize) {
        let cap = context.len().min(max_len).min(self.max_depth);
        for take in (1..=cap).rev() {
            if let Some(node) = self.locate(&context[context.len() - take..]) {
                if self.nodes[node].count >= min_count {
                    return (take, node);
                }
            }
        }
        (0, 0)
    }

    /// Frequency-weighted greedy draft: locate the longest context suffix,
    /// then repeatedly step to the most frequent child (ties broken by
    /// smallest token id, deterministically), up to `budget` tokens.
    ///
    /// Returns the draft and, for each draft token, the empirical
    /// confidence `count(child)/count(node)` — used by the acceptance model
    /// estimator (§4.2.2's α, k fitting).
    pub fn draft_weighted(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, Vec<f32>) {
        let (mlen, mut node) = self.longest_suffix_node(context, max_match, 1);
        if mlen == 0 || budget == 0 {
            return (Vec::new(), Vec::new());
        }
        let mut draft = Vec::with_capacity(budget);
        let mut conf = Vec::with_capacity(budget);
        for _ in 0..budget {
            let parent_count = self.nodes[node].count;
            let mut best: Option<(TokenId, usize, u64)> = None;
            for (&tok, &child) in &self.nodes[node].children {
                let c = self.nodes[child].count;
                match best {
                    None => best = Some((tok, child, c)),
                    Some((btok, _, bc)) => {
                        if c > bc || (c == bc && tok < btok) {
                            best = Some((tok, child, c));
                        }
                    }
                }
            }
            let Some((tok, child, c)) = best else { break };
            draft.push(tok);
            conf.push((c as f64 / parent_count.max(1) as f64) as f32);
            node = child;
        }
        (draft, conf)
    }

    /// Match length the context achieves against the index (diagnostics).
    pub fn match_len(&self, context: &[TokenId], max_len: usize) -> usize {
        self.longest_suffix_node(context, max_len, 1).0
    }

    /// Approximate heap bytes.
    pub fn approx_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<TrieNode>()
            + self
                .nodes
                .iter()
                .map(|n| n.children.capacity() * (std::mem::size_of::<(TokenId, usize)>() + 8))
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn counts_are_occurrences() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[1, 2, 1, 2, 3]);
        assert_eq!(idx.count(&[1, 2]), 2);
        assert_eq!(idx.count(&[1, 2, 3]), 1);
        assert_eq!(idx.count(&[2, 1]), 1);
        assert_eq!(idx.count(&[3, 1]), 0);
        assert!(idx.contains(&[2, 3]));
    }

    #[test]
    fn depth_cap_respected() {
        let mut idx = SuffixTrieIndex::new(3);
        idx.insert(&[1, 2, 3, 4, 5]);
        assert!(idx.contains(&[1, 2, 3]));
        assert_eq!(idx.count(&[1, 2, 3, 4]), 0); // beyond cap
    }

    #[test]
    fn draft_follows_majority() {
        let mut idx = SuffixTrieIndex::new(8);
        // After [5], token 7 follows twice, token 9 once.
        idx.insert(&[5, 7, 1]);
        idx.insert(&[5, 7, 2]);
        idx.insert(&[5, 9, 3]);
        let (draft, conf) = idx.draft_weighted(&[0, 0, 5], 4, 1);
        assert_eq!(draft, vec![7]);
        assert!((conf[0] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn draft_deterministic_tiebreak() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[5, 7]);
        idx.insert(&[5, 3]);
        let (draft, _) = idx.draft_weighted(&[5], 4, 1);
        assert_eq!(draft, vec![3]); // smallest token wins ties
    }

    #[test]
    fn empty_context_or_no_match() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[1, 2, 3]);
        assert!(idx.draft_weighted(&[], 4, 4).0.is_empty());
        assert!(idx.draft_weighted(&[9, 9], 4, 4).0.is_empty());
        assert!(idx.draft_weighted(&[1], 4, 0).0.is_empty());
    }

    #[test]
    fn multi_rollout_counts_accumulate() {
        let mut idx = SuffixTrieIndex::new(6);
        for _ in 0..10 {
            idx.insert(&[1, 2, 3]);
        }
        assert_eq!(idx.count(&[2, 3]), 10);
        assert_eq!(idx.rollouts(), 10);
        assert_eq!(idx.tokens_indexed(), 30);
    }

    #[test]
    fn prop_counts_match_naive() {
        prop::check(128, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let depth = 2 + g.usize_in(0, 6);
            let mut idx = SuffixTrieIndex::new(depth);
            let mut rollouts = Vec::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 50);
                idx.insert(&r);
                rollouts.push(r);
            }
            for _ in 0..12 {
                let pat = g.vec_u32_nonempty(alphabet, depth);
                let naive: u64 = rollouts
                    .iter()
                    .map(|r| {
                        if r.len() < pat.len() {
                            0
                        } else {
                            r.windows(pat.len()).filter(|w| *w == pat.as_slice()).count() as u64
                        }
                    })
                    .sum();
                prop::require_eq(idx.count(&pat), naive, "count vs naive")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_tokens_seen_in_corpus() {
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let mut idx = SuffixTrieIndex::new(12);
            let mut corpus: Vec<Vec<u32>> = Vec::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 40);
                idx.insert(&r);
                corpus.push(r);
            }
            let ctx = g.vec_u32_nonempty(alphabet, 10);
            let (draft, conf) = idx.draft_weighted(&ctx, 6, 5);
            prop::require_eq(draft.len(), conf.len(), "draft/conf aligned")?;
            for c in &conf {
                prop::require(*c > 0.0 && *c <= 1.0, "confidence in (0,1]")?;
            }
            // Every drafted step extends a context suffix that occurs with
            // that continuation somewhere in the corpus.
            if !draft.is_empty() {
                let mlen = idx.match_len(&ctx, 6);
                let mut needle: Vec<u32> = ctx[ctx.len() - mlen..].to_vec();
                needle.push(draft[0]);
                let found = corpus
                    .iter()
                    .any(|r| r.windows(needle.len()).any(|w| w == needle.as_slice()));
                prop::require(found, "first draft token must be a seen continuation")?;
            }
            Ok(())
        });
    }
}

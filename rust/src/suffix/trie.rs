//! Depth-capped counting suffix trie — the drafter's production index.
//!
//! [`super::tree::SuffixTree`] gives exact O(m) longest-match with retrieval
//! drafting ("copy what followed one occurrence"). For *frequency-weighted*
//! drafting (propose the continuation that followed the context MOST OFTEN —
//! the high-frequency suffix-match walk of Fig. 3 right), we need per-path
//! occurrence counts. Maintaining exact subtree-leaf counts online in a
//! Ukkonen tree costs an ancestor walk per update; instead we follow the
//! SuffixDecoding implementation strategy: a suffix *trie* capped at depth D
//! (D = max match length + max draft budget), inserting the D-bounded
//! suffixes of every new rollout and bumping counts along each path.
//!
//! Since the core refactor this type is a thin veneer: all trie machinery —
//! the **path-compressed** flat node arena, the interned token-segment pool
//! (shareable across shards via [`super::core::SharedPool`]), the branchless
//! inline `ChildTable`, suffix links over compressed edges, and the locate /
//! insert / deepest-match / greedy-walk traversals — lives once in
//! [`super::core::ArenaTrie`], parameterized here with the plain
//! [`super::core::Counts`] store.
//!
//! # Cost model
//!
//! * `insert`: one skip/count walk per start position; count bumps are per
//!   *explicit node* (branching/termination points), not per token, so
//!   shared-prefix rollouts pay a few bumps per position instead of D. The
//!   whole rollout is interned once — repeats add zero pool bytes.
//! * `count`/`contains`: O(m) label comparison (may end mid-edge).
//! * longest-suffix match: a **single O(m) forward pass** using suffix
//!   links generalized to compressed edges (skip/count re-descents).
//! * greedy draft walk: O(budget) — forced (probe-free) inside an edge,
//!   one sorted branchless table scan at explicit nodes; deterministic
//!   smallest-token tie-breaking either way.

use crate::store::wire::{Reader, StoreError, Writer};
use crate::suffix::core::{ArenaTrie, Counts, PoolStats, SharedPool, SnapshotStats, TrieSnapshot};
use crate::tokens::TokenId;

#[derive(Debug, Clone)]
pub struct SuffixTrieIndex {
    trie: ArenaTrie<Counts>,
    tokens_indexed: usize,
    rollouts: usize,
}

impl SuffixTrieIndex {
    pub fn new(max_depth: usize) -> Self {
        Self::with_pool(max_depth, SharedPool::new())
    }

    /// Index whose edge labels are interned in `pool` (shared-prefix
    /// deduplication across every index on the same pool).
    pub fn with_pool(max_depth: usize, pool: SharedPool) -> Self {
        SuffixTrieIndex {
            trie: ArenaTrie::with_pool(max_depth.max(2), Counts::default(), pool),
            tokens_indexed: 0,
            rollouts: 0,
        }
    }

    pub fn max_depth(&self) -> usize {
        self.trie.max_depth()
    }

    /// Explicit (compressed) trie nodes. See
    /// [`SuffixTrieIndex::token_positions`] for the uncompressed equivalent.
    pub fn node_count(&self) -> usize {
        self.trie.node_count()
    }

    /// What a one-node-per-token trie would allocate for the same content.
    pub fn token_positions(&self) -> usize {
        self.trie.token_positions()
    }

    /// Live/dead accounting of the (possibly shared) segment pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.trie.pool_stats()
    }

    /// Exact suffix-link rebuilds the core has run for this index (the
    /// plain trie never compacts, so these are all insert-count-triggered
    /// refreshes).
    pub fn link_rebuilds(&self) -> u64 {
        self.trie.link_rebuilds()
    }

    pub fn tokens_indexed(&self) -> usize {
        self.tokens_indexed
    }

    pub fn rollouts(&self) -> usize {
        self.rollouts
    }

    /// Index one rollout: insert every suffix, truncated at `max_depth`.
    pub fn insert(&mut self, tokens: &[TokenId]) {
        self.trie.insert_suffixes(tokens, ());
        self.tokens_indexed += tokens.len();
        self.rollouts += 1;
    }

    /// Occurrence count of `pattern` in the indexed corpus (patterns longer
    /// than `max_depth` report 0). Mid-edge matches read the edge's lower
    /// node — exact by the compressed-counting invariant.
    pub fn count(&self, pattern: &[TokenId]) -> u64 {
        if pattern.len() > self.max_depth() {
            return 0;
        }
        self.trie
            .locate(pattern)
            .map(|p| self.trie.store().get(p.row()))
            .unwrap_or(0)
    }

    pub fn contains(&self, pattern: &[TokenId]) -> bool {
        self.count(pattern) > 0
    }

    /// Frequency-weighted greedy draft: locate the longest context suffix
    /// (one suffix-link pass), then repeatedly step to the most frequent
    /// continuation (ties broken by smallest token id, deterministically),
    /// up to `budget` tokens.
    ///
    /// Returns the draft and, for each draft token, the empirical
    /// confidence `count(child)/count(node)` — used by the acceptance model
    /// estimator (§4.2.2's α, k fitting).
    pub fn draft_weighted(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, Vec<f32>) {
        let (tokens, confidence, _) = self.draft_weighted_with_match(context, max_match, budget);
        (tokens, confidence)
    }

    /// `draft_weighted` plus the achieved match length, from ONE
    /// suffix-link pass — callers that need both (the `DraftSource` layer)
    /// must not pay the match twice.
    pub fn draft_weighted_with_match(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, Vec<f32>, usize) {
        let (mlen, pos) = self.trie.deepest_suffix(context, max_match, ());
        if mlen == 0 || budget == 0 {
            return (Vec::new(), Vec::new(), mlen);
        }
        let (tokens, confidence) = self.trie.greedy_walk(pos, budget, ());
        (tokens, confidence, mlen)
    }

    /// Match length the context achieves against the index (diagnostics).
    pub fn match_len(&self, context: &[TokenId], max_len: usize) -> usize {
        self.trie.deepest_suffix(context, max_len, ()).0
    }

    /// Approximate heap bytes (arena + store; pool bytes are reported
    /// separately since the pool may be shared).
    pub fn approx_bytes(&self) -> usize {
        self.trie.approx_bytes()
    }

    /// Handle to the segment pool backing this index's edge labels.
    pub fn pool(&self) -> SharedPool {
        self.trie.pool()
    }

    /// Publish an immutable lock-free read view of the index as of every
    /// insert so far (an O(chunk-table) clone; see
    /// [`crate::suffix::core::TrieSnapshot`]).
    pub fn publish(&self) -> SuffixTrieSnapshot {
        SuffixTrieSnapshot {
            trie: self.trie.publish(),
            tokens_indexed: self.tokens_indexed,
            rollouts: self.rollouts,
        }
    }

    /// Serialize the index (counters + counting trie) as one
    /// `das-store-v1` source blob; the pool is saved once by the owner.
    pub fn save_state(&self, w: &mut Writer) {
        w.str("trie-index");
        w.usize(self.max_depth());
        w.usize(self.tokens_indexed);
        w.usize(self.rollouts);
        self.trie.save_state(w);
    }

    /// Restore from [`SuffixTrieIndex::save_state`] into this instance
    /// (constructed on the pool holding the snapshot's segments). A depth
    /// cap that disagrees with the configured one is a
    /// [`StoreError::Mismatch`].
    pub fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        r.expect_str("trie-index", "source blob tag")?;
        let max_depth = r.usize()?;
        if max_depth != self.max_depth() {
            return Err(StoreError::Mismatch(format!(
                "snapshot depth cap {max_depth} != configured {}",
                self.max_depth()
            )));
        }
        let tokens_indexed = r.usize()?;
        let rollouts = r.usize()?;
        self.trie = ArenaTrie::load_state(r, self.trie.pool())?;
        self.tokens_indexed = tokens_indexed;
        self.rollouts = rollouts;
        Ok(())
    }
}

/// Immutable published view of one [`SuffixTrieIndex`]: the same count /
/// match / frequency-weighted draft walks over a
/// [`crate::suffix::core::TrieSnapshot`], frozen at the publish and
/// answering with zero lock acquisitions. Bit-identical to the live index
/// at the publish point (property-tested in the drafter layer).
#[derive(Debug, Clone)]
pub struct SuffixTrieSnapshot {
    trie: TrieSnapshot<Counts>,
    tokens_indexed: usize,
    rollouts: usize,
}

impl SuffixTrieSnapshot {
    pub fn max_depth(&self) -> usize {
        self.trie.max_depth()
    }

    /// Size gauges precomputed at publish (no arena rescan).
    pub fn stats(&self) -> SnapshotStats {
        self.trie.stats()
    }

    pub fn tokens_indexed(&self) -> usize {
        self.tokens_indexed
    }

    pub fn rollouts(&self) -> usize {
        self.rollouts
    }

    /// See [`SuffixTrieIndex::count`].
    pub fn count(&self, pattern: &[TokenId]) -> u64 {
        if pattern.len() > self.max_depth() {
            return 0;
        }
        self.trie
            .locate(pattern)
            .map(|p| self.trie.store().get(p.row()))
            .unwrap_or(0)
    }

    /// See [`SuffixTrieIndex::match_len`].
    pub fn match_len(&self, context: &[TokenId], max_len: usize) -> usize {
        self.trie.deepest_suffix(context, max_len, ()).0
    }

    /// See [`SuffixTrieIndex::draft_weighted_with_match`].
    pub fn draft_weighted_with_match(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, Vec<f32>, usize) {
        let (mlen, pos) = self.trie.deepest_suffix(context, max_match, ());
        if mlen == 0 || budget == 0 {
            return (Vec::new(), Vec::new(), mlen);
        }
        let (tokens, confidence) = self.trie.greedy_walk(pos, budget, ());
        (tokens, confidence, mlen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::tree::SuffixTree;
    use crate::util::prop;

    #[test]
    fn published_snapshot_answers_like_live_index_and_freezes() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[5, 7, 1]);
        idx.insert(&[5, 7, 2]);
        idx.insert(&[5, 9, 3]);
        let snap = idx.publish();
        assert_eq!(snap.count(&[5, 7]), idx.count(&[5, 7]));
        assert_eq!(snap.match_len(&[0, 5, 7], 4), idx.match_len(&[0, 5, 7], 4));
        assert_eq!(
            snap.draft_weighted_with_match(&[0, 0, 5], 4, 2),
            idx.draft_weighted_with_match(&[0, 0, 5], 4, 2),
        );
        assert_eq!(snap.stats().nodes, idx.node_count());
        assert_eq!(snap.stats().heap_bytes, idx.approx_bytes());
        assert_eq!((snap.tokens_indexed(), snap.rollouts()), (9, 3));
        // Mutating the writer leaves the snapshot at its publish point.
        idx.insert(&[5, 9, 4]);
        assert_eq!(snap.count(&[5, 9]), 1);
        assert_eq!(idx.count(&[5, 9]), 2);
    }

    #[test]
    fn counts_are_occurrences() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[1, 2, 1, 2, 3]);
        assert_eq!(idx.count(&[1, 2]), 2);
        assert_eq!(idx.count(&[1, 2, 3]), 1);
        assert_eq!(idx.count(&[2, 1]), 1);
        assert_eq!(idx.count(&[3, 1]), 0);
        assert!(idx.contains(&[2, 3]));
    }

    #[test]
    fn depth_cap_respected() {
        let mut idx = SuffixTrieIndex::new(3);
        idx.insert(&[1, 2, 3, 4, 5]);
        assert!(idx.contains(&[1, 2, 3]));
        assert_eq!(idx.count(&[1, 2, 3, 4]), 0); // beyond cap
    }

    #[test]
    fn draft_follows_majority() {
        let mut idx = SuffixTrieIndex::new(8);
        // After [5], token 7 follows twice, token 9 once.
        idx.insert(&[5, 7, 1]);
        idx.insert(&[5, 7, 2]);
        idx.insert(&[5, 9, 3]);
        let (draft, conf) = idx.draft_weighted(&[0, 0, 5], 4, 1);
        assert_eq!(draft, vec![7]);
        assert!((conf[0] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn draft_deterministic_tiebreak() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[5, 7]);
        idx.insert(&[5, 3]);
        let (draft, _) = idx.draft_weighted(&[5], 4, 1);
        assert_eq!(draft, vec![3]); // smallest token wins ties
    }

    #[test]
    fn empty_context_or_no_match() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[1, 2, 3]);
        assert!(idx.draft_weighted(&[], 4, 4).0.is_empty());
        assert!(idx.draft_weighted(&[9, 9], 4, 4).0.is_empty());
        assert!(idx.draft_weighted(&[1], 4, 0).0.is_empty());
    }

    #[test]
    fn multi_rollout_counts_accumulate() {
        let mut idx = SuffixTrieIndex::new(6);
        for _ in 0..10 {
            idx.insert(&[1, 2, 3]);
        }
        assert_eq!(idx.count(&[2, 3]), 10);
        assert_eq!(idx.rollouts(), 10);
        assert_eq!(idx.tokens_indexed(), 30);
    }

    #[test]
    fn compression_collapses_shared_prefixes() {
        // Rollouts sharing a long boilerplate prefix: explicit nodes stay
        // close to the branching structure while the token-position count
        // (what the uncompressed trie allocated) keeps growing.
        let mut idx = SuffixTrieIndex::new(24);
        let prefix: Vec<u32> = (0..40).map(|i| 100 + i).collect();
        for tail in 0..8u32 {
            let mut r = prefix.clone();
            r.extend((0..10).map(|j| 200 + tail * 10 + j));
            idx.insert(&r);
        }
        assert!(
            idx.node_count() * 2 < idx.token_positions(),
            "shared-prefix corpus must compress ≥2×: {} nodes vs {} positions",
            idx.node_count(),
            idx.token_positions()
        );
        // Drafting through the shared prefix still works.
        let (draft, _) = idx.draft_weighted(&[100, 101, 102], 8, 4);
        assert_eq!(draft, vec![103, 104, 105, 106]);
    }

    #[test]
    fn high_fanout_spills_and_stays_sorted() {
        // Force the root past the inline capacity: 12 distinct first tokens.
        let mut idx = SuffixTrieIndex::new(4);
        for t in (0..12u32).rev() {
            idx.insert(&[t, 100 + t]);
        }
        for t in 0..12u32 {
            assert_eq!(idx.count(&[t]), 1, "child {t} reachable after spill");
            assert_eq!(idx.count(&[t, 100 + t]), 1);
        }
        // All counts equal ⇒ deterministic smallest-token draft from root
        // context match is still well-defined via any matching suffix.
        let (draft, _) = idx.draft_weighted(&[3], 4, 1);
        assert_eq!(draft, vec![103]);
    }

    #[test]
    fn prop_counts_match_naive() {
        prop::check(128, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let depth = 2 + g.usize_in(0, 6);
            let mut idx = SuffixTrieIndex::new(depth);
            let mut rollouts = Vec::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 50);
                idx.insert(&r);
                rollouts.push(r);
            }
            for _ in 0..12 {
                let pat = g.vec_u32_nonempty(alphabet, depth);
                let naive: u64 = rollouts
                    .iter()
                    .map(|r| {
                        if r.len() < pat.len() {
                            0
                        } else {
                            r.windows(pat.len()).filter(|w| *w == pat.as_slice()).count() as u64
                        }
                    })
                    .sum();
                prop::require_eq(idx.count(&pat), naive, "count vs naive")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_tokens_seen_in_corpus() {
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let mut idx = SuffixTrieIndex::new(12);
            let mut corpus: Vec<Vec<u32>> = Vec::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 40);
                idx.insert(&r);
                corpus.push(r);
            }
            let ctx = g.vec_u32_nonempty(alphabet, 10);
            let (draft, conf) = idx.draft_weighted(&ctx, 6, 5);
            prop::require_eq(draft.len(), conf.len(), "draft/conf aligned")?;
            for c in &conf {
                prop::require(*c > 0.0 && *c <= 1.0, "confidence in (0,1]")?;
            }
            // Every drafted step extends a context suffix that occurs with
            // that continuation somewhere in the corpus.
            if !draft.is_empty() {
                let mlen = idx.match_len(&ctx, 6);
                let mut needle: Vec<u32> = ctx[ctx.len() - mlen..].to_vec();
                needle.push(draft[0]);
                let found = corpus
                    .iter()
                    .any(|r| r.windows(needle.len()).any(|w| w == needle.as_slice()));
                prop::require(found, "first draft token must be a seen continuation")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_longest_suffix_matches_naive_rescan() {
        // Safety net for the compressed suffix-link O(m) pass: it must find
        // exactly the length the old descending rescan found.
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let depth = 2 + g.usize_in(0, 10);
            let mut idx = SuffixTrieIndex::new(depth);
            for _ in 0..g.usize_in(1, 4) {
                idx.insert(&g.vec_u32_nonempty(alphabet, 40));
            }
            let ctx = g.vec_u32_nonempty(alphabet, 20);
            let max_len = 1 + g.usize_in(0, 10);
            let naive = {
                let cap = ctx.len().min(max_len).min(idx.max_depth());
                let mut best = 0;
                for take in (1..=cap).rev() {
                    if idx.count(&ctx[ctx.len() - take..]) >= 1 {
                        best = take;
                        break;
                    }
                }
                best
            };
            prop::require_eq(idx.match_len(&ctx, max_len), naive, "deepest match vs rescan")?;
            Ok(())
        });
    }

    #[test]
    fn prop_agrees_with_suffix_tree() {
        // Cross-structure agreement: the compressed arena trie and the
        // Ukkonen tree must answer containment and longest-suffix-match
        // identically for patterns within the trie's depth cap.
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut trie = SuffixTrieIndex::new(16);
            let mut tree = SuffixTree::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 40);
                trie.insert(&r);
                tree.insert(&r);
            }
            for _ in 0..12 {
                let pat = g.vec_u32_nonempty(alphabet, 12);
                prop::require_eq(
                    trie.contains(&pat),
                    tree.contains(&pat),
                    "containment agreement",
                )?;
            }
            let ctx = g.vec_u32_nonempty(alphabet, 12);
            let (tree_mlen, _) = tree.longest_suffix_match(&ctx, 8);
            prop::require_eq(trie.match_len(&ctx, 8), tree_mlen, "longest-suffix agreement")?;
            Ok(())
        });
    }
}

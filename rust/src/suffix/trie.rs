//! Depth-capped counting suffix trie — the drafter's production index.
//!
//! [`super::tree::SuffixTree`] gives exact O(m) longest-match with retrieval
//! drafting ("copy what followed one occurrence"). For *frequency-weighted*
//! drafting (propose the continuation that followed the context MOST OFTEN —
//! the high-frequency suffix-match walk of Fig. 3 right), we need per-path
//! occurrence counts. Maintaining exact subtree-leaf counts online in a
//! Ukkonen tree costs an ancestor walk per update; instead we follow the
//! SuffixDecoding implementation strategy: a suffix *trie* capped at depth D
//! (D = max match length + max draft budget), inserting the D-bounded
//! suffixes of every new rollout and bumping counts along each path.
//!
//! # Layout: flat node arena + inline sorted children
//!
//! Nodes live in one bump-allocated `Vec` (ids are indices, the root is 0)
//! and child edges use [`ChildTable`]: up to [`INLINE_CHILDREN`] children are
//! stored *inside the node* as parallel sorted arrays, spilling to a sorted
//! heap `Vec` only for high-fanout nodes (in practice just the root and its
//! immediate children — deeper trie nodes are overwhelmingly low-fanout).
//! Compared to the original `HashMap<TokenId, usize>` per node this removes
//! a hash + heap indirection from every (suffix × token) probe on both the
//! insert and query hot paths, and keeps child scans inside one cache line.
//!
//! # Cost model
//!
//! * `insert`: O(len · D) child probes, each an inline scan of ≤ 4 slots or
//!   a binary search of the spill vector.
//! * `count`/`contains`: O(m) probes.
//! * longest-suffix match: O(m log m) — suffix *presence* (and counts) are
//!   monotone under suffix-shortening (every substring of an indexed string
//!   is itself indexed), so the deepest match is found by binary search on
//!   the match length instead of the old O(m²) rescan of every candidate.
//! * greedy draft walk: O(budget · fanout) with sorted, deterministic child
//!   iteration (ties break toward the smallest token id for free).

use crate::tokens::TokenId;

/// Children stored inline per node before spilling to a sorted heap vector.
pub(crate) const INLINE_CHILDREN: usize = 4;

/// Sorted child table: inline small-array storage with sorted-`Vec` spill.
///
/// Iteration order is always ascending token id, which the draft walks rely
/// on for deterministic smallest-token tie-breaking.
#[derive(Debug, Clone, Default)]
pub(crate) struct ChildTable {
    inline_len: u8,
    inline_tokens: [TokenId; INLINE_CHILDREN],
    inline_children: [u32; INLINE_CHILDREN],
    /// Sorted by token; `Some` once fanout exceeds `INLINE_CHILDREN` (the
    /// inline arrays are then no longer authoritative).
    spill: Option<Box<Vec<(TokenId, u32)>>>,
}

impl ChildTable {
    #[inline]
    pub(crate) fn get(&self, tok: TokenId) -> Option<u32> {
        if let Some(spill) = &self.spill {
            match spill.binary_search_by_key(&tok, |&(t, _)| t) {
                Ok(i) => Some(spill[i].1),
                Err(_) => None,
            }
        } else {
            for i in 0..self.inline_len as usize {
                if self.inline_tokens[i] == tok {
                    return Some(self.inline_children[i]);
                }
            }
            None
        }
    }

    /// Insert a child for a token NOT already present.
    pub(crate) fn insert(&mut self, tok: TokenId, child: u32) {
        if let Some(spill) = &mut self.spill {
            let pos = spill
                .binary_search_by_key(&tok, |&(t, _)| t)
                .unwrap_err();
            spill.insert(pos, (tok, child));
            return;
        }
        let len = self.inline_len as usize;
        if len < INLINE_CHILDREN {
            let mut pos = len;
            for i in 0..len {
                if self.inline_tokens[i] > tok {
                    pos = i;
                    break;
                }
            }
            let mut i = len;
            while i > pos {
                self.inline_tokens[i] = self.inline_tokens[i - 1];
                self.inline_children[i] = self.inline_children[i - 1];
                i -= 1;
            }
            self.inline_tokens[pos] = tok;
            self.inline_children[pos] = child;
            self.inline_len = (len + 1) as u8;
        } else {
            // Spill: move everything to one sorted heap vector.
            let mut v: Vec<(TokenId, u32)> = Vec::with_capacity(INLINE_CHILDREN * 2);
            for i in 0..len {
                v.push((self.inline_tokens[i], self.inline_children[i]));
            }
            let pos = v.binary_search_by_key(&tok, |&(t, _)| t).unwrap_err();
            v.insert(pos, (tok, child));
            self.spill = Some(Box::new(v));
            self.inline_len = 0;
        }
    }

    /// Visit children in ascending token order.
    #[inline]
    pub(crate) fn for_each<F: FnMut(TokenId, u32)>(&self, mut f: F) {
        if let Some(spill) = &self.spill {
            for &(t, c) in spill.iter() {
                f(t, c);
            }
        } else {
            for i in 0..self.inline_len as usize {
                f(self.inline_tokens[i], self.inline_children[i]);
            }
        }
    }

    pub(crate) fn len(&self) -> usize {
        match &self.spill {
            Some(spill) => spill.len(),
            None => self.inline_len as usize,
        }
    }

    /// Heap bytes beyond the inline struct (the spill vector, if any).
    pub(crate) fn heap_bytes(&self) -> usize {
        match &self.spill {
            Some(spill) => {
                std::mem::size_of::<Vec<(TokenId, u32)>>()
                    + spill.capacity() * std::mem::size_of::<(TokenId, u32)>()
            }
            None => 0,
        }
    }
}

#[derive(Debug, Clone, Default)]
struct TrieNode {
    children: ChildTable,
    /// Number of (bounded) suffixes whose path passes through this node,
    /// i.e. occurrences of the path-string in the indexed corpus.
    count: u64,
}

#[derive(Debug, Clone)]
pub struct SuffixTrieIndex {
    nodes: Vec<TrieNode>,
    max_depth: usize,
    tokens_indexed: usize,
    rollouts: usize,
}

impl SuffixTrieIndex {
    pub fn new(max_depth: usize) -> Self {
        SuffixTrieIndex {
            nodes: vec![TrieNode::default()],
            max_depth: max_depth.max(2),
            tokens_indexed: 0,
            rollouts: 0,
        }
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn tokens_indexed(&self) -> usize {
        self.tokens_indexed
    }

    pub fn rollouts(&self) -> usize {
        self.rollouts
    }

    /// Index one rollout: insert every suffix, truncated at `max_depth`.
    pub fn insert(&mut self, tokens: &[TokenId]) {
        for start in 0..tokens.len() {
            let end = (start + self.max_depth).min(tokens.len());
            let mut node = 0usize;
            self.nodes[0].count += 1;
            for &tok in &tokens[start..end] {
                let next = match self.nodes[node].children.get(tok) {
                    Some(n) => n as usize,
                    None => {
                        let id = self.nodes.len();
                        self.nodes.push(TrieNode::default());
                        self.nodes[node].children.insert(tok, id as u32);
                        id
                    }
                };
                node = next;
                self.nodes[node].count += 1;
            }
        }
        self.tokens_indexed += tokens.len();
        self.rollouts += 1;
    }

    /// Walk a pattern from the root; returns the node if fully matched.
    fn locate(&self, pattern: &[TokenId]) -> Option<usize> {
        let mut node = 0usize;
        for &tok in pattern {
            node = self.nodes[node].children.get(tok)? as usize;
        }
        Some(node)
    }

    /// Occurrence count of `pattern` in the indexed corpus (patterns longer
    /// than `max_depth` report 0).
    pub fn count(&self, pattern: &[TokenId]) -> u64 {
        if pattern.len() > self.max_depth {
            return 0;
        }
        self.locate(pattern).map(|n| self.nodes[n].count).unwrap_or(0)
    }

    pub fn contains(&self, pattern: &[TokenId]) -> bool {
        self.count(pattern) > 0
    }

    /// Longest suffix of `context` (≤ `max_len`) with at least `min_count`
    /// occurrences. Returns (match_len, node).
    ///
    /// Presence (and count) of a suffix is monotone in its length: if the
    /// length-k suffix occurs ≥ c times, every shorter suffix occurs at
    /// least as often (each occurrence of the longer string contains one of
    /// the shorter, and both are within the depth cap). So instead of the
    /// old O(m²) descending rescan of every candidate suffix from the root,
    /// binary-search the deepest matching length: O(m log m) arena probes.
    fn longest_suffix_node(
        &self,
        context: &[TokenId],
        max_len: usize,
        min_count: u64,
    ) -> (usize, usize) {
        let cap = context.len().min(max_len).min(self.max_depth);
        if cap == 0 {
            return (0, 0);
        }
        let probe = |take: usize| -> Option<usize> {
            self.locate(&context[context.len() - take..])
                .filter(|&n| self.nodes[n].count >= min_count)
        };
        let Some(mut best_node) = probe(1) else {
            return (0, 0);
        };
        let mut lo = 1usize;
        let mut hi = cap;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            match probe(mid) {
                Some(n) => {
                    lo = mid;
                    best_node = n;
                }
                None => hi = mid - 1,
            }
        }
        (lo, best_node)
    }

    /// Frequency-weighted greedy draft: locate the longest context suffix,
    /// then repeatedly step to the most frequent child (ties broken by
    /// smallest token id, deterministically), up to `budget` tokens.
    ///
    /// Returns the draft and, for each draft token, the empirical
    /// confidence `count(child)/count(node)` — used by the acceptance model
    /// estimator (§4.2.2's α, k fitting).
    pub fn draft_weighted(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, Vec<f32>) {
        let (mlen, mut node) = self.longest_suffix_node(context, max_match, 1);
        if mlen == 0 || budget == 0 {
            return (Vec::new(), Vec::new());
        }
        let mut draft = Vec::with_capacity(budget);
        let mut conf = Vec::with_capacity(budget);
        for _ in 0..budget {
            let parent_count = self.nodes[node].count;
            let mut best: Option<(TokenId, usize, u64)> = None;
            // Ascending-token iteration + strict `>` ⇒ smallest token id
            // wins count ties, matching the old HashMap scan's tie rule.
            self.nodes[node].children.for_each(|tok, child| {
                let c = self.nodes[child as usize].count;
                match best {
                    None => best = Some((tok, child as usize, c)),
                    Some((_, _, bc)) => {
                        if c > bc {
                            best = Some((tok, child as usize, c));
                        }
                    }
                }
            });
            let Some((tok, child, c)) = best else { break };
            draft.push(tok);
            conf.push((c as f64 / parent_count.max(1) as f64) as f32);
            node = child;
        }
        (draft, conf)
    }

    /// Match length the context achieves against the index (diagnostics).
    pub fn match_len(&self, context: &[TokenId], max_len: usize) -> usize {
        self.longest_suffix_node(context, max_len, 1).0
    }

    /// Approximate heap bytes.
    pub fn approx_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<TrieNode>()
            + self
                .nodes
                .iter()
                .map(|n| n.children.heap_bytes())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::tree::SuffixTree;
    use crate::util::prop;

    #[test]
    fn counts_are_occurrences() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[1, 2, 1, 2, 3]);
        assert_eq!(idx.count(&[1, 2]), 2);
        assert_eq!(idx.count(&[1, 2, 3]), 1);
        assert_eq!(idx.count(&[2, 1]), 1);
        assert_eq!(idx.count(&[3, 1]), 0);
        assert!(idx.contains(&[2, 3]));
    }

    #[test]
    fn depth_cap_respected() {
        let mut idx = SuffixTrieIndex::new(3);
        idx.insert(&[1, 2, 3, 4, 5]);
        assert!(idx.contains(&[1, 2, 3]));
        assert_eq!(idx.count(&[1, 2, 3, 4]), 0); // beyond cap
    }

    #[test]
    fn draft_follows_majority() {
        let mut idx = SuffixTrieIndex::new(8);
        // After [5], token 7 follows twice, token 9 once.
        idx.insert(&[5, 7, 1]);
        idx.insert(&[5, 7, 2]);
        idx.insert(&[5, 9, 3]);
        let (draft, conf) = idx.draft_weighted(&[0, 0, 5], 4, 1);
        assert_eq!(draft, vec![7]);
        assert!((conf[0] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn draft_deterministic_tiebreak() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[5, 7]);
        idx.insert(&[5, 3]);
        let (draft, _) = idx.draft_weighted(&[5], 4, 1);
        assert_eq!(draft, vec![3]); // smallest token wins ties
    }

    #[test]
    fn empty_context_or_no_match() {
        let mut idx = SuffixTrieIndex::new(8);
        idx.insert(&[1, 2, 3]);
        assert!(idx.draft_weighted(&[], 4, 4).0.is_empty());
        assert!(idx.draft_weighted(&[9, 9], 4, 4).0.is_empty());
        assert!(idx.draft_weighted(&[1], 4, 0).0.is_empty());
    }

    #[test]
    fn multi_rollout_counts_accumulate() {
        let mut idx = SuffixTrieIndex::new(6);
        for _ in 0..10 {
            idx.insert(&[1, 2, 3]);
        }
        assert_eq!(idx.count(&[2, 3]), 10);
        assert_eq!(idx.rollouts(), 10);
        assert_eq!(idx.tokens_indexed(), 30);
    }

    #[test]
    fn high_fanout_spills_and_stays_sorted() {
        // Force the root past the inline capacity: 12 distinct first tokens.
        let mut idx = SuffixTrieIndex::new(4);
        for t in (0..12u32).rev() {
            idx.insert(&[t, 100 + t]);
        }
        for t in 0..12u32 {
            assert_eq!(idx.count(&[t]), 1, "child {t} reachable after spill");
            assert_eq!(idx.count(&[t, 100 + t]), 1);
        }
        // All counts equal ⇒ deterministic smallest-token draft from root
        // context match is still well-defined via any matching suffix.
        let (draft, _) = idx.draft_weighted(&[3], 4, 1);
        assert_eq!(draft, vec![103]);
    }

    #[test]
    fn child_table_inline_and_spill_paths() {
        let mut t = ChildTable::default();
        for (i, tok) in [7u32, 3, 9, 1].iter().enumerate() {
            t.insert(*tok, i as u32 + 10);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.get(3), Some(11));
        assert_eq!(t.get(2), None);
        // Fifth child spills to the sorted vector.
        t.insert(5, 99);
        assert_eq!(t.len(), 5);
        let mut order = Vec::new();
        t.for_each(|tok, _| order.push(tok));
        assert_eq!(order, vec![1, 3, 5, 7, 9]);
        assert_eq!(t.get(5), Some(99));
        assert_eq!(t.get(7), Some(10));
        assert!(t.heap_bytes() > 0);
    }

    #[test]
    fn prop_counts_match_naive() {
        prop::check(128, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let depth = 2 + g.usize_in(0, 6);
            let mut idx = SuffixTrieIndex::new(depth);
            let mut rollouts = Vec::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 50);
                idx.insert(&r);
                rollouts.push(r);
            }
            for _ in 0..12 {
                let pat = g.vec_u32_nonempty(alphabet, depth);
                let naive: u64 = rollouts
                    .iter()
                    .map(|r| {
                        if r.len() < pat.len() {
                            0
                        } else {
                            r.windows(pat.len()).filter(|w| *w == pat.as_slice()).count() as u64
                        }
                    })
                    .sum();
                prop::require_eq(idx.count(&pat), naive, "count vs naive")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_tokens_seen_in_corpus() {
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let mut idx = SuffixTrieIndex::new(12);
            let mut corpus: Vec<Vec<u32>> = Vec::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 40);
                idx.insert(&r);
                corpus.push(r);
            }
            let ctx = g.vec_u32_nonempty(alphabet, 10);
            let (draft, conf) = idx.draft_weighted(&ctx, 6, 5);
            prop::require_eq(draft.len(), conf.len(), "draft/conf aligned")?;
            for c in &conf {
                prop::require(*c > 0.0 && *c <= 1.0, "confidence in (0,1]")?;
            }
            // Every drafted step extends a context suffix that occurs with
            // that continuation somewhere in the corpus.
            if !draft.is_empty() {
                let mlen = idx.match_len(&ctx, 6);
                let mut needle: Vec<u32> = ctx[ctx.len() - mlen..].to_vec();
                needle.push(draft[0]);
                let found = corpus
                    .iter()
                    .any(|r| r.windows(needle.len()).any(|w| w == needle.as_slice()));
                prop::require(found, "first draft token must be a seen continuation")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_longest_suffix_matches_naive_rescan() {
        // Safety net for the monotone binary search: it must find exactly
        // the length the old descending rescan found.
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let depth = 2 + g.usize_in(0, 10);
            let mut idx = SuffixTrieIndex::new(depth);
            for _ in 0..g.usize_in(1, 4) {
                idx.insert(&g.vec_u32_nonempty(alphabet, 40));
            }
            let ctx = g.vec_u32_nonempty(alphabet, 20);
            let max_len = 1 + g.usize_in(0, 10);
            let naive = {
                let cap = ctx.len().min(max_len).min(idx.max_depth());
                let mut best = 0;
                for take in (1..=cap).rev() {
                    if idx.count(&ctx[ctx.len() - take..]) >= 1 {
                        best = take;
                        break;
                    }
                }
                best
            };
            prop::require_eq(idx.match_len(&ctx, max_len), naive, "deepest match vs rescan")?;
            Ok(())
        });
    }

    #[test]
    fn prop_agrees_with_suffix_tree() {
        // Cross-structure agreement: the arena trie and the Ukkonen tree
        // must answer containment and longest-suffix-match identically for
        // patterns within the trie's depth cap.
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut trie = SuffixTrieIndex::new(16);
            let mut tree = SuffixTree::new();
            for _ in 0..g.usize_in(1, 4) {
                let r = g.vec_u32_nonempty(alphabet, 40);
                trie.insert(&r);
                tree.insert(&r);
            }
            for _ in 0..12 {
                let pat = g.vec_u32_nonempty(alphabet, 12);
                prop::require_eq(
                    trie.contains(&pat),
                    tree.contains(&pat),
                    "containment agreement",
                )?;
            }
            let ctx = g.vec_u32_nonempty(alphabet, 12);
            let (tree_mlen, _) = tree.longest_suffix_match(&ctx, 8);
            prop::require_eq(trie.match_len(&ctx, 8), tree_mlen, "longest-suffix agreement")?;
            Ok(())
        });
    }
}

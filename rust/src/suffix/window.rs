//! Sliding-window drafter index (§4.1.2 "Sliding window selection tree").
//!
//! Policy drift makes old rollouts less predictive (Fig. 2), so the drafter
//! is built from a sliding window of recent trajectories. Historically this
//! was one counting suffix-trie *bucket per epoch* (one full trie walk per
//! bucket per draft); the production representation is a **fused
//! epoch-tagged trie**: one [`crate::suffix::core::ArenaTrie`] per shard
//! whose [`CountStore`] keeps a per-epoch count slot table per node.
//!
//! # Fused layout (every window size, including `window_all`)
//!
//! One arena trie holds the union of all live epochs' paths. Each node owns
//! `cap` count slots in a flat side table; an insert at epoch `e` bumps
//! slot `e % cap`, tagging it with `e` and lazily zeroing whatever stale
//! epoch the slot held before (live epochs span at most `cap` consecutive
//! values, so live tags never collide). For a bounded window, `cap =
//! window` and rolling the epoch is O(1): slots whose tag falls out of the
//! window are simply no longer live — whole-epoch eviction without touching
//! a single node (a periodic compaction sweep reclaims dead paths once they
//! dominate the arena, rebuilding suffix links in the same pass). For the
//! unbounded `window_all` ablation (window = 0) the slot table is
//! **growable**: `cap` starts small and re-strides (doubling) whenever the
//! live epoch span outgrows it, so the same fused trie covers the
//! no-eviction case too and the per-epoch bucket ring is gone from
//! production entirely (it survives only as the executable specification
//! inside the property tests below).
//!
//! Memory model of `window_all`: the dense slot rows cost
//! O(nodes × live-epoch-span), so a run spanning E epochs pays ~E slots
//! per node and scans them on liveness probes. That is the honest price of
//! the no-eviction *ablation* — the configuration the paper measures
//! precisely to show it loses — and it trades the old bucket ring's
//! one-walk-per-epoch query cost for wider rows. Production windows are
//! small constants (4–32), where the dense row IS the compact
//! representation; if `window_all` ever needs to scale past hundreds of
//! epochs, swap `EpochStore`'s dense rows for sparse per-node
//! (epoch, count) lists (ROADMAP item) — the `CountStore` seam makes that
//! a one-file change.
//!
//! A draft call probes ONE structure: a single O(m) suffix-link pass finds
//! the deepest live match, then the match node's suffix-link chain (depths
//! m, m−1, …, 1 — no re-walks) yields each live epoch's deepest match from
//! the visited nodes' slots. Candidates are ranked by the same
//! `match_len · age_discount^age` rule as the old bucket ring — identical
//! drafts (property-tested), window-independent probe structure.
//!
//! Eviction is by epoch *distance* (`newest − e < window`); with the
//! consecutive epoch advances RL training produces this is exactly the old
//! keep-the-last-`window`-buckets behavior.
//!
//! Late arrivals (a rollout from an already-sealed epoch) are indexed under
//! their TRUE epoch so they age and evict with their cohort; arrivals from
//! epochs already outside the window are dropped (Fig. 2's drift argument).

use std::collections::VecDeque;

use crate::suffix::core::{ArenaTrie, CountStore};
use crate::tokens::{Epoch, TokenId};

/// One candidate draft from one epoch.
#[derive(Debug, Clone)]
pub struct WindowDraft {
    pub tokens: Vec<TokenId>,
    pub confidence: Vec<f32>,
    pub match_len: usize,
    pub epoch: Epoch,
    pub score: f64,
}

#[derive(Debug, Clone)]
pub struct WindowedIndex {
    /// Window size in epochs; 0 = unbounded ("window_all" in Fig. 7).
    pub window: usize,
    /// Multiplicative per-epoch age discount applied to match length when
    /// ranking candidate drafts across epochs.
    pub age_discount: f64,
    fused: FusedEpochTrie,
}

impl WindowedIndex {
    pub fn new(window: usize, max_depth: usize) -> Self {
        WindowedIndex {
            window,
            age_discount: 0.85,
            fused: FusedEpochTrie::new(window, max_depth),
        }
    }

    /// Number of distinct live epochs currently indexed.
    pub fn bucket_count(&self) -> usize {
        self.fused.live.len()
    }

    pub fn tokens_indexed(&self) -> usize {
        self.fused.live_tokens.iter().sum()
    }

    pub fn newest_epoch(&self) -> Option<Epoch> {
        self.fused.newest
    }

    /// Insert a rollout produced at `epoch`. Epochs are expected to be
    /// non-decreasing; a late arrival is indexed under its true epoch while
    /// it is still inside the window and dropped once it is not.
    pub fn insert(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        self.fused.insert_rollout(epoch, tokens);
    }

    /// Start a new (possibly empty) epoch and evict stale ones.
    pub fn roll_epoch(&mut self, epoch: Epoch) {
        self.fused.roll_epoch(epoch);
    }

    /// Best draft across the window. Candidates are ranked by
    /// `match_len · age_discount^age` (ties → newer epoch), so a much longer
    /// match in an older epoch can still win, but recency is preferred.
    pub fn draft(&self, context: &[TokenId], max_match: usize, budget: usize) -> Option<WindowDraft> {
        if budget == 0 {
            return None;
        }
        self.fused.draft(context, max_match, budget, self.age_discount)
    }

    /// Number of independent index structures a draft call probes (for
    /// latency figures): always 1 since the fused trie covers every window
    /// size, `window_all` included — the unbounded case pays instead in
    /// per-node slot-scan width (`cap` grows with the live epoch span).
    pub fn probe_cost(&self) -> usize {
        1
    }

    pub fn approx_bytes(&self) -> usize {
        self.fused.trie.approx_bytes()
    }

    /// Trie nodes currently allocated (diagnostics; bounded by compaction
    /// for windowed shards).
    pub fn node_count(&self) -> usize {
        self.fused.trie.node_count()
    }
}

// ---------------------------------------------------------------------------
// Epoch-slot CountStore
// ---------------------------------------------------------------------------

/// One per-epoch count slot of a node's slot row.
#[derive(Debug, Clone, Copy, Default)]
struct Slot {
    epoch: Epoch,
    count: u64,
}

/// Per-node epoch-tagged count rows: node `i` owns
/// `slots[i*cap .. (i+1)*cap]`, slot index `epoch % cap`.
#[derive(Debug, Clone)]
struct EpochStore {
    slots: Vec<Slot>,
    /// Slots per node. Fixed at `window` for bounded windows; grows (with a
    /// re-stride) as the live epoch span grows when `window == 0`.
    cap: usize,
    /// 0 = unbounded (`window_all`).
    window: usize,
    n_nodes: usize,
}

/// Query-time epoch visibility.
#[derive(Debug, Clone, Copy)]
enum EpochFilter {
    /// Visible if ANY live epoch (relative to `newest`) holds a count.
    AnyLive { newest: Epoch },
    /// Visible under exactly this epoch.
    Exact { epoch: Epoch },
}

impl EpochStore {
    fn new(window: usize) -> Self {
        EpochStore {
            slots: Vec::new(),
            cap: if window == 0 { 4 } else { window },
            window,
            n_nodes: 0,
        }
    }

    #[inline]
    fn in_window(&self, newest: Epoch, epoch: Epoch) -> bool {
        epoch <= newest && (self.window == 0 || (newest - epoch) < self.window as Epoch)
    }

    /// Count this node holds for exactly `epoch` (0 if the slot was
    /// recycled by a colliding epoch).
    #[inline]
    fn epoch_count(&self, node: usize, epoch: Epoch) -> u64 {
        let s = &self.slots[node * self.cap + (epoch as usize % self.cap)];
        if s.epoch == epoch {
            s.count
        } else {
            0
        }
    }

    /// Visit the live (epoch, count) pairs of one node's slot row.
    fn for_each_live<F: FnMut(Epoch, u64)>(&self, node: usize, newest: Epoch, mut f: F) {
        let base = node * self.cap;
        for s in &self.slots[base..base + self.cap] {
            if s.count > 0 && self.in_window(newest, s.epoch) {
                f(s.epoch, s.count);
            }
        }
    }

    /// Re-stride every node's slot row to `new_cap` (a multiple of `cap`,
    /// so no two occupied slots collide in the new layout). Only the
    /// unbounded window grows.
    fn grow_to(&mut self, new_cap: usize) {
        debug_assert!(new_cap > self.cap && new_cap % self.cap == 0);
        let mut new_slots = vec![Slot::default(); self.n_nodes * new_cap];
        for node in 0..self.n_nodes {
            for s in &self.slots[node * self.cap..(node + 1) * self.cap] {
                if s.count > 0 {
                    let t = &mut new_slots[node * new_cap + (s.epoch as usize % new_cap)];
                    debug_assert_eq!(t.count, 0, "re-stride collision");
                    *t = *s;
                }
            }
        }
        self.slots = new_slots;
        self.cap = new_cap;
    }
}

impl CountStore for EpochStore {
    type Tag = Epoch;
    type Filter = EpochFilter;

    fn new_empty(&self) -> Self {
        EpochStore {
            slots: Vec::new(),
            cap: self.cap,
            window: self.window,
            n_nodes: 0,
        }
    }

    fn push_node(&mut self) {
        self.slots.extend(std::iter::repeat(Slot::default()).take(self.cap));
        self.n_nodes += 1;
    }

    /// Bump the node's epoch slot, lazily reclaiming a stale tag.
    #[inline]
    fn bump(&mut self, node: usize, epoch: Epoch) {
        let s = &mut self.slots[node * self.cap + (epoch as usize % self.cap)];
        if s.epoch != epoch {
            s.epoch = epoch;
            s.count = 0;
        }
        s.count += 1;
    }

    fn weight(&self, node: usize, filter: EpochFilter) -> u64 {
        match filter {
            EpochFilter::Exact { epoch } => self.epoch_count(node, epoch),
            EpochFilter::AnyLive { newest } => {
                let base = node * self.cap;
                let live = self.slots[base..base + self.cap]
                    .iter()
                    .any(|s| s.count > 0 && self.in_window(newest, s.epoch));
                live as u64
            }
        }
    }

    fn copy_node_from(&mut self, src: &Self, old: usize) {
        debug_assert_eq!(self.cap, src.cap);
        let base = old * src.cap;
        self.slots.extend_from_slice(&src.slots[base..base + src.cap]);
        self.n_nodes += 1;
    }

    fn heap_bytes(&self) -> usize {
        self.slots.capacity() * std::mem::size_of::<Slot>()
    }
}

// ---------------------------------------------------------------------------
// Fused epoch-tagged trie (every window size)
// ---------------------------------------------------------------------------

/// Don't bother compacting tiny arenas.
const COMPACT_MIN_NODES: usize = 1024;

#[derive(Debug, Clone)]
struct FusedEpochTrie {
    trie: ArenaTrie<EpochStore>,
    /// 0 = unbounded.
    window: usize,
    newest: Option<Epoch>,
    /// Distinct live epochs, ascending (≤ `window` entries when bounded).
    live: VecDeque<Epoch>,
    /// Tokens indexed per live epoch (parallel to `live`).
    live_tokens: VecDeque<usize>,
    /// Arena size right after the last compaction (growth trigger).
    last_compact_nodes: usize,
}

impl FusedEpochTrie {
    fn new(window: usize, max_depth: usize) -> Self {
        FusedEpochTrie {
            trie: ArenaTrie::new(max_depth.max(2), EpochStore::new(window)),
            window,
            newest: None,
            live: VecDeque::new(),
            live_tokens: VecDeque::new(),
            last_compact_nodes: 1,
        }
    }

    #[inline]
    fn in_window(&self, newest: Epoch, epoch: Epoch) -> bool {
        self.trie.store().in_window(newest, epoch)
    }

    /// Unbounded windows: grow the slot stride whenever the live epoch span
    /// outgrows it, so live epochs never collide in `epoch % cap`.
    fn ensure_cap(&mut self) {
        if self.window != 0 {
            return;
        }
        let (Some(&front), Some(&back)) = (self.live.front(), self.live.back()) else {
            return;
        };
        let span = (back - front) as usize + 1;
        let cap = self.trie.store().cap;
        if span > cap {
            let mut new_cap = cap;
            while new_cap < span {
                new_cap *= 2;
            }
            self.trie.store_mut().grow_to(new_cap);
        }
    }

    /// Advance `newest` to `epoch` (≥ current), registering it as live and
    /// lazily dropping epochs that fell out of the window. O(window).
    fn advance(&mut self, epoch: Epoch) {
        if self.live.back() != Some(&epoch) {
            self.live.push_back(epoch);
            self.live_tokens.push_back(0);
        }
        self.newest = Some(epoch);
        while let Some(&front) = self.live.front() {
            if self.in_window(epoch, front) {
                break;
            }
            self.live.pop_front();
            self.live_tokens.pop_front();
        }
        self.ensure_cap();
        // Epochs can advance via roll_epoch OR direct inserts at a newer
        // epoch; reclaim dead paths on either path (the guard inside is two
        // integer compares, so this is free on the hot path).
        self.maybe_compact();
    }

    fn roll_epoch(&mut self, epoch: Epoch) {
        if self.newest.map(|n| n < epoch).unwrap_or(true) {
            self.advance(epoch);
        }
    }

    /// Dead-epoch paths stay in the arena after (lazy) eviction; once the
    /// arena has doubled since the last sweep, rebuild it from the
    /// live-reachable nodes only. A node is live iff any slot holds a
    /// live-epoch count, and liveness propagates to ancestors (counts are
    /// bumped along whole paths), so the core's keep-live-children DFS
    /// reconstructs exactly the reachable live trie and re-derives every
    /// suffix link. Counts are copied verbatim, so drafts are unchanged.
    /// Amortized O(1) per insert; bounds memory at ~2× the live working
    /// set. Unbounded windows never evict, hence never compact.
    fn maybe_compact(&mut self) {
        if self.window == 0 {
            return;
        }
        let n = self.trie.node_count();
        if n < COMPACT_MIN_NODES || n < self.last_compact_nodes.saturating_mul(2) {
            return;
        }
        let Some(newest) = self.newest else { return };
        let filter = EpochFilter::AnyLive { newest };
        self.trie.compact(|store, node| store.weight(node, filter) > 0);
        self.last_compact_nodes = self.trie.node_count().max(1);
    }

    fn insert_rollout(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        match self.newest {
            Some(n) if epoch < n => {
                // Late arrival from a sealed epoch: keep its TRUE epoch tag
                // (it must age and evict with its cohort) or drop it when
                // the cohort is already outside the window.
                if !self.in_window(n, epoch) {
                    return;
                }
                if !self.live.contains(&epoch) {
                    let pos = self
                        .live
                        .iter()
                        .position(|&e| e > epoch)
                        .unwrap_or(self.live.len());
                    self.live.insert(pos, epoch);
                    self.live_tokens.insert(pos, 0);
                }
                self.ensure_cap();
            }
            _ => self.advance(epoch),
        }
        if let Some(pos) = self.live.iter().position(|&e| e == epoch) {
            self.live_tokens[pos] += tokens.len();
        }
        self.trie.insert_suffixes(tokens, epoch);
    }

    fn draft(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
        age_discount: f64,
    ) -> Option<WindowDraft> {
        let newest = self.newest?;
        // 1. Deepest match over ANY live epoch — one O(m) suffix-link pass.
        let (take_max, node) =
            self.trie
                .deepest_suffix(context, max_match, EpochFilter::AnyLive { newest });
        if take_max == 0 {
            return None;
        }
        // 2. Per-epoch match depths: the suffix-link chain from the match
        //    node visits exactly the matched suffixes of lengths take_max,
        //    take_max−1, …, 1 (no re-walks); record each live epoch the
        //    first (deepest) time it appears in a visited node's slot row.
        let mut cands: Vec<(f64, Epoch, usize, usize)> = Vec::new(); // (score, epoch, mlen, node)
        let mut n = node;
        let mut take = take_max;
        loop {
            self.trie.store().for_each_live(n, newest, |epoch, _count| {
                if !cands.iter().any(|&(_, e, _, _)| e == epoch) {
                    let age = (newest - epoch) as f64;
                    let score = take as f64 * age_discount.powf(age);
                    cands.push((score, epoch, take, n));
                }
            });
            if cands.len() == self.live.len() || take == 1 {
                break; // every live epoch accounted for, or chain exhausted
            }
            n = self.trie.suffix_link(n);
            take -= 1;
        }
        // 3. Same ranking as the old bucket ring: best score, ties to the
        //    newer epoch, skipping candidates whose greedy walk is empty.
        cands.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.1.cmp(&a.1))
        });
        for &(score, epoch, mlen, node) in &cands {
            let (tokens, confidence) =
                self.trie.greedy_walk(node, budget, EpochFilter::Exact { epoch });
            if !tokens.is_empty() {
                return Some(WindowDraft {
                    tokens,
                    confidence,
                    match_len: mlen,
                    epoch,
                    score,
                });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::trie::SuffixTrieIndex;
    use crate::util::prop;

    // -----------------------------------------------------------------
    // The pre-fusion per-epoch bucket ring, kept ONLY as the executable
    // specification the fused trie is property-tested against. One full
    // counting-trie walk per bucket per draft — the cost the fused trie
    // removed — but trivially correct.
    // -----------------------------------------------------------------
    #[derive(Debug, Clone)]
    struct BucketRingRef {
        /// Ascending epoch order; newest at the back.
        buckets: VecDeque<(Epoch, SuffixTrieIndex)>,
        window: usize,
        max_depth: usize,
    }

    impl BucketRingRef {
        fn new(window: usize, max_depth: usize) -> Self {
            BucketRingRef {
                buckets: VecDeque::new(),
                window,
                max_depth,
            }
        }

        fn tokens_indexed(&self) -> usize {
            self.buckets.iter().map(|(_, b)| b.tokens_indexed()).sum()
        }

        fn insert(&mut self, epoch: Epoch, tokens: &[TokenId]) {
            match self.buckets.back().map(|(e, _)| *e) {
                Some(e) if e == epoch => {
                    self.buckets.back_mut().expect("nonempty").1.insert(tokens);
                }
                Some(e) if e > epoch => {
                    // Late arrival: index under its TRUE epoch; eviction
                    // drops it when it is already outside the window.
                    if let Some((_, b)) = self.buckets.iter_mut().find(|(e2, _)| *e2 == epoch) {
                        b.insert(tokens);
                    } else {
                        let mut bucket = SuffixTrieIndex::new(self.max_depth);
                        bucket.insert(tokens);
                        let pos = self
                            .buckets
                            .iter()
                            .position(|(e2, _)| *e2 > epoch)
                            .unwrap_or(self.buckets.len());
                        self.buckets.insert(pos, (epoch, bucket));
                        self.evict();
                    }
                }
                _ => {
                    let mut bucket = SuffixTrieIndex::new(self.max_depth);
                    bucket.insert(tokens);
                    self.buckets.push_back((epoch, bucket));
                    self.evict();
                }
            }
        }

        fn roll_epoch(&mut self, epoch: Epoch) {
            if self.buckets.back().map(|(e, _)| *e < epoch).unwrap_or(true) {
                self.buckets
                    .push_back((epoch, SuffixTrieIndex::new(self.max_depth)));
                self.evict();
            }
        }

        fn evict(&mut self) {
            if self.window == 0 {
                return;
            }
            while self.buckets.len() > self.window {
                self.buckets.pop_front();
            }
        }

        fn draft(
            &self,
            context: &[TokenId],
            max_match: usize,
            budget: usize,
            age_discount: f64,
        ) -> Option<WindowDraft> {
            let newest = self.buckets.back().map(|(e, _)| *e)?;
            let mut best: Option<WindowDraft> = None;
            for (epoch, bucket) in self.buckets.iter().rev() {
                let mlen = bucket.match_len(context, max_match);
                if mlen == 0 {
                    continue;
                }
                let age = (newest - *epoch) as f64;
                let score = mlen as f64 * age_discount.powf(age);
                let better = match &best {
                    None => true,
                    Some(b) => score > b.score,
                };
                if better {
                    let (tokens, confidence) = bucket.draft_weighted(context, max_match, budget);
                    if !tokens.is_empty() {
                        best = Some(WindowDraft {
                            tokens,
                            confidence,
                            match_len: mlen,
                            epoch: *epoch,
                            score,
                        });
                    }
                }
            }
            best
        }
    }

    #[test]
    fn window_evicts_old_epochs() {
        let mut w = WindowedIndex::new(2, 8);
        w.insert(0, &[1, 2, 3]);
        w.insert(1, &[4, 5, 6]);
        w.insert(2, &[7, 8, 9]);
        assert_eq!(w.bucket_count(), 2);
        // Epoch-0 content is gone.
        assert!(w.draft(&[1, 2], 4, 2).is_none());
        // Epoch-2 content matches.
        let d = w.draft(&[7, 8], 4, 2).unwrap();
        assert_eq!(d.tokens, vec![9]);
        assert_eq!(d.epoch, 2);
    }

    #[test]
    fn unbounded_window_keeps_everything() {
        let mut w = WindowedIndex::new(0, 8);
        for e in 0..20 {
            w.insert(e, &[e + 100, e + 101, e + 102]);
        }
        assert_eq!(w.bucket_count(), 20);
        // Oldest and newest epoch content both still draftable — the
        // growable epoch-tag table must have re-strided past 4 epochs.
        assert!(w.draft(&[100, 101], 4, 1).is_some());
        assert!(w.draft(&[119, 120], 4, 1).is_some());
        assert_eq!(w.probe_cost(), 1, "window_all runs on the fused trie");
    }

    #[test]
    fn recency_preferred_on_equal_match() {
        let mut w = WindowedIndex::new(0, 8);
        w.insert(0, &[1, 2, 30]); // old continuation: 30
        w.insert(5, &[1, 2, 40]); // new continuation: 40
        let d = w.draft(&[1, 2], 4, 1).unwrap();
        assert_eq!(d.epoch, 5);
        assert_eq!(d.tokens, vec![40]);
    }

    #[test]
    fn much_longer_old_match_can_win() {
        let mut w = WindowedIndex::new(0, 16);
        w.insert(0, &[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]); // long pattern, old epoch
        w.insert(1, &[8, 50]); // short match in new epoch
        let d = w.draft(&[1, 2, 3, 4, 5, 6, 7, 8], 8, 2).unwrap();
        // Old bucket matches 8 tokens (score 8·0.85=6.8) vs new 1 (score 1).
        assert_eq!(d.epoch, 0);
        assert_eq!(d.tokens, vec![60, 61]);
    }

    #[test]
    fn fused_recency_and_long_match_ranking() {
        // The two ranking behaviors above, on a bounded window.
        let mut w = WindowedIndex::new(8, 16);
        w.insert(0, &[1, 2, 30]);
        w.insert(5, &[1, 2, 40]);
        let d = w.draft(&[1, 2], 4, 1).unwrap();
        assert_eq!((d.epoch, d.tokens.clone()), (5, vec![40]));

        let mut w = WindowedIndex::new(8, 16);
        w.insert(0, &[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]);
        w.insert(1, &[8, 50]);
        let d = w.draft(&[1, 2, 3, 4, 5, 6, 7, 8], 8, 2).unwrap();
        assert_eq!((d.epoch, d.tokens.clone()), (0, vec![60, 61]));
    }

    #[test]
    fn roll_epoch_creates_and_evicts() {
        let mut w = WindowedIndex::new(3, 8);
        for e in 0..10 {
            w.roll_epoch(e);
        }
        assert_eq!(w.bucket_count(), 3);
        assert_eq!(w.newest_epoch(), Some(9));
    }

    #[test]
    fn late_arrival_tagged_with_true_epoch() {
        // Regression for the old promote-to-newest-bucket bug: a rollout
        // from a sealed epoch must be indexed under ITS epoch, not smuggled
        // into the newest one.
        let mut w = WindowedIndex::new(4, 8);
        w.insert(3, &[1, 2]);
        w.insert(1, &[5, 6]); // late: epoch 1 after epoch 3 opened
        assert_eq!(w.bucket_count(), 2);
        let d = w.draft(&[5], 4, 1).unwrap();
        assert_eq!(d.epoch, 1);
        assert_eq!(d.tokens, vec![6]);
    }

    #[test]
    fn late_arrival_evicts_with_its_cohort() {
        let mut w = WindowedIndex::new(2, 8);
        w.insert(0, &[1, 2, 3]);
        w.roll_epoch(1);
        w.insert(1, &[4, 5, 6]);
        // Late arrival from epoch 0: visible now...
        w.insert(0, &[7, 8, 9]);
        assert_eq!(w.draft(&[7, 8], 4, 1).unwrap().epoch, 0);
        // ...but it ages with epoch 0 and evicts when the window moves on —
        // the old bug kept it alive inside the newest bucket.
        w.roll_epoch(2);
        assert!(w.draft(&[7, 8], 4, 1).is_none());
        // An arrival already outside the window is dropped outright.
        w.insert(0, &[9, 9, 9]);
        assert!(w.draft(&[9, 9], 4, 1).is_none());
        assert_eq!(w.newest_epoch(), Some(2));
    }

    #[test]
    fn fused_arena_compacts_after_eviction() {
        // 300 epochs of disjoint content with window 2: without compaction
        // the arena would retain every dead epoch's paths forever (~90k
        // nodes here); the sweep keeps it near the live working set.
        let mut w = WindowedIndex::new(2, 8);
        for e in 0..300u32 {
            w.roll_epoch(e);
            let r: Vec<u32> = (0..40).map(|i| e * 100 + (i % 37)).collect();
            w.insert(e, &r);
        }
        let newest_ctx = [299 * 100, 299 * 100 + 1];
        assert!(w.draft(&newest_ctx, 4, 2).is_some(), "live content drafts");
        assert!(w.draft(&[100, 101], 4, 2).is_none(), "dead content gone");
        assert!(
            w.node_count() < 5_000,
            "dead epochs must be compacted away, arena holds {} nodes",
            w.node_count()
        );
    }

    #[test]
    fn window_all_matches_large_window_on_identical_streams() {
        // Regression for the old split-representation bug: window = 0 used
        // a bucket ring while window ≥ 1 used the fused trie, and their
        // `roll_epoch` bookkeeping could diverge. Both now run fused; an
        // unbounded window and a window larger than the whole run must
        // behave identically on the same stream (inserts, rolls, late
        // arrivals) — same drafts, same live-epoch accounting.
        let mut all = WindowedIndex::new(0, 10);
        let mut big = WindowedIndex::new(64, 10);
        let mut rng = crate::util::rng::Rng::seed_from_u64(7);
        let mut epoch: Epoch = 0;
        for step in 0..120 {
            match step % 5 {
                0 => {
                    epoch += 1;
                    all.roll_epoch(epoch);
                    big.roll_epoch(epoch);
                }
                1 if epoch > 0 => {
                    let r: Vec<u32> = (0..12).map(|_| rng.below(6) as u32).collect();
                    all.insert(epoch - 1, &r); // late arrival
                    big.insert(epoch - 1, &r);
                }
                _ => {
                    let r: Vec<u32> = (0..15).map(|_| rng.below(6) as u32).collect();
                    all.insert(epoch, &r);
                    big.insert(epoch, &r);
                }
            }
            assert_eq!(all.bucket_count(), big.bucket_count(), "step {step}");
            assert_eq!(all.tokens_indexed(), big.tokens_indexed(), "step {step}");
            assert_eq!(all.newest_epoch(), big.newest_epoch(), "step {step}");
            let ctx: Vec<u32> = (0..8).map(|_| rng.below(6) as u32).collect();
            let (a, b) = (all.draft(&ctx, 6, 4), big.draft(&ctx, 6, 4));
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.tokens, y.tokens, "step {step}");
                    assert_eq!(x.epoch, y.epoch, "step {step}");
                    assert_eq!(x.match_len, y.match_len, "step {step}");
                }
                (a, b) => panic!("draft presence diverged at step {step}: {a:?} vs {b:?}"),
            }
        }
        assert!(epoch > 20, "stream must span many epochs");
    }

    #[test]
    fn prop_window_size_never_exceeded() {
        prop::check(64, |g| {
            let win = 1 + g.usize_in(0, 6);
            let mut w = WindowedIndex::new(win, 8);
            let mut epoch = 0;
            for _ in 0..g.usize_in(1, 40) {
                if g.bool() {
                    epoch += 1;
                }
                let r = g.vec_u32_nonempty(8, 20);
                w.insert(epoch, &r);
                prop::require(w.bucket_count() <= win, "window bound respected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_nonempty_implies_match() {
        prop::check(64, |g| {
            let mut w = WindowedIndex::new(0, 10);
            for e in 0..g.usize_in(1, 5) as u32 {
                w.insert(e, &g.vec_u32_nonempty(5, 30));
            }
            let ctx = g.vec_u32_nonempty(5, 10);
            if let Some(d) = w.draft(&ctx, 6, 4) {
                prop::require(d.match_len >= 1, "match_len >= 1")?;
                prop::require(!d.tokens.is_empty(), "tokens nonempty")?;
                prop::require(d.tokens.len() <= 4, "budget respected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fused_matches_bucket_reference() {
        // THE equivalence anchor: over random consecutive-epoch histories
        // (rolls, inserts, late arrivals) the fused epoch-slot trie must
        // produce byte-identical drafts to the per-epoch bucket ring — for
        // bounded windows AND the unbounded window_all path (win == 0).
        prop::check(96, |g| {
            let win = g.usize_in(0, 6); // 0 = window_all
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut fused = WindowedIndex::new(win, 10);
            let mut reference = BucketRingRef::new(win, 10);
            let mut epoch: Epoch = 0;
            for _ in 0..g.usize_in(1, 30) {
                match g.usize_in(0, 3) {
                    0 => {
                        epoch += 1;
                        fused.roll_epoch(epoch);
                        reference.roll_epoch(epoch);
                    }
                    1 if epoch > 0 => {
                        // Late arrival from the previous epoch.
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        fused.insert(epoch - 1, &r);
                        reference.insert(epoch - 1, &r);
                    }
                    _ => {
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        fused.insert(epoch, &r);
                        reference.insert(epoch, &r);
                    }
                }
                prop::require_eq(
                    fused.bucket_count(),
                    reference.buckets.len(),
                    "live epoch count",
                )?;
                prop::require_eq(
                    fused.tokens_indexed(),
                    reference.tokens_indexed(),
                    "tokens indexed",
                )?;
                let ctx = g.vec_u32_nonempty(alphabet, 12);
                let budget = 1 + g.usize_in(0, 5);
                let a = fused.draft(&ctx, 6, budget);
                let b = reference.draft(&ctx, 6, budget, fused.age_discount);
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        prop::require_eq(x.tokens, y.tokens, "draft tokens")?;
                        prop::require_eq(x.epoch, y.epoch, "draft epoch")?;
                        prop::require_eq(x.match_len, y.match_len, "draft match_len")?;
                        prop::require_eq(x.confidence, y.confidence, "draft confidence")?;
                        prop::require((x.score - y.score).abs() < 1e-9, "draft score")?;
                    }
                    (a, b) => {
                        prop::require(
                            false,
                            &format!("draft presence diverged: fused={:?} ref={:?}", a, b),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }
}

//! Sliding-window drafter index (§4.1.2 "Sliding window selection tree").
//!
//! Policy drift makes old rollouts less predictive (Fig. 2), so the drafter
//! is built from a sliding window of recent trajectories. Historically this
//! was one counting suffix-trie *bucket per epoch* (one full trie walk per
//! bucket per draft); the production representation is a **fused
//! epoch-tagged trie**: one [`crate::suffix::core::ArenaTrie`] per shard
//! (path-compressed, labels interned in the shared segment pool) whose
//! [`CountStore`] keeps per-epoch counts per node.
//!
//! # Fused layout (every window size, including `window_all`)
//!
//! One arena trie holds the union of all live epochs' paths. Per-epoch
//! counts come in two row layouts behind the same `CountStore`:
//!
//! * **Bounded windows** (`window ≥ 1`): a dense ring of `window` slots per
//!   node, slot `epoch % window`, each tagged with the epoch it last
//!   counted (live epochs span at most `window` consecutive values, so live
//!   tags never collide). Rolling the epoch is O(1): slots whose tag falls
//!   out of the window are simply no longer live — whole-epoch eviction
//!   without touching a single node; a periodic compaction sweep reclaims
//!   dead paths (and their pool segments) once they dominate the arena.
//! * **`window_all`** (`window == 0`, the no-eviction ablation): a sparse
//!   per-node `(epoch, count)` list, kept sorted by epoch. Memory is linear
//!   in *distinct (node, epoch) pairs* — i.e. linear in indexed tokens —
//!   instead of the old dense O(nodes × live-epoch-span) slot rows that
//!   re-strided (doubling) as the run aged. Bumps are O(1) amortized
//!   (epochs arrive in nondecreasing order, so the append fast-path hits),
//!   liveness is an is-empty check, and exact-epoch reads binary-search.
//!   These tries never evict, hence never compact — exact suffix links
//!   come from the core's insert-count-triggered
//!   `rebuild_suffix_links` refresh instead, so the unbounded ablation's
//!   O(m) match pass re-descends exactly one edge per fallback, like the
//!   bounded path after a compaction sweep.
//!
//! A draft call probes ONE structure: a single O(m) compressed-edge
//! suffix-link pass finds the deepest live match position, then the
//! suffix-chain walk (positions of depths m, m−1, …, 1 — skip/count
//! re-descents, no root re-walks) yields each live epoch's deepest match
//! from the visited rows. Candidates are ranked by the same
//! `match_len · age_discount^age` rule as the old bucket ring — identical
//! drafts (property-tested), window-independent probe structure.
//!
//! Eviction is by epoch *distance* (`newest − e < window`); with the
//! consecutive epoch advances RL training produces this is exactly the old
//! keep-the-last-`window`-buckets behavior.
//!
//! Late arrivals (a rollout from an already-sealed epoch) are indexed under
//! their TRUE epoch so they age and evict with their cohort; arrivals from
//! epochs already outside the window are dropped (Fig. 2's drift argument).

use std::collections::VecDeque;
use std::sync::Arc;

use crate::store::wire::{Reader, StoreError, Writer};
use crate::suffix::core::{
    ArenaTrie, CountStore, PoolStats, SharedPool, SnapshotStats, TriePos, TrieSnapshot,
};
use crate::tokens::{Epoch, TokenId};
use crate::util::cow::CowVec;

/// One candidate draft from one epoch.
#[derive(Debug, Clone)]
pub struct WindowDraft {
    pub tokens: Vec<TokenId>,
    pub confidence: Vec<f32>,
    pub match_len: usize,
    pub epoch: Epoch,
    pub score: f64,
}

#[derive(Debug, Clone)]
pub struct WindowedIndex {
    /// Window size in epochs; 0 = unbounded ("window_all" in Fig. 7).
    pub window: usize,
    /// Multiplicative per-epoch age discount applied to match length when
    /// ranking candidate drafts across epochs. Baked into each published
    /// snapshot — a change takes effect at the next publish boundary.
    pub age_discount: f64,
    fused: FusedEpochTrie,
    /// Cached published read view; invalidated by every mutation so
    /// [`WindowedIndex::publish`] re-snapshots exactly once per
    /// absorb/epoch boundary and is free between them.
    snap: Option<Arc<WindowSnapshot>>,
    /// Distinct snapshots actually published (cache misses) — the
    /// `IndexStats::snapshot_publishes` gauge.
    publishes: u64,
}

impl WindowedIndex {
    pub fn new(window: usize, max_depth: usize) -> Self {
        Self::with_pool(window, max_depth, SharedPool::new())
    }

    /// Index whose edge labels are interned in `pool` — the drafter shares
    /// one pool across every shard so common rollout content is stored once.
    pub fn with_pool(window: usize, max_depth: usize, pool: SharedPool) -> Self {
        WindowedIndex {
            window,
            age_discount: 0.85,
            fused: FusedEpochTrie::new(window, max_depth, pool),
            snap: None,
            publishes: 0,
        }
    }

    /// Number of distinct live epochs currently indexed.
    pub fn bucket_count(&self) -> usize {
        self.fused.live.len()
    }

    pub fn tokens_indexed(&self) -> usize {
        self.fused.live_tokens.iter().sum()
    }

    pub fn newest_epoch(&self) -> Option<Epoch> {
        self.fused.newest
    }

    /// Insert a rollout produced at `epoch`. Epochs are expected to be
    /// non-decreasing; a late arrival is indexed under its true epoch while
    /// it is still inside the window and dropped once it is not.
    pub fn insert(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        self.snap = None;
        self.fused.insert_rollout(epoch, tokens);
    }

    /// Start a new (possibly empty) epoch and evict stale ones.
    pub fn roll_epoch(&mut self, epoch: Epoch) {
        self.snap = None;
        self.fused.roll_epoch(epoch);
    }

    /// Publish (or reuse) the immutable lock-free read view covering every
    /// mutation so far. Cheap between mutations (an `Arc` clone of the
    /// cached view); after an `insert`/`roll_epoch` the first call
    /// re-publishes — O(chunk-table) clones of the arena, count rows, and
    /// pool slots, with size gauges precomputed onto the snapshot.
    pub fn publish(&mut self) -> Arc<WindowSnapshot> {
        if let Some(s) = &self.snap {
            return Arc::clone(s);
        }
        self.publishes += 1;
        let s = Arc::new(WindowSnapshot {
            trie: self.fused.trie.publish(),
            newest: self.fused.newest,
            live: self.fused.live.iter().copied().collect(),
            age_discount: self.age_discount,
        });
        self.snap = Some(Arc::clone(&s));
        s
    }

    /// Distinct snapshots published so far (cache hits excluded).
    pub fn snapshot_publishes(&self) -> u64 {
        self.publishes
    }

    /// Best draft across the window. Candidates are ranked by
    /// `match_len · age_discount^age` (ties → newer epoch), so a much longer
    /// match in an older epoch can still win, but recency is preferred.
    pub fn draft(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> Option<WindowDraft> {
        if budget == 0 {
            return None;
        }
        self.fused.draft(context, max_match, budget, self.age_discount)
    }

    /// Number of independent index structures a draft call probes (for
    /// latency figures): always 1 — the fused trie covers every window
    /// size, `window_all` included (its sparse rows keep even the unbounded
    /// path linear in indexed tokens).
    pub fn probe_cost(&self) -> usize {
        1
    }

    pub fn approx_bytes(&self) -> usize {
        self.fused.trie.approx_bytes()
    }

    /// Explicit trie nodes currently allocated (diagnostics; bounded by
    /// compaction for windowed shards). With path compression this counts
    /// branching/termination points, not indexed token positions — see
    /// [`WindowedIndex::token_positions`].
    pub fn node_count(&self) -> usize {
        self.fused.trie.node_count()
    }

    /// What a one-node-per-token trie would allocate for the same content
    /// (the compression-ratio denominator in the telemetry gauges).
    pub fn token_positions(&self) -> usize {
        self.fused.trie.token_positions()
    }

    /// Live/dead byte accounting of the (possibly shared) segment pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.fused.trie.pool_stats()
    }

    /// Exact suffix-link rebuilds this shard's trie has run — compaction
    /// sweeps (bounded windows) plus the insert-count-triggered refresh
    /// that keeps the never-compacting `window_all` path's links exact.
    pub fn link_rebuilds(&self) -> u64 {
        self.fused.trie.link_rebuilds()
    }

    /// Handle to the segment pool backing this index's edge labels.
    pub fn pool(&self) -> SharedPool {
        self.fused.trie.pool()
    }

    /// Serialize the full index — window/ranking config, live-epoch
    /// bookkeeping, and the fused epoch trie — as one `das-store-v1`
    /// source blob (pool saved separately by the owner).
    pub fn save_state(&self, w: &mut Writer) {
        w.str("window");
        w.usize(self.window);
        w.f64(self.age_discount);
        match self.fused.newest {
            Some(e) => {
                w.u8(1);
                w.u32(e);
            }
            None => w.u8(0),
        }
        w.usize(self.fused.live.len());
        for (&e, &t) in self.fused.live.iter().zip(self.fused.live_tokens.iter()) {
            w.u32(e);
            w.usize(t);
        }
        w.usize(self.fused.last_compact_nodes);
        self.fused.trie.save_state(w);
    }

    /// Restore from [`WindowedIndex::save_state`] into this instance, whose
    /// pool must already hold the snapshot's segments (the drafter loads
    /// the pool section first and constructs shards on it). The window size
    /// is part of the format — a snapshot taken under a different window is
    /// a [`StoreError::Mismatch`], not a silent reinterpretation.
    pub fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        r.expect_str("window", "source blob tag")?;
        let window = r.usize()?;
        if window != self.window {
            return Err(StoreError::Mismatch(format!(
                "snapshot window {window} != configured {}",
                self.window
            )));
        }
        let age_discount = r.f64()?;
        let newest = match r.u8()? {
            0 => None,
            1 => Some(r.u32()?),
            t => return Err(StoreError::Corrupt(format!("bad newest flag {t}"))),
        };
        let n_live = r.count(12)?;
        let mut live = VecDeque::with_capacity(n_live);
        let mut live_tokens = VecDeque::with_capacity(n_live);
        let mut prev: Option<Epoch> = None;
        for _ in 0..n_live {
            let e = r.u32()?;
            if prev.map(|p| p >= e).unwrap_or(false) {
                return Err(StoreError::Corrupt("live epochs not ascending".into()));
            }
            prev = Some(e);
            live.push_back(e);
            live_tokens.push_back(r.usize()?);
        }
        let last_compact_nodes = r.usize()?.max(1);
        let trie = ArenaTrie::load_state(r, self.fused.trie.pool())?;
        if trie.store().window != window {
            return Err(StoreError::Corrupt(
                "epoch-store window disagrees with index window".into(),
            ));
        }
        self.age_discount = age_discount;
        self.snap = None;
        self.fused = FusedEpochTrie {
            trie,
            window,
            newest,
            live,
            live_tokens,
            last_compact_nodes,
        };
        Ok(())
    }

    /// Test hook: run the dead-epoch compaction sweep immediately instead
    /// of waiting for the arena-doubling trigger (used by the equivalence
    /// property test to exercise compaction on small arenas).
    #[cfg(test)]
    pub(crate) fn compact_now(&mut self) {
        self.snap = None;
        self.fused.compact_now();
    }
}

/// Immutable published view of one [`WindowedIndex`]: the fused epoch
/// trie's [`TrieSnapshot`] plus the live-epoch bookkeeping the ranking
/// rule needs, frozen exactly as of the [`WindowedIndex::publish`] call.
/// `draft` takes `&self` over `Arc`-shared state and acquires no lock —
/// any number of reader threads draft concurrently while the writer
/// absorbs; they simply see the window as of the last publish boundary
/// (one absorb round of staleness, surfaced by the
/// `draft_snapshot_lag_epochs` gauge).
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    trie: TrieSnapshot<EpochStore>,
    newest: Option<Epoch>,
    /// Distinct live epochs at publish, ascending.
    live: Vec<Epoch>,
    age_discount: f64,
}

impl WindowSnapshot {
    pub fn newest_epoch(&self) -> Option<Epoch> {
        self.newest
    }

    /// Distinct live epochs as of the publish.
    pub fn bucket_count(&self) -> usize {
        self.live.len()
    }

    /// Size gauges precomputed at publish (no arena rescan).
    pub fn stats(&self) -> SnapshotStats {
        self.trie.stats()
    }

    /// Best draft across the window as of the publish — the same
    /// deepest-match → suffix-chain → `match_len · age_discount^age`
    /// pipeline as [`WindowedIndex::draft`], walking the snapshot. Given
    /// the same publish point the two are bit-identical (property-tested).
    pub fn draft(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> Option<WindowDraft> {
        if budget == 0 {
            return None;
        }
        let newest = self.newest?;
        let (take_max, pos) =
            self.trie
                .deepest_suffix(context, max_match, EpochFilter::AnyLive { newest });
        if take_max == 0 {
            return None;
        }
        let matched = &context[context.len() - take_max..];
        let live_total = self.live.len();
        let mut cands: Vec<(f64, Epoch, usize, TriePos)> = Vec::new();
        self.trie.walk_suffix_chain(matched, pos, |take, p| {
            self.trie.store().for_each_live(p.row(), newest, |epoch, _count| {
                if !cands.iter().any(|&(_, e, _, _)| e == epoch) {
                    let age = (newest - epoch) as f64;
                    let score = take as f64 * self.age_discount.powf(age);
                    cands.push((score, epoch, take, p));
                }
            });
            cands.len() < live_total
        });
        cands.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.1.cmp(&a.1))
        });
        for &(score, epoch, mlen, p) in &cands {
            let (tokens, confidence) =
                self.trie.greedy_walk(p, budget, EpochFilter::Exact { epoch });
            if !tokens.is_empty() {
                return Some(WindowDraft {
                    tokens,
                    confidence,
                    match_len: mlen,
                    epoch,
                    score,
                });
            }
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Epoch-count CountStore: dense ring (bounded) / sparse rows (window_all)
// ---------------------------------------------------------------------------

/// One per-epoch count slot of a bounded window's dense ring row.
#[derive(Debug, Clone, Copy, Default)]
struct Slot {
    epoch: Epoch,
    count: u64,
}

/// Per-node epoch row storage. Both layouts answer the same three
/// questions — exact-epoch count, any-live-epoch liveness, live-pair
/// iteration — so the trie walks never know which one is underneath.
#[derive(Debug, Clone)]
enum Rows {
    /// Bounded window: node `i` owns `slots[i*cap .. (i+1)*cap]`, slot
    /// index `epoch % cap`, lazily reclaimed on tag mismatch.
    Dense { slots: CowVec<Slot>, cap: usize },
    /// `window_all`: per-node sorted `(epoch, count)` lists — linear in
    /// distinct (node, epoch) pairs, no re-striding, unbounded epochs.
    /// `entries` counts the total (epoch, count) pairs across all rows so
    /// `heap_bytes` stays O(1) — publication stamps it onto every
    /// snapshot, and a rescan per publish would defeat that.
    Sparse {
        rows: CowVec<Vec<(Epoch, u64)>>,
        entries: usize,
    },
}

#[derive(Debug, Clone)]
struct EpochStore {
    rows: Rows,
    /// 0 = unbounded (`window_all`).
    window: usize,
    n_nodes: usize,
}

/// Query-time epoch visibility.
#[derive(Debug, Clone, Copy)]
enum EpochFilter {
    /// Visible if ANY live epoch (relative to `newest`) holds a count.
    AnyLive { newest: Epoch },
    /// Visible under exactly this epoch.
    Exact { epoch: Epoch },
}

impl EpochStore {
    fn new(window: usize) -> Self {
        EpochStore {
            rows: if window == 0 {
                Rows::Sparse { rows: CowVec::new(), entries: 0 }
            } else {
                Rows::Dense { slots: CowVec::new(), cap: window }
            },
            window,
            n_nodes: 0,
        }
    }

    #[inline]
    fn in_window(&self, newest: Epoch, epoch: Epoch) -> bool {
        epoch <= newest && (self.window == 0 || (newest - epoch) < self.window as Epoch)
    }

    /// Count this node holds for exactly `epoch`.
    #[inline]
    fn epoch_count(&self, node: usize, epoch: Epoch) -> u64 {
        match &self.rows {
            Rows::Dense { slots, cap } => {
                let s = &slots[node * cap + (epoch as usize % cap)];
                if s.epoch == epoch {
                    s.count
                } else {
                    0
                }
            }
            Rows::Sparse { rows, .. } => rows[node]
                .binary_search_by_key(&epoch, |&(e, _)| e)
                .map(|i| rows[node][i].1)
                .unwrap_or(0),
        }
    }

    /// Visit the live (epoch, count) pairs of one node's row.
    fn for_each_live<F: FnMut(Epoch, u64)>(&self, node: usize, newest: Epoch, mut f: F) {
        match &self.rows {
            Rows::Dense { slots, cap } => {
                for i in node * cap..(node + 1) * cap {
                    let s = &slots[i];
                    if s.count > 0 && self.in_window(newest, s.epoch) {
                        f(s.epoch, s.count);
                    }
                }
            }
            Rows::Sparse { rows, .. } => {
                for &(e, c) in &rows[node] {
                    if c > 0 && self.in_window(newest, e) {
                        f(e, c);
                    }
                }
            }
        }
    }
}

impl CountStore for EpochStore {
    type Tag = Epoch;
    type Filter = EpochFilter;

    fn new_empty(&self) -> Self {
        EpochStore {
            rows: match &self.rows {
                Rows::Dense { cap, .. } => Rows::Dense { slots: CowVec::new(), cap: *cap },
                Rows::Sparse { .. } => Rows::Sparse { rows: CowVec::new(), entries: 0 },
            },
            window: self.window,
            n_nodes: 0,
        }
    }

    fn push_node(&mut self) {
        match &mut self.rows {
            Rows::Dense { slots, cap } => {
                for _ in 0..*cap {
                    slots.push(Slot::default());
                }
            }
            Rows::Sparse { rows, .. } => rows.push(Vec::new()),
        }
        self.n_nodes += 1;
    }

    /// Bump the node's epoch count. Dense: lazily reclaim a stale tag.
    /// Sparse: append fast-path (epochs are non-decreasing), binary-search
    /// insert for late arrivals.
    #[inline]
    fn bump(&mut self, node: usize, epoch: Epoch) {
        match &mut self.rows {
            Rows::Dense { slots, cap } => {
                let s = &mut slots[node * *cap + (epoch as usize % *cap)];
                if s.epoch != epoch {
                    s.epoch = epoch;
                    s.count = 0;
                }
                s.count += 1;
            }
            Rows::Sparse { rows, entries } => {
                let row = &mut rows[node];
                match row.last().copied() {
                    Some((e, _)) if e == epoch => {
                        if let Some(last) = row.last_mut() {
                            last.1 += 1;
                        }
                    }
                    Some((e, _)) if e < epoch => {
                        row.push((epoch, 1));
                        *entries += 1;
                    }
                    None => {
                        row.push((epoch, 1));
                        *entries += 1;
                    }
                    // Late arrival behind the row's newest epoch.
                    Some(_) => match row.binary_search_by_key(&epoch, |&(e, _)| e) {
                        Ok(i) => row[i].1 += 1,
                        Err(i) => {
                            row.insert(i, (epoch, 1));
                            *entries += 1;
                        }
                    },
                }
            }
        }
    }

    fn weight(&self, node: usize, filter: EpochFilter) -> u64 {
        match filter {
            EpochFilter::Exact { epoch } => self.epoch_count(node, epoch),
            EpochFilter::AnyLive { newest } => match &self.rows {
                Rows::Dense { slots, cap } => {
                    let live = (node * cap..(node + 1) * cap)
                        .any(|i| slots[i].count > 0 && self.in_window(newest, slots[i].epoch));
                    live as u64
                }
                // window_all never evicts: any recorded epoch is live.
                Rows::Sparse { rows, .. } => (!rows[node].is_empty()) as u64,
            },
        }
    }

    fn copy_node_from(&mut self, src: &Self, old: usize) {
        match (&mut self.rows, &src.rows) {
            (Rows::Dense { slots, cap }, Rows::Dense { slots: ss, cap: sc }) => {
                debug_assert_eq!(*cap, *sc);
                for i in old * sc..(old + 1) * sc {
                    slots.push(ss[i]);
                }
            }
            (Rows::Sparse { rows, entries }, Rows::Sparse { rows: sr, .. }) => {
                let row = sr[old].clone();
                *entries += row.len();
                rows.push(row);
            }
            _ => unreachable!("epoch row layouts never mix"),
        }
        self.n_nodes += 1;
    }

    fn split_node(&mut self, child: usize) {
        match &mut self.rows {
            Rows::Dense { slots, cap } => {
                let base = child * *cap;
                for i in base..base + *cap {
                    let s = slots[i];
                    slots.push(s);
                }
            }
            Rows::Sparse { rows, entries } => {
                let row = rows[child].clone();
                *entries += row.len();
                rows.push(row);
            }
        }
        self.n_nodes += 1;
    }

    fn heap_bytes(&self) -> usize {
        // O(1) on both layouts: publication stamps this onto every
        // snapshot, so it must not rescan the rows.
        match &self.rows {
            Rows::Dense { slots, .. } => slots.heap_bytes(),
            Rows::Sparse { rows, entries } => {
                debug_assert_eq!(
                    *entries,
                    rows.iter().map(|r| r.len()).sum::<usize>(),
                    "sparse epoch-entry counter drifted"
                );
                rows.len() * std::mem::size_of::<Vec<(Epoch, u64)>>()
                    + *entries * std::mem::size_of::<(Epoch, u64)>()
            }
        }
    }

    fn save_rows(&self, w: &mut Writer) {
        w.str("epoch");
        w.usize(self.window);
        w.usize(self.n_nodes);
        match &self.rows {
            Rows::Dense { slots, cap } => {
                w.u8(0);
                w.usize(*cap);
                for s in slots.iter() {
                    w.u32(s.epoch);
                    w.u64(s.count);
                }
            }
            Rows::Sparse { rows, .. } => {
                w.u8(1);
                for row in rows.iter() {
                    w.usize(row.len());
                    for &(e, c) in row {
                        w.u32(e);
                        w.u64(c);
                    }
                }
            }
        }
    }

    fn load_rows(r: &mut Reader<'_>, n_nodes: usize) -> Result<Self, StoreError> {
        r.expect_str("epoch", "count-store tag")?;
        let window = r.usize()?;
        let n = r.usize()?;
        if n != n_nodes {
            return Err(StoreError::Corrupt(format!(
                "epoch rows ({n}) != arena nodes ({n_nodes})"
            )));
        }
        let rows = match r.u8()? {
            0 => {
                let cap = r.usize()?;
                if window == 0 || cap != window {
                    return Err(StoreError::Corrupt(format!(
                        "dense epoch rows with cap {cap} under window {window}"
                    )));
                }
                let total = n
                    .checked_mul(cap)
                    .ok_or_else(|| StoreError::Corrupt("epoch slot count overflow".into()))?;
                if total.saturating_mul(12) > r.remaining() {
                    return Err(StoreError::Truncated);
                }
                let mut slots = CowVec::new();
                for _ in 0..total {
                    slots.push(Slot {
                        epoch: r.u32()?,
                        count: r.u64()?,
                    });
                }
                Rows::Dense { slots, cap }
            }
            1 => {
                if window != 0 {
                    return Err(StoreError::Corrupt(format!(
                        "sparse epoch rows under bounded window {window}"
                    )));
                }
                let mut rows = CowVec::new();
                let mut entries = 0usize;
                for _ in 0..n {
                    let len = r.count(12)?;
                    let mut row = Vec::with_capacity(len);
                    let mut prev: Option<Epoch> = None;
                    for _ in 0..len {
                        let e = r.u32()?;
                        let c = r.u64()?;
                        if prev.map(|p| p >= e).unwrap_or(false) {
                            return Err(StoreError::Corrupt(
                                "sparse epoch row not strictly ascending".into(),
                            ));
                        }
                        prev = Some(e);
                        row.push((e, c));
                    }
                    entries += row.len();
                    rows.push(row);
                }
                Rows::Sparse { rows, entries }
            }
            t => {
                return Err(StoreError::Corrupt(format!("unknown epoch row layout {t}")));
            }
        };
        Ok(EpochStore {
            rows,
            window,
            n_nodes: n,
        })
    }
}

// ---------------------------------------------------------------------------
// Fused epoch-tagged trie (every window size)
// ---------------------------------------------------------------------------

/// Don't bother compacting tiny arenas.
const COMPACT_MIN_NODES: usize = 1024;

#[derive(Debug, Clone)]
struct FusedEpochTrie {
    trie: ArenaTrie<EpochStore>,
    /// 0 = unbounded.
    window: usize,
    newest: Option<Epoch>,
    /// Distinct live epochs, ascending (≤ `window` entries when bounded).
    live: VecDeque<Epoch>,
    /// Tokens indexed per live epoch (parallel to `live`).
    live_tokens: VecDeque<usize>,
    /// Arena size right after the last compaction (growth trigger).
    last_compact_nodes: usize,
}

impl FusedEpochTrie {
    fn new(window: usize, max_depth: usize, pool: SharedPool) -> Self {
        FusedEpochTrie {
            trie: ArenaTrie::with_pool(max_depth.max(2), EpochStore::new(window), pool),
            window,
            newest: None,
            live: VecDeque::new(),
            live_tokens: VecDeque::new(),
            last_compact_nodes: 1,
        }
    }

    #[inline]
    fn in_window(&self, newest: Epoch, epoch: Epoch) -> bool {
        self.trie.store().in_window(newest, epoch)
    }

    /// Advance `newest` to `epoch` (≥ current), registering it as live and
    /// lazily dropping epochs that fell out of the window. O(window).
    fn advance(&mut self, epoch: Epoch) {
        if self.live.back() != Some(&epoch) {
            self.live.push_back(epoch);
            self.live_tokens.push_back(0);
        }
        self.newest = Some(epoch);
        while let Some(&front) = self.live.front() {
            if self.in_window(epoch, front) {
                break;
            }
            self.live.pop_front();
            self.live_tokens.pop_front();
        }
        // Epochs can advance via roll_epoch OR direct inserts at a newer
        // epoch; reclaim dead paths on either path (the guard inside is two
        // integer compares, so this is free on the hot path).
        self.maybe_compact();
    }

    fn roll_epoch(&mut self, epoch: Epoch) {
        if self.newest.map(|n| n < epoch).unwrap_or(true) {
            self.advance(epoch);
        }
    }

    /// Dead-epoch paths stay in the arena after (lazy) eviction; once the
    /// arena has doubled since the last sweep, rebuild it from the
    /// live-reachable nodes only. A node is live iff any row entry holds a
    /// live-epoch count, and liveness propagates to ancestors (counts are
    /// bumped along whole paths), so the core's keep-live-children DFS
    /// reconstructs exactly the reachable live trie, releases the dropped
    /// edges' pool segments, and re-derives every suffix link. Counts are
    /// copied verbatim, so drafts are unchanged. Amortized O(1) per insert;
    /// bounds memory at ~2× the live working set. Unbounded windows never
    /// evict, hence never compact — their suffix links are refreshed by
    /// the core's insert-count trigger instead.
    fn maybe_compact(&mut self) {
        if self.window == 0 {
            return;
        }
        let n = self.trie.node_count();
        if n < COMPACT_MIN_NODES || n < self.last_compact_nodes.saturating_mul(2) {
            return;
        }
        self.compact_live();
    }

    fn compact_live(&mut self) {
        let Some(newest) = self.newest else { return };
        let filter = EpochFilter::AnyLive { newest };
        self.trie.compact(|store, node| store.weight(node, filter) > 0);
        self.last_compact_nodes = self.trie.node_count().max(1);
    }

    #[cfg(test)]
    fn compact_now(&mut self) {
        if self.window != 0 {
            self.compact_live();
        }
    }

    fn insert_rollout(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        match self.newest {
            Some(n) if epoch < n => {
                // Late arrival from a sealed epoch: keep its TRUE epoch tag
                // (it must age and evict with its cohort) or drop it when
                // the cohort is already outside the window.
                if !self.in_window(n, epoch) {
                    return;
                }
                if !self.live.contains(&epoch) {
                    let pos = self
                        .live
                        .iter()
                        .position(|&e| e > epoch)
                        .unwrap_or(self.live.len());
                    self.live.insert(pos, epoch);
                    self.live_tokens.insert(pos, 0);
                }
            }
            _ => self.advance(epoch),
        }
        if let Some(pos) = self.live.iter().position(|&e| e == epoch) {
            self.live_tokens[pos] += tokens.len();
        }
        self.trie.insert_suffixes(tokens, epoch);
    }

    fn draft(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
        age_discount: f64,
    ) -> Option<WindowDraft> {
        let newest = self.newest?;
        // 1. Deepest match over ANY live epoch — one O(m) compressed-edge
        //    suffix-link pass; the position may sit mid-edge.
        let (take_max, pos) =
            self.trie
                .deepest_suffix(context, max_match, EpochFilter::AnyLive { newest });
        if take_max == 0 {
            return None;
        }
        // 2. Per-epoch match depths: the suffix chain from the match
        //    position visits exactly the matched suffixes of lengths
        //    take_max, take_max−1, …, 1 (skip/count re-descents, no root
        //    re-walks); record each live epoch the first (deepest) time it
        //    appears in a visited position's row.
        let matched = &context[context.len() - take_max..];
        let live_total = self.live.len();
        let mut cands: Vec<(f64, Epoch, usize, TriePos)> = Vec::new();
        self.trie.walk_suffix_chain(matched, pos, |take, p| {
            self.trie.store().for_each_live(p.row(), newest, |epoch, _count| {
                if !cands.iter().any(|&(_, e, _, _)| e == epoch) {
                    let age = (newest - epoch) as f64;
                    let score = take as f64 * age_discount.powf(age);
                    cands.push((score, epoch, take, p));
                }
            });
            // Continue until every live epoch is accounted for (the chain
            // stops at depth 1 on its own).
            cands.len() < live_total
        });
        // 3. Same ranking as the old bucket ring: best score, ties to the
        //    newer epoch, skipping candidates whose greedy walk is empty.
        cands.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.1.cmp(&a.1))
        });
        for &(score, epoch, mlen, p) in &cands {
            let (tokens, confidence) =
                self.trie.greedy_walk(p, budget, EpochFilter::Exact { epoch });
            if !tokens.is_empty() {
                return Some(WindowDraft {
                    tokens,
                    confidence,
                    match_len: mlen,
                    epoch,
                    score,
                });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::suffix::trie::SuffixTrieIndex;
    use crate::util::prop;

    // -----------------------------------------------------------------
    // The pre-fusion per-epoch bucket ring, kept ONLY as the executable
    // specification the fused trie is property-tested against. One full
    // counting-trie walk per bucket per draft — the cost the fused trie
    // removed — but trivially correct.
    // -----------------------------------------------------------------
    #[derive(Debug, Clone)]
    struct BucketRingRef {
        /// Ascending epoch order; newest at the back.
        buckets: VecDeque<(Epoch, SuffixTrieIndex)>,
        window: usize,
        max_depth: usize,
    }

    impl BucketRingRef {
        fn new(window: usize, max_depth: usize) -> Self {
            BucketRingRef {
                buckets: VecDeque::new(),
                window,
                max_depth,
            }
        }

        fn tokens_indexed(&self) -> usize {
            self.buckets.iter().map(|(_, b)| b.tokens_indexed()).sum()
        }

        fn insert(&mut self, epoch: Epoch, tokens: &[TokenId]) {
            match self.buckets.back().map(|(e, _)| *e) {
                Some(e) if e == epoch => {
                    self.buckets.back_mut().expect("nonempty").1.insert(tokens);
                }
                Some(e) if e > epoch => {
                    // Late arrival: index under its TRUE epoch; eviction
                    // drops it when it is already outside the window.
                    if let Some((_, b)) = self.buckets.iter_mut().find(|(e2, _)| *e2 == epoch) {
                        b.insert(tokens);
                    } else {
                        let mut bucket = SuffixTrieIndex::new(self.max_depth);
                        bucket.insert(tokens);
                        let pos = self
                            .buckets
                            .iter()
                            .position(|(e2, _)| *e2 > epoch)
                            .unwrap_or(self.buckets.len());
                        self.buckets.insert(pos, (epoch, bucket));
                        self.evict();
                    }
                }
                _ => {
                    let mut bucket = SuffixTrieIndex::new(self.max_depth);
                    bucket.insert(tokens);
                    self.buckets.push_back((epoch, bucket));
                    self.evict();
                }
            }
        }

        fn roll_epoch(&mut self, epoch: Epoch) {
            if self.buckets.back().map(|(e, _)| *e < epoch).unwrap_or(true) {
                self.buckets
                    .push_back((epoch, SuffixTrieIndex::new(self.max_depth)));
                self.evict();
            }
        }

        fn evict(&mut self) {
            if self.window == 0 {
                return;
            }
            while self.buckets.len() > self.window {
                self.buckets.pop_front();
            }
        }

        fn draft(
            &self,
            context: &[TokenId],
            max_match: usize,
            budget: usize,
            age_discount: f64,
        ) -> Option<WindowDraft> {
            let newest = self.buckets.back().map(|(e, _)| *e)?;
            let mut best: Option<WindowDraft> = None;
            for (epoch, bucket) in self.buckets.iter().rev() {
                let mlen = bucket.match_len(context, max_match);
                if mlen == 0 {
                    continue;
                }
                let age = (newest - *epoch) as f64;
                let score = mlen as f64 * age_discount.powf(age);
                let better = match &best {
                    None => true,
                    Some(b) => score > b.score,
                };
                if better {
                    let (tokens, confidence) = bucket.draft_weighted(context, max_match, budget);
                    if !tokens.is_empty() {
                        best = Some(WindowDraft {
                            tokens,
                            confidence,
                            match_len: mlen,
                            epoch: *epoch,
                            score,
                        });
                    }
                }
            }
            best
        }
    }

    #[test]
    fn window_evicts_old_epochs() {
        let mut w = WindowedIndex::new(2, 8);
        w.insert(0, &[1, 2, 3]);
        w.insert(1, &[4, 5, 6]);
        w.insert(2, &[7, 8, 9]);
        assert_eq!(w.bucket_count(), 2);
        // Epoch-0 content is gone.
        assert!(w.draft(&[1, 2], 4, 2).is_none());
        // Epoch-2 content matches.
        let d = w.draft(&[7, 8], 4, 2).unwrap();
        assert_eq!(d.tokens, vec![9]);
        assert_eq!(d.epoch, 2);
    }

    #[test]
    fn unbounded_window_keeps_everything() {
        let mut w = WindowedIndex::new(0, 8);
        for e in 0..20 {
            w.insert(e, &[e + 100, e + 101, e + 102]);
        }
        assert_eq!(w.bucket_count(), 20);
        // Oldest and newest epoch content both still draftable from the
        // sparse per-node rows.
        assert!(w.draft(&[100, 101], 4, 1).is_some());
        assert!(w.draft(&[119, 120], 4, 1).is_some());
        assert_eq!(w.probe_cost(), 1, "window_all runs on the fused trie");
    }

    #[test]
    fn sparse_rows_stay_linear_in_content() {
        // The ROADMAP complaint the sparse rows fix: with dense rows the
        // unbounded window paid O(nodes × epoch-span); sparse rows pay per
        // (node, epoch) pair. 200 epochs of the SAME rollout must not grow
        // per-epoch storage superlinearly — every path node carries one
        // entry per epoch it was seen in, and the trie itself stays
        // single-rollout-sized.
        let mut w = WindowedIndex::new(0, 8);
        let r: Vec<u32> = (0..30).map(|i| i % 7).collect();
        w.insert(0, &r);
        let nodes_once = w.node_count();
        for e in 1..200u32 {
            w.insert(e, &r);
        }
        assert_eq!(w.node_count(), nodes_once, "same content, same paths");
        assert_eq!(w.bucket_count(), 200);
        // Exact-epoch drafting still works across the whole span.
        assert!(w.draft(&[0, 1], 4, 2).is_some());
    }

    #[test]
    fn recency_preferred_on_equal_match() {
        let mut w = WindowedIndex::new(0, 8);
        w.insert(0, &[1, 2, 30]); // old continuation: 30
        w.insert(5, &[1, 2, 40]); // new continuation: 40
        let d = w.draft(&[1, 2], 4, 1).unwrap();
        assert_eq!(d.epoch, 5);
        assert_eq!(d.tokens, vec![40]);
    }

    #[test]
    fn much_longer_old_match_can_win() {
        let mut w = WindowedIndex::new(0, 16);
        w.insert(0, &[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]); // long pattern, old epoch
        w.insert(1, &[8, 50]); // short match in new epoch
        let d = w.draft(&[1, 2, 3, 4, 5, 6, 7, 8], 8, 2).unwrap();
        // Old bucket matches 8 tokens (score 8·0.85=6.8) vs new 1 (score 1).
        assert_eq!(d.epoch, 0);
        assert_eq!(d.tokens, vec![60, 61]);
    }

    #[test]
    fn fused_recency_and_long_match_ranking() {
        // The two ranking behaviors above, on a bounded window.
        let mut w = WindowedIndex::new(8, 16);
        w.insert(0, &[1, 2, 30]);
        w.insert(5, &[1, 2, 40]);
        let d = w.draft(&[1, 2], 4, 1).unwrap();
        assert_eq!((d.epoch, d.tokens.clone()), (5, vec![40]));

        let mut w = WindowedIndex::new(8, 16);
        w.insert(0, &[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]);
        w.insert(1, &[8, 50]);
        let d = w.draft(&[1, 2, 3, 4, 5, 6, 7, 8], 8, 2).unwrap();
        assert_eq!((d.epoch, d.tokens.clone()), (0, vec![60, 61]));
    }

    #[test]
    fn roll_epoch_creates_and_evicts() {
        let mut w = WindowedIndex::new(3, 8);
        for e in 0..10 {
            w.roll_epoch(e);
        }
        assert_eq!(w.bucket_count(), 3);
        assert_eq!(w.newest_epoch(), Some(9));
    }

    #[test]
    fn late_arrival_tagged_with_true_epoch() {
        // Regression for the old promote-to-newest-bucket bug: a rollout
        // from a sealed epoch must be indexed under ITS epoch, not smuggled
        // into the newest one.
        let mut w = WindowedIndex::new(4, 8);
        w.insert(3, &[1, 2]);
        w.insert(1, &[5, 6]); // late: epoch 1 after epoch 3 opened
        assert_eq!(w.bucket_count(), 2);
        let d = w.draft(&[5], 4, 1).unwrap();
        assert_eq!(d.epoch, 1);
        assert_eq!(d.tokens, vec![6]);
    }

    #[test]
    fn late_arrival_evicts_with_its_cohort() {
        let mut w = WindowedIndex::new(2, 8);
        w.insert(0, &[1, 2, 3]);
        w.roll_epoch(1);
        w.insert(1, &[4, 5, 6]);
        // Late arrival from epoch 0: visible now...
        w.insert(0, &[7, 8, 9]);
        assert_eq!(w.draft(&[7, 8], 4, 1).unwrap().epoch, 0);
        // ...but it ages with epoch 0 and evicts when the window moves on —
        // the old bug kept it alive inside the newest bucket.
        w.roll_epoch(2);
        assert!(w.draft(&[7, 8], 4, 1).is_none());
        // An arrival already outside the window is dropped outright.
        w.insert(0, &[9, 9, 9]);
        assert!(w.draft(&[9, 9], 4, 1).is_none());
        assert_eq!(w.newest_epoch(), Some(2));
    }

    #[test]
    fn fused_arena_compacts_after_eviction() {
        // 300 epochs of disjoint content with window 2: without compaction
        // the arena would retain every dead epoch's paths forever; the
        // sweep keeps it near the live working set — and the segment pool
        // must shed dead epochs' label bytes too, not just nodes.
        let mut w = WindowedIndex::new(2, 8);
        for e in 0..300u32 {
            w.roll_epoch(e);
            let r: Vec<u32> = (0..40).map(|i| e * 100 + (i % 37)).collect();
            w.insert(e, &r);
        }
        let newest_ctx = [299 * 100, 299 * 100 + 1];
        assert!(w.draft(&newest_ctx, 4, 2).is_some(), "live content drafts");
        assert!(w.draft(&[100, 101], 4, 2).is_none(), "dead content gone");
        assert!(
            w.node_count() < 5_000,
            "dead epochs must be compacted away, arena holds {} nodes",
            w.node_count()
        );
        let ps = w.pool_stats();
        assert!(
            ps.live_tokens < 40 * 300 / 2,
            "dead epochs' segments must be released, pool holds {} live tokens",
            ps.live_tokens
        );
    }

    #[test]
    fn window_all_matches_large_window_on_identical_streams() {
        // Regression for the old split-representation bug: window = 0 used
        // a bucket ring while window ≥ 1 used the fused trie, and their
        // `roll_epoch` bookkeeping could diverge. Both now run fused (one
        // on sparse rows, one on the dense ring); an unbounded window and a
        // window larger than the whole run must behave identically on the
        // same stream (inserts, rolls, late arrivals) — same drafts, same
        // live-epoch accounting.
        let mut all = WindowedIndex::new(0, 10);
        let mut big = WindowedIndex::new(64, 10);
        let mut rng = crate::util::rng::Rng::seed_from_u64(7);
        let mut epoch: Epoch = 0;
        for step in 0..120 {
            match step % 5 {
                0 => {
                    epoch += 1;
                    all.roll_epoch(epoch);
                    big.roll_epoch(epoch);
                }
                1 if epoch > 0 => {
                    let r: Vec<u32> = (0..12).map(|_| rng.below(6) as u32).collect();
                    all.insert(epoch - 1, &r); // late arrival
                    big.insert(epoch - 1, &r);
                }
                _ => {
                    let r: Vec<u32> = (0..15).map(|_| rng.below(6) as u32).collect();
                    all.insert(epoch, &r);
                    big.insert(epoch, &r);
                }
            }
            assert_eq!(all.bucket_count(), big.bucket_count(), "step {step}");
            assert_eq!(all.tokens_indexed(), big.tokens_indexed(), "step {step}");
            assert_eq!(all.newest_epoch(), big.newest_epoch(), "step {step}");
            let ctx: Vec<u32> = (0..8).map(|_| rng.below(6) as u32).collect();
            let (a, b) = (all.draft(&ctx, 6, 4), big.draft(&ctx, 6, 4));
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.tokens, y.tokens, "step {step}");
                    assert_eq!(x.epoch, y.epoch, "step {step}");
                    assert_eq!(x.match_len, y.match_len, "step {step}");
                }
                (a, b) => panic!("draft presence diverged at step {step}: {a:?} vs {b:?}"),
            }
        }
        assert!(epoch > 20, "stream must span many epochs");
    }

    #[test]
    fn deepest_visible_prefix_skips_drained_dense_edges() {
        // Satellite regression: a partial-edge match reports the edge's
        // lower node ONLY when that node's filtered weight is nonzero.
        // Dense rows drain by eviction: epoch 0 falls out of a window of
        // 1, the path stays in the arena (no compaction below the size
        // floor), and the drained edge must be descended through but
        // never reported.
        let mut w = WindowedIndex::new(1, 8);
        w.insert(0, &[1, 2, 3, 4]);
        w.insert(1, &[1, 9]); // splits [1,2,3,4] at depth 1; evicts epoch 0
        let trie = &w.fused.trie;
        assert!(trie.locate(&[1, 2]).is_some(), "drained path still in the arena");
        let live = EpochFilter::AnyLive { newest: 1 };
        let one = trie.locate(&[1]).expect("explicit after the split");
        // Context [1,2,3]: the [1] node is live (epoch 1); the partial
        // match inside the drained [2,3,4] edge must not be reported.
        assert_eq!(trie.deepest_visible_prefix(&[1, 2, 3], live), Some((one.row(), 1)));
        assert_eq!(
            trie.deepest_visible_prefix(&[1, 2, 3], EpochFilter::Exact { epoch: 1 }),
            Some((one.row(), 1))
        );
        assert_eq!(
            trie.deepest_visible_prefix(&[1, 2, 3], EpochFilter::Exact { epoch: 7 }),
            None,
            "an epoch nothing was indexed under sees no position at all"
        );
    }

    #[test]
    fn deepest_visible_prefix_mid_edge_on_sparse_rows() {
        // The sparse (window_all) counterpart: nothing ever drains under
        // AnyLive, so the partial-edge match reports the lower node's row
        // across arbitrary epoch distance — while exact-epoch filters
        // still distinguish which epochs each node saw.
        let mut w = WindowedIndex::new(0, 8);
        w.insert(0, &[1, 2, 3, 4]);
        w.insert(5, &[1, 9]);
        let trie = &w.fused.trie;
        let live = EpochFilter::AnyLive { newest: 5 };
        let lower = trie.locate(&[1, 2, 3, 4]).expect("present");
        let one = trie.locate(&[1]).expect("explicit after the split");
        assert_eq!(
            trie.deepest_visible_prefix(&[1, 2, 3], live),
            Some((lower.row(), 3)),
            "partial-edge match reports the lower node's row at matched depth"
        );
        assert_eq!(
            trie.deepest_visible_prefix(&[1, 2, 3], EpochFilter::Exact { epoch: 0 }),
            Some((lower.row(), 3)),
            "epoch 0 still holds the deep counts"
        );
        assert_eq!(
            trie.deepest_visible_prefix(&[1, 2, 3], EpochFilter::Exact { epoch: 5 }),
            Some((one.row(), 1)),
            "epoch 5 only ever reached the split boundary"
        );
        assert_eq!(
            trie.deepest_visible_prefix(&[1, 2, 3], EpochFilter::Exact { epoch: 3 }),
            None
        );
    }

    #[test]
    fn window_all_link_refresh_fires_and_preserves_drafts() {
        // The ROADMAP hole this PR closes: window_all tries never compact,
        // so their split links stayed approximate forever. The
        // insert-count trigger must fire on a long stream — and change
        // nothing observable: drafts stay identical to the bucket-ring
        // reference throughout.
        let mut all = WindowedIndex::new(0, 10);
        let mut reference = BucketRingRef::new(0, 10);
        let mut rng = crate::util::rng::Rng::seed_from_u64(42);
        for e in 0..60u32 {
            all.roll_epoch(e);
            reference.roll_epoch(e);
            for _ in 0..3 {
                let r: Vec<u32> = (0..25).map(|_| rng.below(9) as u32).collect();
                all.insert(e, &r);
                reference.insert(e, &r);
            }
            let ctx: Vec<u32> = (0..6).map(|_| rng.below(9) as u32).collect();
            let (a, b) = (all.draft(&ctx, 8, 4), reference.draft(&ctx, 8, 4, all.age_discount));
            match (a, b) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(x.tokens, y.tokens, "epoch {e}");
                    assert_eq!(x.epoch, y.epoch, "epoch {e}");
                }
                (a, b) => panic!("draft presence diverged at epoch {e}: {a:?} vs {b:?}"),
            }
        }
        assert!(
            all.link_rebuilds() >= 1,
            "the insert-count refresh must fire on the unbounded path"
        );
    }

    #[test]
    fn prop_window_all_exact_links_match_approximate() {
        // Tentpole anchor for the window_all refresh: a trie carrying
        // whatever mix of approximate and threshold-refreshed links must
        // answer every deepest-suffix query — and every draft —
        // identically to a clone whose links were just rebuilt exactly,
        // after long mixed insert/roll/late-arrival streams.
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 6) as u32;
            let mut w = WindowedIndex::new(0, 8);
            let mut epoch: Epoch = 0;
            for _ in 0..g.usize_in(1, 40) {
                match g.usize_in(0, 3) {
                    0 => {
                        epoch += 1;
                        w.roll_epoch(epoch);
                    }
                    1 if epoch > 0 => {
                        let r = g.vec_u32_nonempty(alphabet, 24);
                        w.insert(epoch - 1, &r); // late arrival
                    }
                    _ => {
                        let r = g.vec_u32_nonempty(alphabet, 24);
                        w.insert(epoch, &r);
                    }
                }
            }
            let Some(newest) = w.fused.newest else { return Ok(()) };
            let mut exact = w.clone();
            exact.fused.trie.rebuild_suffix_links();
            for _ in 0..12 {
                let ctx = g.vec_u32_nonempty(alphabet, 12);
                let f = EpochFilter::AnyLive { newest };
                prop::require_eq(
                    w.fused.trie.deepest_suffix(&ctx, 8, f),
                    exact.fused.trie.deepest_suffix(&ctx, 8, f),
                    "window_all deepest suffix, approximate vs exact links",
                )?;
                let budget = 1 + g.usize_in(0, 4);
                match (w.draft(&ctx, 8, budget), exact.draft(&ctx, 8, budget)) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        prop::require_eq(x.tokens, y.tokens, "draft tokens")?;
                        prop::require_eq(x.epoch, y.epoch, "draft epoch")?;
                        prop::require_eq(x.match_len, y.match_len, "draft match_len")?;
                    }
                    (a, b) => {
                        prop::require(false, &format!("presence diverged: {a:?} vs {b:?}"))?
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_window_size_never_exceeded() {
        prop::check(64, |g| {
            let win = 1 + g.usize_in(0, 6);
            let mut w = WindowedIndex::new(win, 8);
            let mut epoch = 0;
            for _ in 0..g.usize_in(1, 40) {
                if g.bool() {
                    epoch += 1;
                }
                let r = g.vec_u32_nonempty(8, 20);
                w.insert(epoch, &r);
                prop::require(w.bucket_count() <= win, "window bound respected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_nonempty_implies_match() {
        prop::check(64, |g| {
            let mut w = WindowedIndex::new(0, 10);
            for e in 0..g.usize_in(1, 5) as u32 {
                w.insert(e, &g.vec_u32_nonempty(5, 30));
            }
            let ctx = g.vec_u32_nonempty(5, 10);
            if let Some(d) = w.draft(&ctx, 6, 4) {
                prop::require(d.match_len >= 1, "match_len >= 1")?;
                prop::require(!d.tokens.is_empty(), "tokens nonempty")?;
                prop::require(d.tokens.len() <= 4, "budget respected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fused_matches_bucket_reference() {
        // THE equivalence anchor: over random consecutive-epoch histories
        // (rolls, inserts, late arrivals, forced compaction sweeps) the
        // fused compressed epoch trie must produce byte-identical drafts to
        // the per-epoch bucket ring — for bounded windows AND the unbounded
        // window_all path (win == 0, sparse rows).
        prop::check(96, |g| {
            let win = g.usize_in(0, 6); // 0 = window_all
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut fused = WindowedIndex::new(win, 10);
            let mut reference = BucketRingRef::new(win, 10);
            let mut epoch: Epoch = 0;
            for _ in 0..g.usize_in(1, 30) {
                match g.usize_in(0, 3) {
                    0 => {
                        epoch += 1;
                        fused.roll_epoch(epoch);
                        reference.roll_epoch(epoch);
                    }
                    1 if epoch > 0 => {
                        // Late arrival from the previous epoch.
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        fused.insert(epoch - 1, &r);
                        reference.insert(epoch - 1, &r);
                    }
                    _ => {
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        fused.insert(epoch, &r);
                        reference.insert(epoch, &r);
                    }
                }
                if g.usize_in(0, 7) == 0 {
                    // Sweep dead epochs right now (reference unaffected):
                    // drafts must not change across a compaction.
                    fused.compact_now();
                }
                prop::require_eq(
                    fused.bucket_count(),
                    reference.buckets.len(),
                    "live epoch count",
                )?;
                prop::require_eq(
                    fused.tokens_indexed(),
                    reference.tokens_indexed(),
                    "tokens indexed",
                )?;
                let ctx = g.vec_u32_nonempty(alphabet, 12);
                let budget = 1 + g.usize_in(0, 5);
                let a = fused.draft(&ctx, 6, budget);
                let b = reference.draft(&ctx, 6, budget, fused.age_discount);
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        prop::require_eq(x.tokens, y.tokens, "draft tokens")?;
                        prop::require_eq(x.epoch, y.epoch, "draft epoch")?;
                        prop::require_eq(x.match_len, y.match_len, "draft match_len")?;
                        prop::require_eq(x.confidence, y.confidence, "draft confidence")?;
                        prop::require((x.score - y.score).abs() < 1e-9, "draft score")?;
                    }
                    (a, b) => {
                        prop::require(
                            false,
                            &format!("draft presence diverged: fused={:?} ref={:?}", a, b),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    /// `das-store-v1` round trip of one windowed index (pool + source blob
    /// into fresh instances).
    fn roundtrip(w: &WindowedIndex) -> WindowedIndex {
        use crate::store::wire::{Reader, Writer};
        let mut out = Writer::new();
        w.pool().save_state(&mut out);
        w.save_state(&mut out);
        let bytes = out.into_bytes();
        let mut r = Reader::new(&bytes);
        let (pool, recorded) = SharedPool::load_state(&mut r).expect("pool loads");
        let mut restored =
            WindowedIndex::with_pool(w.window, w.fused.trie.max_depth(), pool.clone());
        restored.load_state(&mut r).expect("index loads");
        assert!(r.is_empty());
        assert_eq!(pool.reconcile_recorded(&recorded), 0, "refcounts re-derive");
        restored
    }

    #[test]
    fn prop_snapshot_roundtrip_matches_live_index() {
        // Dense (bounded window, incl. mid-stream compaction sweeps) AND
        // sparse (window_all, incl. the threshold link rebuild counter)
        // layouts: the restored index must draft bit-identically, report
        // identical gauges, and stay identical under further epoch rolls
        // and inserts.
        prop::check(48, |g| {
            let window = if g.bool() { 0 } else { 1 + g.usize_in(0, 4) };
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut w = WindowedIndex::new(window, 10);
            let mut epoch = 0u32;
            for _ in 0..g.usize_in(1, 6) {
                if g.bool() {
                    epoch += 1 + g.usize_in(0, 2) as u32;
                    w.roll_epoch(epoch);
                }
                w.insert(epoch, &g.vec_u32_nonempty(alphabet, 30));
                if window != 0 && g.usize_in(0, 5) == 0 {
                    w.compact_now(); // mid-stream compaction in the record
                }
            }
            let mut restored = roundtrip(&w);
            prop::require_eq(restored.node_count(), w.node_count(), "nodes")?;
            prop::require_eq(restored.token_positions(), w.token_positions(), "positions")?;
            prop::require_eq(restored.approx_bytes(), w.approx_bytes(), "heap bytes")?;
            prop::require_eq(restored.tokens_indexed(), w.tokens_indexed(), "tokens")?;
            prop::require_eq(restored.bucket_count(), w.bucket_count(), "live epochs")?;
            prop::require_eq(restored.newest_epoch(), w.newest_epoch(), "newest epoch")?;
            prop::require_eq(restored.link_rebuilds(), w.link_rebuilds(), "link rebuilds")?;
            for _ in 0..4 {
                let ctx = g.vec_u32_nonempty(alphabet, 12);
                let a = w.draft(&ctx, 6, 4);
                let b = restored.draft(&ctx, 6, 4);
                prop::require_eq(
                    a.as_ref().map(|d| (&d.tokens, &d.confidence, d.match_len, d.epoch)),
                    b.as_ref().map(|d| (&d.tokens, &d.confidence, d.match_len, d.epoch)),
                    "draft",
                )?;
            }
            // Further stream: rolls (evicting in the bounded case) and
            // inserts land identically on both.
            epoch += 1;
            w.roll_epoch(epoch);
            restored.roll_epoch(epoch);
            let extra = g.vec_u32_nonempty(alphabet, 20);
            w.insert(epoch, &extra);
            restored.insert(epoch, &extra);
            prop::require_eq(restored.node_count(), w.node_count(), "post-restore nodes")?;
            let ctx = g.vec_u32_nonempty(alphabet, 8);
            prop::require_eq(
                w.draft(&ctx, 6, 4).map(|d| d.tokens),
                restored.draft(&ctx, 6, 4).map(|d| d.tokens),
                "post-restore draft",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_published_snapshot_drafts_match_live_index() {
        // Tentpole anchor for the window layer: at every publish point, the
        // lock-free WindowSnapshot must draft bit-identically (tokens,
        // confidences, match_len, epoch, score) to the live locked index —
        // bounded windows AND window_all, across rolls, late arrivals, and
        // forced compaction sweeps. And a snapshot taken before a mutation
        // must keep answering from its publish state afterwards.
        prop::check(96, |g| {
            let win = g.usize_in(0, 6); // 0 = window_all
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut w = WindowedIndex::new(win, 10);
            let mut epoch: Epoch = 0;
            let mut stale: Option<(Arc<WindowSnapshot>, Vec<u32>, Option<WindowDraft>)> = None;
            for _ in 0..g.usize_in(1, 25) {
                match g.usize_in(0, 3) {
                    0 => {
                        epoch += 1;
                        w.roll_epoch(epoch);
                    }
                    1 if epoch > 0 => {
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        w.insert(epoch - 1, &r); // late arrival
                    }
                    _ => {
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        w.insert(epoch, &r);
                    }
                }
                if win != 0 && g.usize_in(0, 7) == 0 {
                    w.compact_now();
                }
                let snap = w.publish();
                prop::require_eq(snap.newest_epoch(), w.newest_epoch(), "newest epoch")?;
                prop::require_eq(snap.bucket_count(), w.bucket_count(), "live epochs")?;
                prop::require_eq(snap.stats().nodes, w.node_count(), "stat nodes")?;
                prop::require_eq(snap.stats().heap_bytes, w.approx_bytes(), "stat bytes")?;
                for _ in 0..4 {
                    let ctx = g.vec_u32_nonempty(alphabet, 12);
                    let budget = 1 + g.usize_in(0, 5);
                    let a = snap.draft(&ctx, 6, budget);
                    let b = w.draft(&ctx, 6, budget);
                    match (a, b) {
                        (None, None) => {}
                        (Some(x), Some(y)) => {
                            prop::require_eq(x.tokens, y.tokens, "draft tokens")?;
                            prop::require_eq(x.confidence, y.confidence, "draft confidence")?;
                            prop::require_eq(x.match_len, y.match_len, "draft match_len")?;
                            prop::require_eq(x.epoch, y.epoch, "draft epoch")?;
                            prop::require((x.score - y.score).abs() < 1e-12, "draft score")?;
                        }
                        (a, b) => prop::require(
                            false,
                            &format!("presence diverged: snap={a:?} live={b:?}"),
                        )?,
                    }
                }
                // Record one (snapshot, probe, answer) triple to check
                // staleness freezing at the end of the stream.
                if stale.is_none() {
                    let probe = g.vec_u32_nonempty(alphabet, 8);
                    let ans = snap.draft(&probe, 6, 4);
                    stale = Some((snap, probe, ans));
                }
            }
            if let Some((snap, probe, ans)) = stale {
                let now = snap.draft(&probe, 6, 4);
                prop::require_eq(
                    now.map(|d| (d.tokens, d.epoch)),
                    ans.map(|d| (d.tokens, d.epoch)),
                    "snapshot frozen at its publish point",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn publish_is_cached_between_mutations() {
        let mut w = WindowedIndex::new(4, 8);
        w.insert(0, &[1, 2, 3]);
        let a = w.publish();
        let b = w.publish();
        assert!(Arc::ptr_eq(&a, &b), "no mutation → same snapshot");
        assert_eq!(w.snapshot_publishes(), 1);
        w.insert(0, &[4, 5, 6]);
        let c = w.publish();
        assert!(!Arc::ptr_eq(&a, &c), "mutation → fresh snapshot");
        assert_eq!(w.snapshot_publishes(), 2);
        w.roll_epoch(1);
        w.publish();
        assert_eq!(w.snapshot_publishes(), 3);
        // The stale snapshot still answers from its own publish point.
        assert!(a.draft(&[1, 2], 4, 1).is_some());
        assert!(a.draft(&[4, 5], 4, 1).is_none(), "post-publish insert invisible");
        assert!(c.draft(&[4, 5], 4, 1).is_some());
        assert_eq!(a.newest_epoch(), Some(0));
    }

    #[test]
    fn window_mismatch_rejected_on_load() {
        use crate::store::wire::{Reader, StoreError, Writer};
        let mut w = WindowedIndex::new(4, 10);
        w.insert(0, &[1, 2, 3]);
        let mut out = Writer::new();
        w.pool().save_state(&mut out);
        w.save_state(&mut out);
        let bytes = out.into_bytes();
        let mut r = Reader::new(&bytes);
        let (pool, _) = SharedPool::load_state(&mut r).unwrap();
        let mut other = WindowedIndex::with_pool(8, 10, pool);
        match other.load_state(&mut r) {
            Err(StoreError::Mismatch(_)) => {}
            other => panic!("expected Mismatch, got {other:?}"),
        }
    }
}

//! Sliding-window drafter index (§4.1.2 "Sliding window selection tree").
//!
//! Policy drift makes old rollouts less predictive (Fig. 2), so the drafter
//! is built from a sliding window of recent trajectories. Historically this
//! was one counting suffix-trie *bucket per epoch*, which made every draft
//! call pay one full trie walk per bucket. The production representation is
//! now a **fused epoch-tagged trie**: one arena trie per shard whose nodes
//! carry a per-epoch count ring.
//!
//! # Fused layout (window ≥ 1)
//!
//! One [`ChildTable`]-arena trie holds the union of all live epochs' paths.
//! Each node owns `window` count slots in a flat side array; an insert at
//! epoch `e` bumps slot `e % window`, tagging it with `e` and lazily
//! zeroing whatever stale epoch the slot held before (live epochs span
//! fewer than `window` consecutive values, so live tags never collide).
//! Rolling the epoch is O(1): slots whose tag falls out of the window are
//! simply no longer live — whole-epoch eviction without touching a single
//! node (a periodic compaction sweep reclaims the dead paths once they
//! dominate the arena). A draft call probes ONE fused trie — a
//! binary-searched deepest match (O(m log m) arena probes, m = max match
//! length) plus a descending per-epoch depth scan of at most m short
//! re-walks — instead of `window` independent O(m²) bucket walks over
//! `window` separate hash-node tries. It reads each live epoch's match
//! depth from the visited nodes' rings and ranks candidates by the same
//! `match_len · age_discount^age` rule as before — identical drafts,
//! window-independent cost.
//!
//! Eviction is by epoch *distance* (`newest − e < window`); with the
//! consecutive epoch advances RL training produces this is exactly the old
//! keep-the-last-`window`-buckets behavior (property-tested below against
//! the bucket-ring reference).
//!
//! # Bucket layout (window = 0, "window_all" of Fig. 7)
//!
//! An unbounded window cannot use a fixed ring, so the ablation baseline
//! keeps the per-epoch bucket list — and honestly pays one walk per bucket,
//! which is precisely the cost the ablation measures.
//!
//! Late arrivals (a rollout from an already-sealed epoch) are indexed under
//! their TRUE epoch so they age and evict with their cohort; arrivals from
//! epochs already outside the window are dropped (Fig. 2's drift argument).
//! The old implementation silently promoted them into the newest bucket,
//! letting stale data outlive its window.

use std::collections::VecDeque;

use crate::suffix::trie::{ChildTable, SuffixTrieIndex};
use crate::tokens::{Epoch, TokenId};

/// One candidate draft from one epoch.
#[derive(Debug, Clone)]
pub struct WindowDraft {
    pub tokens: Vec<TokenId>,
    pub confidence: Vec<f32>,
    pub match_len: usize,
    pub epoch: Epoch,
    pub score: f64,
}

#[derive(Debug, Clone)]
pub struct WindowedIndex {
    /// Window size in epochs; 0 = unbounded ("window_all" in Fig. 7).
    pub window: usize,
    /// Multiplicative per-epoch age discount applied to match length when
    /// ranking candidate drafts across epochs.
    pub age_discount: f64,
    repr: Repr,
}

#[derive(Debug, Clone)]
enum Repr {
    /// window ≥ 1: one fused epoch-tagged trie.
    Fused(FusedEpochTrie),
    /// window == 0: legacy per-epoch buckets (unbounded history).
    Buckets(BucketRing),
}

impl WindowedIndex {
    pub fn new(window: usize, max_depth: usize) -> Self {
        let repr = if window == 0 {
            Repr::Buckets(BucketRing::new(0, max_depth))
        } else {
            Repr::Fused(FusedEpochTrie::new(window, max_depth))
        };
        WindowedIndex {
            window,
            age_discount: 0.85,
            repr,
        }
    }

    /// Number of distinct live epochs currently indexed.
    pub fn bucket_count(&self) -> usize {
        match &self.repr {
            Repr::Fused(f) => f.live.len(),
            Repr::Buckets(b) => b.buckets.len(),
        }
    }

    pub fn tokens_indexed(&self) -> usize {
        match &self.repr {
            Repr::Fused(f) => f.live_tokens.iter().sum(),
            Repr::Buckets(b) => b.tokens_indexed(),
        }
    }

    pub fn newest_epoch(&self) -> Option<Epoch> {
        match &self.repr {
            Repr::Fused(f) => f.newest,
            Repr::Buckets(b) => b.newest_epoch(),
        }
    }

    /// Insert a rollout produced at `epoch`. Epochs are expected to be
    /// non-decreasing; a late arrival is indexed under its true epoch while
    /// it is still inside the window and dropped once it is not.
    pub fn insert(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        match &mut self.repr {
            Repr::Fused(f) => f.insert_rollout(epoch, tokens),
            Repr::Buckets(b) => b.insert(epoch, tokens),
        }
    }

    /// Start a new (possibly empty) epoch and evict stale ones.
    pub fn roll_epoch(&mut self, epoch: Epoch) {
        match &mut self.repr {
            Repr::Fused(f) => f.roll_epoch(epoch),
            Repr::Buckets(b) => b.roll_epoch(epoch),
        }
    }

    /// Best draft across the window. Candidates are ranked by
    /// `match_len · age_discount^age` (ties → newer epoch), so a much longer
    /// match in an older epoch can still win, but recency is preferred.
    pub fn draft(&self, context: &[TokenId], max_match: usize, budget: usize) -> Option<WindowDraft> {
        if budget == 0 {
            return None;
        }
        match &self.repr {
            Repr::Fused(f) => f.draft(context, max_match, budget, self.age_discount),
            Repr::Buckets(b) => b.draft(context, max_match, budget, self.age_discount),
        }
    }

    /// Number of independent index structures a draft call probes (for
    /// latency figures): the fused trie is a single structure regardless of
    /// window size (its probe sequence is O(m log m), window-independent);
    /// window_all pays one full walk per bucket.
    pub fn probe_cost(&self) -> usize {
        match &self.repr {
            Repr::Fused(_) => 1,
            Repr::Buckets(b) => b.buckets.len(),
        }
    }

    pub fn approx_bytes(&self) -> usize {
        match &self.repr {
            Repr::Fused(f) => f.approx_bytes(),
            Repr::Buckets(b) => b.approx_bytes(),
        }
    }

    /// Trie nodes currently allocated (diagnostics; bounded by compaction
    /// on the fused path).
    pub fn node_count(&self) -> usize {
        match &self.repr {
            Repr::Fused(f) => f.nodes.len(),
            Repr::Buckets(b) => b.buckets.iter().map(|(_, t)| t.node_count()).sum(),
        }
    }
}

// ---------------------------------------------------------------------------
// Fused epoch-tagged trie (window ≥ 1)
// ---------------------------------------------------------------------------

/// One per-epoch count slot of a node's ring.
#[derive(Debug, Clone, Copy, Default)]
struct Slot {
    epoch: Epoch,
    count: u64,
}

#[derive(Debug, Clone, Default)]
struct RingNode {
    children: ChildTable,
}

#[derive(Debug, Clone)]
struct FusedEpochTrie {
    nodes: Vec<RingNode>,
    /// `window` slots per node: node `i`'s ring is
    /// `slots[i*window .. (i+1)*window]`, slot index `epoch % window`.
    slots: Vec<Slot>,
    window: usize,
    max_depth: usize,
    newest: Option<Epoch>,
    /// Distinct live epochs, ascending (≤ `window` entries).
    live: VecDeque<Epoch>,
    /// Tokens indexed per live epoch (parallel to `live`).
    live_tokens: VecDeque<usize>,
    /// Arena size right after the last compaction (growth trigger).
    last_compact_nodes: usize,
}

/// Don't bother compacting tiny arenas.
const COMPACT_MIN_NODES: usize = 1024;

impl FusedEpochTrie {
    fn new(window: usize, max_depth: usize) -> Self {
        FusedEpochTrie {
            nodes: vec![RingNode::default()],
            slots: vec![Slot::default(); window],
            window,
            max_depth: max_depth.max(2),
            newest: None,
            live: VecDeque::new(),
            live_tokens: VecDeque::new(),
            last_compact_nodes: 1,
        }
    }

    /// Is `epoch` inside the window relative to `newest`?
    #[inline]
    fn in_window(&self, newest: Epoch, epoch: Epoch) -> bool {
        epoch <= newest && (newest - epoch) < self.window as Epoch
    }

    /// Advance `newest` to `epoch` (≥ current), registering it as live and
    /// lazily dropping epochs that fell out of the window. O(window).
    fn advance(&mut self, epoch: Epoch) {
        if self.live.back() != Some(&epoch) {
            self.live.push_back(epoch);
            self.live_tokens.push_back(0);
        }
        self.newest = Some(epoch);
        while let Some(&front) = self.live.front() {
            if self.in_window(epoch, front) {
                break;
            }
            self.live.pop_front();
            self.live_tokens.pop_front();
        }
        // Epochs can advance via roll_epoch OR direct inserts at a newer
        // epoch; reclaim dead paths on either path (the guard inside is two
        // integer compares, so this is free on the hot path).
        self.maybe_compact();
    }

    fn roll_epoch(&mut self, epoch: Epoch) {
        if self.newest.map(|n| n < epoch).unwrap_or(true) {
            self.advance(epoch);
        }
    }

    /// Dead-epoch paths stay in the arena after (lazy) eviction; once the
    /// arena has doubled since the last sweep, rebuild it from the
    /// live-reachable nodes only. A node is live iff any ring slot holds a
    /// live-epoch count, and liveness propagates to ancestors (counts are
    /// bumped along whole paths), so one DFS that keeps live children
    /// reconstructs exactly the reachable live trie. Counts are copied
    /// verbatim, so drafts are unchanged. Amortized O(1) per insert;
    /// bounds memory at ~2× the live working set instead of growing with
    /// every epoch the run has ever seen (the old bucket ring freed whole
    /// tries on eviction — this is the fused equivalent).
    fn maybe_compact(&mut self) {
        let n = self.nodes.len();
        if n < COMPACT_MIN_NODES || n < self.last_compact_nodes.saturating_mul(2) {
            return;
        }
        let Some(newest) = self.newest else { return };
        let mut new_nodes: Vec<RingNode> = Vec::with_capacity(n / 2);
        let mut new_slots: Vec<Slot> = Vec::with_capacity((n / 2) * self.window);
        new_nodes.push(RingNode::default());
        new_slots.extend_from_slice(&self.slots[0..self.window]);
        let mut stack: Vec<(usize, usize)> = vec![(0, 0)]; // (old id, new id)
        while let Some((old_id, new_id)) = stack.pop() {
            let mut live_children: Vec<(TokenId, usize)> = Vec::new();
            self.nodes[old_id].children.for_each(|tok, child| {
                if self.live_at(child as usize, newest) {
                    live_children.push((tok, child as usize));
                }
            });
            for (tok, child_old) in live_children {
                let child_new = new_nodes.len();
                new_nodes.push(RingNode::default());
                let base = child_old * self.window;
                new_slots.extend_from_slice(&self.slots[base..base + self.window]);
                new_nodes[new_id].children.insert(tok, child_new as u32);
                stack.push((child_old, child_new));
            }
        }
        self.nodes = new_nodes;
        self.slots = new_slots;
        self.last_compact_nodes = self.nodes.len().max(1);
    }

    fn insert_rollout(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        match self.newest {
            Some(n) if epoch < n => {
                // Late arrival from a sealed epoch: keep its TRUE epoch tag
                // (it must age and evict with its cohort) or drop it when
                // the cohort is already outside the window.
                if !self.in_window(n, epoch) {
                    return;
                }
                if !self.live.contains(&epoch) {
                    let pos = self
                        .live
                        .iter()
                        .position(|&e| e > epoch)
                        .unwrap_or(self.live.len());
                    self.live.insert(pos, epoch);
                    self.live_tokens.insert(pos, 0);
                }
            }
            _ => self.advance(epoch),
        }
        if let Some(pos) = self.live.iter().position(|&e| e == epoch) {
            self.live_tokens[pos] += tokens.len();
        }
        self.insert_paths(epoch, tokens);
    }

    /// Bump node's epoch slot, lazily reclaiming a stale tag.
    #[inline]
    fn bump(&mut self, node: usize, epoch: Epoch) {
        let s = &mut self.slots[node * self.window + (epoch as usize % self.window)];
        if s.epoch != epoch {
            s.epoch = epoch;
            s.count = 0;
        }
        s.count += 1;
    }

    /// Count this node holds for `epoch` (0 if the slot was recycled).
    #[inline]
    fn epoch_count(&self, node: usize, epoch: Epoch) -> u64 {
        let s = &self.slots[node * self.window + (epoch as usize % self.window)];
        if s.epoch == epoch {
            s.count
        } else {
            0
        }
    }

    /// Does any live epoch pass through this node?
    fn live_at(&self, node: usize, newest: Epoch) -> bool {
        let base = node * self.window;
        self.slots[base..base + self.window]
            .iter()
            .any(|s| s.count > 0 && self.in_window(newest, s.epoch))
    }

    fn insert_paths(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        for start in 0..tokens.len() {
            let end = (start + self.max_depth).min(tokens.len());
            let mut node = 0usize;
            self.bump(0, epoch);
            for &tok in &tokens[start..end] {
                let next = match self.nodes[node].children.get(tok) {
                    Some(n) => n as usize,
                    None => {
                        let id = self.nodes.len();
                        self.nodes.push(RingNode::default());
                        self.slots
                            .extend(std::iter::repeat(Slot::default()).take(self.window));
                        self.nodes[node].children.insert(tok, id as u32);
                        id
                    }
                };
                node = next;
                self.bump(node, epoch);
            }
        }
    }

    fn locate(&self, pattern: &[TokenId]) -> Option<usize> {
        let mut node = 0usize;
        for &tok in pattern {
            node = self.nodes[node].children.get(tok)? as usize;
        }
        Some(node)
    }

    fn draft(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
        age_discount: f64,
    ) -> Option<WindowDraft> {
        let newest = self.newest?;
        let cap = context.len().min(max_match).min(self.max_depth);
        if cap == 0 {
            return None;
        }
        // 1. Deepest match over ANY live epoch — monotone in the suffix
        //    length (see trie.rs), so binary search.
        let probe = |take: usize| -> Option<usize> {
            self.locate(&context[context.len() - take..])
                .filter(|&n| self.live_at(n, newest))
        };
        probe(1)?;
        let mut lo = 1usize;
        let mut hi = cap;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if probe(mid).is_some() {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        let take_max = lo;
        // 2. Per-epoch match depths: scan take_max → 1, recording each live
        //    epoch the first (deepest) time it appears at the matched node.
        //    Per-epoch presence is monotone too, so first-seen = deepest.
        let mut cands: Vec<(f64, Epoch, usize, usize)> = Vec::new(); // (score, epoch, mlen, node)
        for take in (1..=take_max).rev() {
            let Some(node) = self.locate(&context[context.len() - take..]) else {
                continue;
            };
            let base = node * self.window;
            for s in &self.slots[base..base + self.window] {
                if s.count > 0
                    && self.in_window(newest, s.epoch)
                    && !cands.iter().any(|&(_, e, _, _)| e == s.epoch)
                {
                    let age = (newest - s.epoch) as f64;
                    let score = take as f64 * age_discount.powf(age);
                    cands.push((score, s.epoch, take, node));
                }
            }
            if cands.len() == self.live.len() {
                break; // every live epoch accounted for
            }
        }
        // 3. Same ranking as the bucket ring: best score, ties to the newer
        //    epoch, skipping candidates whose greedy walk yields nothing.
        cands.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.1.cmp(&a.1))
        });
        for &(score, epoch, mlen, node) in &cands {
            let (tokens, confidence) = self.draft_from(node, epoch, budget);
            if !tokens.is_empty() {
                return Some(WindowDraft {
                    tokens,
                    confidence,
                    match_len: mlen,
                    epoch,
                    score,
                });
            }
        }
        None
    }

    /// Greedy most-frequent-child walk restricted to one epoch's counts.
    fn draft_from(&self, start: usize, epoch: Epoch, budget: usize) -> (Vec<TokenId>, Vec<f32>) {
        let mut node = start;
        let mut draft = Vec::with_capacity(budget);
        let mut conf = Vec::with_capacity(budget);
        for _ in 0..budget {
            let parent_count = self.epoch_count(node, epoch);
            let mut best: Option<(TokenId, usize, u64)> = None;
            self.nodes[node].children.for_each(|tok, child| {
                let c = self.epoch_count(child as usize, epoch);
                if c == 0 {
                    return; // path belongs to another epoch
                }
                match best {
                    None => best = Some((tok, child as usize, c)),
                    Some((_, _, bc)) => {
                        if c > bc {
                            best = Some((tok, child as usize, c));
                        }
                    }
                }
            });
            let Some((tok, child, c)) = best else { break };
            draft.push(tok);
            conf.push((c as f64 / parent_count.max(1) as f64) as f32);
            node = child;
        }
        (draft, conf)
    }

    fn approx_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<RingNode>()
            + self.slots.len() * std::mem::size_of::<Slot>()
            + self
                .nodes
                .iter()
                .map(|n| n.children.heap_bytes())
                .sum::<usize>()
    }
}

// ---------------------------------------------------------------------------
// Bucket ring (window = 0 production path; reference impl for the tests)
// ---------------------------------------------------------------------------

/// Per-epoch trie buckets — the pre-fusion representation. Kept as the
/// `window_all` implementation (an unbounded window cannot ring-buffer) and
/// as the executable specification the fused trie is property-tested
/// against.
#[derive(Debug, Clone)]
struct BucketRing {
    /// Ascending epoch order; newest at the back.
    buckets: VecDeque<(Epoch, SuffixTrieIndex)>,
    window: usize,
    max_depth: usize,
}

impl BucketRing {
    fn new(window: usize, max_depth: usize) -> Self {
        BucketRing {
            buckets: VecDeque::new(),
            window,
            max_depth,
        }
    }

    fn tokens_indexed(&self) -> usize {
        self.buckets.iter().map(|(_, b)| b.tokens_indexed()).sum()
    }

    fn newest_epoch(&self) -> Option<Epoch> {
        self.buckets.back().map(|(e, _)| *e)
    }

    fn insert(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        let newest = self.newest_epoch();
        match newest {
            Some(e) if e == epoch => {
                self.buckets.back_mut().expect("nonempty").1.insert(tokens);
            }
            Some(e) if e > epoch => {
                // Late arrival: index under its TRUE epoch (creating the
                // bucket in order if needed); eviction below drops it
                // immediately when it is already outside the window.
                if let Some((_, b)) = self.buckets.iter_mut().find(|(e2, _)| *e2 == epoch) {
                    b.insert(tokens);
                } else {
                    let mut bucket = SuffixTrieIndex::new(self.max_depth);
                    bucket.insert(tokens);
                    let pos = self
                        .buckets
                        .iter()
                        .position(|(e2, _)| *e2 > epoch)
                        .unwrap_or(self.buckets.len());
                    self.buckets.insert(pos, (epoch, bucket));
                    self.evict();
                }
            }
            _ => {
                let mut bucket = SuffixTrieIndex::new(self.max_depth);
                bucket.insert(tokens);
                self.buckets.push_back((epoch, bucket));
                self.evict();
            }
        }
    }

    fn roll_epoch(&mut self, epoch: Epoch) {
        if self.buckets.back().map(|(e, _)| *e < epoch).unwrap_or(true) {
            self.buckets
                .push_back((epoch, SuffixTrieIndex::new(self.max_depth)));
            self.evict();
        }
    }

    fn evict(&mut self) {
        if self.window == 0 {
            return;
        }
        while self.buckets.len() > self.window {
            self.buckets.pop_front();
        }
    }

    fn draft(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
        age_discount: f64,
    ) -> Option<WindowDraft> {
        let newest = self.newest_epoch()?;
        let mut best: Option<WindowDraft> = None;
        for (epoch, bucket) in self.buckets.iter().rev() {
            let mlen = bucket.match_len(context, max_match);
            if mlen == 0 {
                continue;
            }
            let age = (newest - *epoch) as f64;
            let score = mlen as f64 * age_discount.powf(age);
            let better = match &best {
                None => true,
                Some(b) => score > b.score,
            };
            if better {
                let (tokens, confidence) = bucket.draft_weighted(context, max_match, budget);
                if !tokens.is_empty() {
                    best = Some(WindowDraft {
                        tokens,
                        confidence,
                        match_len: mlen,
                        epoch: *epoch,
                        score,
                    });
                }
            }
        }
        best
    }

    fn approx_bytes(&self) -> usize {
        self.buckets.iter().map(|(_, b)| b.approx_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn window_evicts_old_epochs() {
        let mut w = WindowedIndex::new(2, 8);
        w.insert(0, &[1, 2, 3]);
        w.insert(1, &[4, 5, 6]);
        w.insert(2, &[7, 8, 9]);
        assert_eq!(w.bucket_count(), 2);
        // Epoch-0 content is gone.
        assert!(w.draft(&[1, 2], 4, 2).is_none());
        // Epoch-2 content matches.
        let d = w.draft(&[7, 8], 4, 2).unwrap();
        assert_eq!(d.tokens, vec![9]);
        assert_eq!(d.epoch, 2);
    }

    #[test]
    fn unbounded_window_keeps_everything() {
        let mut w = WindowedIndex::new(0, 8);
        for e in 0..20 {
            w.insert(e, &[e + 100, e + 101, e + 102]);
        }
        assert_eq!(w.bucket_count(), 20);
        assert!(w.draft(&[100, 101], 4, 1).is_some());
    }

    #[test]
    fn recency_preferred_on_equal_match() {
        let mut w = WindowedIndex::new(0, 8);
        w.insert(0, &[1, 2, 30]); // old continuation: 30
        w.insert(5, &[1, 2, 40]); // new continuation: 40
        let d = w.draft(&[1, 2], 4, 1).unwrap();
        assert_eq!(d.epoch, 5);
        assert_eq!(d.tokens, vec![40]);
    }

    #[test]
    fn much_longer_old_match_can_win() {
        let mut w = WindowedIndex::new(0, 16);
        w.insert(0, &[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]); // long pattern, old epoch
        w.insert(1, &[8, 50]); // short match in new epoch
        let d = w.draft(&[1, 2, 3, 4, 5, 6, 7, 8], 8, 2).unwrap();
        // Old bucket matches 8 tokens (score 8·0.85=6.8) vs new 1 (score 1).
        assert_eq!(d.epoch, 0);
        assert_eq!(d.tokens, vec![60, 61]);
    }

    #[test]
    fn fused_recency_and_long_match_ranking() {
        // The two ranking behaviors above, on the fused (window ≥ 1) path.
        let mut w = WindowedIndex::new(8, 16);
        w.insert(0, &[1, 2, 30]);
        w.insert(5, &[1, 2, 40]);
        let d = w.draft(&[1, 2], 4, 1).unwrap();
        assert_eq!((d.epoch, d.tokens.clone()), (5, vec![40]));

        let mut w = WindowedIndex::new(8, 16);
        w.insert(0, &[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]);
        w.insert(1, &[8, 50]);
        let d = w.draft(&[1, 2, 3, 4, 5, 6, 7, 8], 8, 2).unwrap();
        assert_eq!((d.epoch, d.tokens.clone()), (0, vec![60, 61]));
    }

    #[test]
    fn roll_epoch_creates_and_evicts() {
        let mut w = WindowedIndex::new(3, 8);
        for e in 0..10 {
            w.roll_epoch(e);
        }
        assert_eq!(w.bucket_count(), 3);
        assert_eq!(w.newest_epoch(), Some(9));
    }

    #[test]
    fn late_arrival_tagged_with_true_epoch() {
        // Regression for the old promote-to-newest-bucket bug: a rollout
        // from a sealed epoch must be indexed under ITS epoch, not smuggled
        // into the newest one.
        let mut w = WindowedIndex::new(4, 8);
        w.insert(3, &[1, 2]);
        w.insert(1, &[5, 6]); // late: epoch 1 after epoch 3 opened
        assert_eq!(w.bucket_count(), 2);
        let d = w.draft(&[5], 4, 1).unwrap();
        assert_eq!(d.epoch, 1);
        assert_eq!(d.tokens, vec![6]);
    }

    #[test]
    fn late_arrival_evicts_with_its_cohort() {
        let mut w = WindowedIndex::new(2, 8);
        w.insert(0, &[1, 2, 3]);
        w.roll_epoch(1);
        w.insert(1, &[4, 5, 6]);
        // Late arrival from epoch 0: visible now...
        w.insert(0, &[7, 8, 9]);
        assert_eq!(w.draft(&[7, 8], 4, 1).unwrap().epoch, 0);
        // ...but it ages with epoch 0 and evicts when the window moves on —
        // the old bug kept it alive inside the newest bucket.
        w.roll_epoch(2);
        assert!(w.draft(&[7, 8], 4, 1).is_none());
        // An arrival already outside the window is dropped outright.
        w.insert(0, &[9, 9, 9]);
        assert!(w.draft(&[9, 9], 4, 1).is_none());
        assert_eq!(w.newest_epoch(), Some(2));
    }

    #[test]
    fn fused_arena_compacts_after_eviction() {
        // 300 epochs of disjoint content with window 2: without compaction
        // the arena would retain every dead epoch's paths forever (~90k
        // nodes here); the sweep keeps it near the live working set.
        let mut w = WindowedIndex::new(2, 8);
        for e in 0..300u32 {
            w.roll_epoch(e);
            let r: Vec<u32> = (0..40).map(|i| e * 100 + (i % 37)).collect();
            w.insert(e, &r);
        }
        let newest_ctx = [299 * 100, 299 * 100 + 1];
        assert!(w.draft(&newest_ctx, 4, 2).is_some(), "live content drafts");
        assert!(w.draft(&[100, 101], 4, 2).is_none(), "dead content gone");
        assert!(
            w.node_count() < 5_000,
            "dead epochs must be compacted away, arena holds {} nodes",
            w.node_count()
        );
    }

    #[test]
    fn prop_window_size_never_exceeded() {
        prop::check(64, |g| {
            let win = 1 + g.usize_in(0, 6);
            let mut w = WindowedIndex::new(win, 8);
            let mut epoch = 0;
            for _ in 0..g.usize_in(1, 40) {
                if g.bool() {
                    epoch += 1;
                }
                let r = g.vec_u32_nonempty(8, 20);
                w.insert(epoch, &r);
                prop::require(w.bucket_count() <= win, "window bound respected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_nonempty_implies_match() {
        prop::check(64, |g| {
            let mut w = WindowedIndex::new(0, 10);
            for e in 0..g.usize_in(1, 5) as u32 {
                w.insert(e, &g.vec_u32_nonempty(5, 30));
            }
            let ctx = g.vec_u32_nonempty(5, 10);
            if let Some(d) = w.draft(&ctx, 6, 4) {
                prop::require(d.match_len >= 1, "match_len >= 1")?;
                prop::require(!d.tokens.is_empty(), "tokens nonempty")?;
                prop::require(d.tokens.len() <= 4, "budget respected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fused_matches_bucket_reference() {
        // THE equivalence anchor: over random consecutive-epoch histories
        // (rolls, inserts, late arrivals) the fused epoch-ring must produce
        // byte-identical drafts to the per-epoch bucket ring.
        prop::check(96, |g| {
            let win = 1 + g.usize_in(0, 5);
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut fused = WindowedIndex::new(win, 10);
            let mut reference = BucketRing::new(win, 10);
            let mut epoch: Epoch = 0;
            for _ in 0..g.usize_in(1, 30) {
                match g.usize_in(0, 3) {
                    0 => {
                        epoch += 1;
                        fused.roll_epoch(epoch);
                        reference.roll_epoch(epoch);
                    }
                    1 if epoch > 0 => {
                        // Late arrival from the previous epoch.
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        fused.insert(epoch - 1, &r);
                        reference.insert(epoch - 1, &r);
                    }
                    _ => {
                        let r = g.vec_u32_nonempty(alphabet, 20);
                        fused.insert(epoch, &r);
                        reference.insert(epoch, &r);
                    }
                }
                prop::require_eq(
                    fused.bucket_count(),
                    reference.buckets.len(),
                    "live epoch count",
                )?;
                prop::require_eq(
                    fused.tokens_indexed(),
                    reference.tokens_indexed(),
                    "tokens indexed",
                )?;
                let ctx = g.vec_u32_nonempty(alphabet, 12);
                let budget = 1 + g.usize_in(0, 5);
                let a = fused.draft(&ctx, 6, budget);
                let b = reference.draft(&ctx, 6, budget, fused.age_discount);
                match (a, b) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        prop::require_eq(x.tokens, y.tokens, "draft tokens")?;
                        prop::require_eq(x.epoch, y.epoch, "draft epoch")?;
                        prop::require_eq(x.match_len, y.match_len, "draft match_len")?;
                        prop::require_eq(x.confidence, y.confidence, "draft confidence")?;
                        prop::require((x.score - y.score).abs() < 1e-9, "draft score")?;
                    }
                    (a, b) => {
                        prop::require(
                            false,
                            &format!("draft presence diverged: fused={:?} ref={:?}", a, b),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }
}

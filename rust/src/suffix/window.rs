//! Sliding-window drafter index (§4.1.2 "Sliding window selection tree").
//!
//! Policy drift makes old rollouts less predictive (Fig. 2), so the drafter
//! is built from a sliding window of recent trajectories. We implement the
//! window as one counting suffix-trie *bucket per epoch*: inserts are
//! append-only into the newest bucket (keeping the incremental-update cost
//! profile of Fig. 5), and eviction drops whole stale buckets — true deletion
//! without tree surgery. Queries probe buckets newest → oldest and pick the
//! draft whose (age-discounted) match quality is best, which realizes the
//! paper's "mild down-weighting of matches originating from older epochs".

use std::collections::VecDeque;

use crate::suffix::trie::SuffixTrieIndex;
use crate::tokens::{Epoch, TokenId};

#[derive(Debug, Clone)]
pub struct WindowedIndex {
    /// Newest bucket at the back.
    buckets: VecDeque<(Epoch, SuffixTrieIndex)>,
    /// Window size in epochs; 0 = unbounded ("window_all" in Fig. 7).
    pub window: usize,
    /// Trie depth cap (match_len + draft budget cap).
    max_depth: usize,
    /// Multiplicative per-epoch age discount applied to match length when
    /// ranking candidate drafts across buckets.
    pub age_discount: f64,
}

/// One candidate draft from one bucket.
#[derive(Debug, Clone)]
pub struct WindowDraft {
    pub tokens: Vec<TokenId>,
    pub confidence: Vec<f32>,
    pub match_len: usize,
    pub epoch: Epoch,
    pub score: f64,
}

impl WindowedIndex {
    pub fn new(window: usize, max_depth: usize) -> Self {
        WindowedIndex {
            buckets: VecDeque::new(),
            window,
            max_depth,
            age_discount: 0.85,
        }
    }

    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    pub fn tokens_indexed(&self) -> usize {
        self.buckets.iter().map(|(_, b)| b.tokens_indexed()).sum()
    }

    pub fn newest_epoch(&self) -> Option<Epoch> {
        self.buckets.back().map(|(e, _)| *e)
    }

    /// Insert a rollout produced at `epoch`. Epochs must be non-decreasing.
    pub fn insert(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        match self.buckets.back_mut() {
            Some((e, bucket)) if *e == epoch => bucket.insert(tokens),
            Some((e, _)) if *e > epoch => {
                // Late arrival from an already-sealed epoch: index it into
                // the newest bucket rather than violating ordering.
                self.buckets.back_mut().unwrap().1.insert(tokens);
            }
            _ => {
                let mut bucket = SuffixTrieIndex::new(self.max_depth);
                bucket.insert(tokens);
                self.buckets.push_back((epoch, bucket));
                self.evict();
            }
        }
    }

    /// Start a new (possibly empty) epoch bucket and evict stale ones.
    pub fn roll_epoch(&mut self, epoch: Epoch) {
        if self.buckets.back().map(|(e, _)| *e < epoch).unwrap_or(true) {
            self.buckets
                .push_back((epoch, SuffixTrieIndex::new(self.max_depth)));
            self.evict();
        }
    }

    fn evict(&mut self) {
        if self.window == 0 {
            return;
        }
        while self.buckets.len() > self.window {
            self.buckets.pop_front();
        }
    }

    /// Best draft across the window. Candidates are ranked by
    /// `match_len · age_discount^age` (ties → newer epoch), so a much longer
    /// match in an older epoch can still win, but recency is preferred.
    pub fn draft(&self, context: &[TokenId], max_match: usize, budget: usize) -> Option<WindowDraft> {
        if budget == 0 {
            return None;
        }
        let newest = self.newest_epoch()?;
        let mut best: Option<WindowDraft> = None;
        for (epoch, bucket) in self.buckets.iter().rev() {
            let mlen = bucket.match_len(context, max_match);
            if mlen == 0 {
                continue;
            }
            let age = (newest - *epoch) as f64;
            let score = mlen as f64 * self.age_discount.powf(age);
            let better = match &best {
                None => true,
                Some(b) => score > b.score,
            };
            if better {
                let (tokens, confidence) = bucket.draft_weighted(context, max_match, budget);
                if !tokens.is_empty() {
                    best = Some(WindowDraft {
                        tokens,
                        confidence,
                        match_len: mlen,
                        epoch: *epoch,
                        score,
                    });
                }
            }
        }
        best
    }

    /// Total number of probe operations a draft costs (for latency figures:
    /// window_all pays for every bucket).
    pub fn probe_cost(&self) -> usize {
        self.buckets.len()
    }

    pub fn approx_bytes(&self) -> usize {
        self.buckets.iter().map(|(_, b)| b.approx_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn window_evicts_old_epochs() {
        let mut w = WindowedIndex::new(2, 8);
        w.insert(0, &[1, 2, 3]);
        w.insert(1, &[4, 5, 6]);
        w.insert(2, &[7, 8, 9]);
        assert_eq!(w.bucket_count(), 2);
        // Epoch-0 content is gone.
        assert!(w.draft(&[1, 2], 4, 2).is_none());
        // Epoch-2 content matches.
        let d = w.draft(&[7, 8], 4, 2).unwrap();
        assert_eq!(d.tokens, vec![9]);
        assert_eq!(d.epoch, 2);
    }

    #[test]
    fn unbounded_window_keeps_everything() {
        let mut w = WindowedIndex::new(0, 8);
        for e in 0..20 {
            w.insert(e, &[e + 100, e + 101, e + 102]);
        }
        assert_eq!(w.bucket_count(), 20);
        assert!(w.draft(&[100, 101], 4, 1).is_some());
    }

    #[test]
    fn recency_preferred_on_equal_match() {
        let mut w = WindowedIndex::new(0, 8);
        w.insert(0, &[1, 2, 30]); // old continuation: 30
        w.insert(5, &[1, 2, 40]); // new continuation: 40
        let d = w.draft(&[1, 2], 4, 1).unwrap();
        assert_eq!(d.epoch, 5);
        assert_eq!(d.tokens, vec![40]);
    }

    #[test]
    fn much_longer_old_match_can_win() {
        let mut w = WindowedIndex::new(0, 16);
        w.insert(0, &[1, 2, 3, 4, 5, 6, 7, 8, 60, 61]); // long pattern, old epoch
        w.insert(1, &[8, 50]); // short match in new epoch
        let d = w.draft(&[1, 2, 3, 4, 5, 6, 7, 8], 8, 2).unwrap();
        // Old bucket matches 8 tokens (score 8·0.85=6.8) vs new 1 (score 1).
        assert_eq!(d.epoch, 0);
        assert_eq!(d.tokens, vec![60, 61]);
    }

    #[test]
    fn roll_epoch_creates_and_evicts() {
        let mut w = WindowedIndex::new(3, 8);
        for e in 0..10 {
            w.roll_epoch(e);
        }
        assert_eq!(w.bucket_count(), 3);
        assert_eq!(w.newest_epoch(), Some(9));
    }

    #[test]
    fn late_arrival_goes_to_newest_bucket() {
        let mut w = WindowedIndex::new(4, 8);
        w.insert(3, &[1, 2]);
        w.insert(1, &[5, 6]); // late: epoch 1 after epoch 3 sealed
        assert_eq!(w.bucket_count(), 1);
        assert!(w.draft(&[5], 4, 1).is_some());
    }

    #[test]
    fn prop_window_size_never_exceeded() {
        prop::check(64, |g| {
            let win = 1 + g.usize_in(0, 6);
            let mut w = WindowedIndex::new(win, 8);
            let mut epoch = 0;
            for _ in 0..g.usize_in(1, 40) {
                if g.bool() {
                    epoch += 1;
                }
                let r = g.vec_u32_nonempty(8, 20);
                w.insert(epoch, &r);
                prop::require(w.bucket_count() <= win, "window bound respected")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_nonempty_implies_match() {
        prop::check(64, |g| {
            let mut w = WindowedIndex::new(0, 10);
            for e in 0..g.usize_in(1, 5) as u32 {
                w.insert(e, &g.vec_u32_nonempty(5, 30));
            }
            let ctx = g.vec_u32_nonempty(5, 10);
            if let Some(d) = w.draft(&ctx, 6, 4) {
                prop::require(d.match_len >= 1, "match_len >= 1")?;
                prop::require(!d.tokens.is_empty(), "tokens nonempty")?;
                prop::require(d.tokens.len() <= 4, "budget respected")?;
            }
            Ok(())
        });
    }
}

//! The single arena-trie core every suffix walk in this crate runs on.
//!
//! Before this module existed the repo carried three hand-rolled copies of
//! the same trie machinery — [`super::trie::SuffixTrieIndex`], the fused
//! epoch trie in [`super::window`], and the HashMap prefix trie in
//! [`super::router`] — that differed only in *what they count per node*
//! (a plain occurrence count, an epoch-tagged count ring, a shard-owner
//! table). They could silently drift; now there is exactly ONE
//! implementation of locate / insert / deepest-match / greedy-walk,
//! parameterized over a [`CountStore`].
//!
//! # Layout
//!
//! Nodes live in one bump-allocated arena (`Vec`, ids are indices, root is
//! node 0). Child edges use [`ChildTable`]: up to [`INLINE_CHILDREN`]
//! children as parallel sorted arrays *inside the node*, spilling to a
//! sorted heap `Vec` only for high-fanout nodes. The inline probe is
//! **branchless** — all 8 slots are compared with a fixed trip count and the
//! unique hit extracted from a bitmask, so the compiler can lower it to one
//! wide vector compare + movemask instead of a data-dependent early-exit
//! scan. Per-node *counts* live in the [`CountStore`], not in the node, so
//! the walk code is identical for every substrate.
//!
//! # Suffix links
//!
//! Every node stores a suffix link: the node whose string is this node's
//! string minus its FIRST token (root for depth-1 nodes). Two consequences:
//!
//! * **Deepest-suffix matching is a single O(m) forward pass**
//!   (Aho–Corasick style): scan the last `m` context tokens once,
//!   descending on a child hit and falling back along suffix links on a
//!   miss. This replaces the previous monotone binary search over suffix
//!   lengths (O(m log m) root re-walks), and before that an O(m²) rescan.
//! * **Sliding-context insertion is one left-to-right pass**: at each
//!   position the suffix-link chain of the current deepest node IS the set
//!   of parents to extend, so inserting all depth-capped suffixes costs one
//!   child probe per count bump and never re-walks from the root. The walk
//!   maintenance itself is O(1) amortized per token; the D count bumps per
//!   position are information-theoretically required (every suffix node's
//!   count changes).
//!
//! The trie's string set is *substring-closed* (every substring ≤ the depth
//! cap of anything inserted via [`ArenaTrie::insert_suffixes`] is itself a
//! path), which gives the invariant the suffix-link machinery relies on:
//! the link target of every node always exists. Closure also survives
//! [`ArenaTrie::compact`] (liveness is substring-closed too — see
//! `window.rs`), so compaction can rebuild all links in one BFS with the
//! textbook rule `link(child(u, t)) = child(link(u), t)`.

use crate::tokens::TokenId;

/// Children stored inline per node before spilling to a sorted heap vector.
/// Widened from 4 after the probe became branchless: 8 slots are one u32x8
/// compare, and deeper-than-root trie nodes almost never exceed it.
pub(crate) const INLINE_CHILDREN: usize = 8;

/// Sorted child table: inline small-array storage with sorted-`Vec` spill.
///
/// Iteration order is always ascending token id, which the draft walks rely
/// on for deterministic smallest-token tie-breaking.
#[derive(Debug, Clone, Default)]
pub(crate) struct ChildTable {
    inline_len: u8,
    inline_tokens: [TokenId; INLINE_CHILDREN],
    inline_children: [u32; INLINE_CHILDREN],
    /// Sorted by token; `Some` once fanout exceeds `INLINE_CHILDREN` (the
    /// inline arrays are then no longer authoritative).
    spill: Option<Box<Vec<(TokenId, u32)>>>,
}

impl ChildTable {
    #[inline]
    pub(crate) fn get(&self, tok: TokenId) -> Option<u32> {
        if let Some(spill) = &self.spill {
            match spill.binary_search_by_key(&tok, |&(t, _)| t) {
                Ok(i) => Some(spill[i].1),
                Err(_) => None,
            }
        } else {
            // Branchless probe: compare ALL slots (fixed trip count, no
            // early exit), mask to the live prefix, extract the unique hit.
            let mut mask = 0u32;
            for i in 0..INLINE_CHILDREN {
                mask |= ((self.inline_tokens[i] == tok) as u32) << i;
            }
            mask &= (1u32 << self.inline_len) - 1;
            if mask == 0 {
                None
            } else {
                Some(self.inline_children[mask.trailing_zeros() as usize])
            }
        }
    }

    /// Insert a child for a token NOT already present.
    pub(crate) fn insert(&mut self, tok: TokenId, child: u32) {
        if let Some(spill) = &mut self.spill {
            let pos = spill
                .binary_search_by_key(&tok, |&(t, _)| t)
                .unwrap_err();
            spill.insert(pos, (tok, child));
            return;
        }
        let len = self.inline_len as usize;
        if len < INLINE_CHILDREN {
            let mut pos = len;
            for i in 0..len {
                if self.inline_tokens[i] > tok {
                    pos = i;
                    break;
                }
            }
            let mut i = len;
            while i > pos {
                self.inline_tokens[i] = self.inline_tokens[i - 1];
                self.inline_children[i] = self.inline_children[i - 1];
                i -= 1;
            }
            self.inline_tokens[pos] = tok;
            self.inline_children[pos] = child;
            self.inline_len = (len + 1) as u8;
        } else {
            // Spill: move everything to one sorted heap vector.
            let mut v: Vec<(TokenId, u32)> = Vec::with_capacity(INLINE_CHILDREN * 2);
            for i in 0..len {
                v.push((self.inline_tokens[i], self.inline_children[i]));
            }
            let pos = v.binary_search_by_key(&tok, |&(t, _)| t).unwrap_err();
            v.insert(pos, (tok, child));
            self.spill = Some(Box::new(v));
            self.inline_len = 0;
        }
    }

    /// Visit children in ascending token order.
    #[inline]
    pub(crate) fn for_each<F: FnMut(TokenId, u32)>(&self, mut f: F) {
        if let Some(spill) = &self.spill {
            for &(t, c) in spill.iter() {
                f(t, c);
            }
        } else {
            for i in 0..self.inline_len as usize {
                f(self.inline_tokens[i], self.inline_children[i]);
            }
        }
    }

    pub(crate) fn len(&self) -> usize {
        match &self.spill {
            Some(spill) => spill.len(),
            None => self.inline_len as usize,
        }
    }

    /// Heap bytes beyond the inline struct (the spill vector, if any).
    pub(crate) fn heap_bytes(&self) -> usize {
        match &self.spill {
            Some(spill) => {
                std::mem::size_of::<Vec<(TokenId, u32)>>()
                    + spill.capacity() * std::mem::size_of::<(TokenId, u32)>()
            }
            None => 0,
        }
    }
}

/// What a trie counts per node. The walk code in [`ArenaTrie`] is generic
/// over this, so the counting suffix trie (plain `u64`), the fused epoch
/// trie (epoch-tagged ring slots) and the prefix router (shard-owner
/// tables) share one implementation of every traversal.
pub trait CountStore: Clone + std::fmt::Debug + Send {
    /// Insert-time context: which stream the bump belongs to (an epoch, a
    /// shard id, or `()` for plain counting).
    type Tag: Copy;
    /// Query-time context: which counts are visible (an epoch filter, or
    /// `()` when everything counts).
    type Filter: Copy;

    /// A fresh store with the same configuration and zero nodes (used by
    /// [`ArenaTrie::compact`] to rebuild).
    fn new_empty(&self) -> Self;
    /// A node was appended to the arena; extend per-node storage.
    fn push_node(&mut self);
    /// Record one occurrence at `node` under `tag`.
    fn bump(&mut self, node: usize, tag: Self::Tag);
    /// Visible weight of `node` under `filter`; 0 means "not present" for
    /// matching purposes (dead epoch, no owners, …).
    fn weight(&self, node: usize, filter: Self::Filter) -> u64;
    /// Append (a copy of) `src`'s payload for node `old` — the compaction
    /// counterpart of [`CountStore::push_node`].
    fn copy_node_from(&mut self, src: &Self, old: usize);
    /// Heap bytes owned by the store (diagnostics).
    fn heap_bytes(&self) -> usize;
}

/// Plain occurrence counting — the [`CountStore`] of the production
/// counting suffix trie (and the reference store for core tests).
#[derive(Debug, Clone, Default)]
pub struct Counts {
    counts: Vec<u64>,
}

impl Counts {
    #[inline]
    pub fn get(&self, node: usize) -> u64 {
        self.counts[node]
    }
}

impl CountStore for Counts {
    type Tag = ();
    type Filter = ();

    fn new_empty(&self) -> Self {
        Counts::default()
    }

    fn push_node(&mut self) {
        self.counts.push(0);
    }

    #[inline]
    fn bump(&mut self, node: usize, _tag: ()) {
        self.counts[node] += 1;
    }

    #[inline]
    fn weight(&self, node: usize, _filter: ()) -> u64 {
        self.counts[node]
    }

    fn copy_node_from(&mut self, src: &Self, old: usize) {
        self.counts.push(src.counts[old]);
    }

    fn heap_bytes(&self) -> usize {
        self.counts.capacity() * std::mem::size_of::<u64>()
    }
}

#[derive(Debug, Clone, Default)]
struct Node {
    children: ChildTable,
    /// Node of this node's string minus its first token; root (0) for
    /// depth-1 nodes. Maintained by `insert_suffixes`; NOT maintained by
    /// `insert_prefix` (prefix-only tries never suffix-match).
    suffix_link: u32,
}

/// Depth-capped arena trie, generic over what each node counts.
#[derive(Debug, Clone)]
pub struct ArenaTrie<S: CountStore> {
    nodes: Vec<Node>,
    store: S,
    max_depth: usize,
}

impl<S: CountStore> ArenaTrie<S> {
    pub fn new(max_depth: usize, mut store: S) -> Self {
        store.push_node(); // root payload
        ArenaTrie {
            nodes: vec![Node::default()],
            store,
            max_depth: max_depth.max(1),
        }
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn store(&self) -> &S {
        &self.store
    }

    pub fn store_mut(&mut self) -> &mut S {
        &mut self.store
    }

    /// Suffix link of `node` (root links to itself). Valid only for tries
    /// built with [`ArenaTrie::insert_suffixes`].
    #[inline]
    pub fn suffix_link(&self, node: usize) -> usize {
        self.nodes[node].suffix_link as usize
    }

    /// Visit `node`'s children in ascending token order.
    pub fn for_each_child<F: FnMut(TokenId, usize)>(&self, node: usize, mut f: F) {
        self.nodes[node].children.for_each(|tok, child| f(tok, child as usize));
    }

    fn get_or_create_child(&mut self, node: usize, tok: TokenId) -> usize {
        if let Some(c) = self.nodes[node].children.get(tok) {
            return c as usize;
        }
        let id = self.nodes.len();
        self.nodes.push(Node::default());
        self.store.push_node();
        self.nodes[node].children.insert(tok, id as u32);
        id
    }

    /// Index every suffix of `tokens` (truncated at `max_depth`), bumping
    /// counts under `tag` along each path — one left-to-right pass.
    ///
    /// The active chain: `deepest` is the node of the longest (depth-capped)
    /// suffix of the processed prefix; its suffix-link chain enumerates
    /// every shorter suffix. Appending a token extends each chain node by
    /// one child (created on demand, link wired to the next chain level),
    /// so there is exactly one child probe per count bump and no root
    /// re-walk per start position.
    pub fn insert_suffixes(&mut self, tokens: &[TokenId], tag: S::Tag) {
        let mut deepest = 0usize;
        let mut depth = 0usize;
        for &tok in tokens {
            // Root counts one occurrence of the empty context per position.
            self.store.bump(0, tag);
            // Deepest parent allowed to grow: depth at most max_depth − 1.
            let mut q = if depth == self.max_depth {
                self.nodes[deepest].suffix_link as usize
            } else {
                deepest
            };
            let mut new_deepest = usize::MAX;
            let mut prev_child = usize::MAX;
            loop {
                let child = self.get_or_create_child(q, tok);
                self.store.bump(child, tag);
                if new_deepest == usize::MAX {
                    new_deepest = child;
                }
                if prev_child != usize::MAX {
                    // The depth-ℓ child's suffix is the depth-(ℓ−1) child.
                    self.nodes[prev_child].suffix_link = child as u32;
                }
                prev_child = child;
                if q == 0 {
                    // Depth-1 child: its suffix is the empty string.
                    self.nodes[prev_child].suffix_link = 0;
                    break;
                }
                q = self.nodes[q].suffix_link as usize;
            }
            deepest = new_deepest;
            depth = (depth + 1).min(self.max_depth);
        }
    }

    /// Index ONLY the prefix path of `tokens` (truncated at `max_depth`),
    /// bumping counts under `tag` along it (the router's registration —
    /// no suffix links, the root is not counted). Returns the deepest node.
    pub fn insert_prefix(&mut self, tokens: &[TokenId], tag: S::Tag) -> usize {
        let mut node = 0usize;
        for &tok in tokens.iter().take(self.max_depth) {
            node = self.get_or_create_child(node, tok);
            self.store.bump(node, tag);
        }
        node
    }

    /// Walk `pattern` exactly from the root; `None` unless fully matched
    /// (structurally — no count filter).
    pub fn locate(&self, pattern: &[TokenId]) -> Option<usize> {
        let mut node = 0usize;
        for &tok in pattern {
            node = self.nodes[node].children.get(tok)? as usize;
        }
        Some(node)
    }

    /// Visit the nodes along `tokens`' depth-capped prefix path (root
    /// excluded), stopping at the first structurally missing child.
    /// Returns how many tokens matched.
    pub fn walk_prefix_path<F: FnMut(usize)>(&self, tokens: &[TokenId], mut f: F) -> usize {
        let mut node = 0usize;
        let mut matched = 0usize;
        for &tok in tokens.iter().take(self.max_depth) {
            let Some(next) = self.nodes[node].children.get(tok) else {
                break;
            };
            node = next as usize;
            matched += 1;
            f(node);
        }
        matched
    }

    /// Deepest node along `context`'s prefix (≤ `max_depth`) whose weight
    /// under `filter` is nonzero; returns `(node, depth)`. Descends through
    /// zero-weight interior nodes (they may have been drained by eviction)
    /// but never reports one.
    pub fn deepest_visible_prefix(
        &self,
        context: &[TokenId],
        filter: S::Filter,
    ) -> Option<(usize, usize)> {
        let mut node = 0usize;
        let mut depth = 0usize;
        let mut best = None;
        for &tok in context.iter().take(self.max_depth) {
            let Some(next) = self.nodes[node].children.get(tok) else {
                break;
            };
            node = next as usize;
            depth += 1;
            if self.store.weight(node, filter) > 0 {
                best = Some((node, depth));
            }
        }
        best
    }

    /// Longest suffix of `context` (length ≤ `max_len`) whose node is
    /// visible under `filter`, as ONE O(m) forward pass over the last
    /// `m = min(len, max_len, max_depth)` context tokens using suffix links
    /// (Aho–Corasick): descend on a visible child, fall back along links on
    /// a miss. Returns `(match_len, node)`; `(0, root)` when nothing
    /// matches. Correct because the visible string set is substring-closed
    /// (see module docs), which makes suffix presence monotone in length.
    pub fn deepest_suffix(
        &self,
        context: &[TokenId],
        max_len: usize,
        filter: S::Filter,
    ) -> (usize, usize) {
        let cap = context.len().min(max_len).min(self.max_depth);
        if cap == 0 {
            return (0, 0);
        }
        let mut node = 0usize;
        let mut depth = 0usize;
        for &tok in &context[context.len() - cap..] {
            loop {
                let next = self.nodes[node]
                    .children
                    .get(tok)
                    .map(|c| c as usize)
                    .filter(|&c| self.store.weight(c, filter) > 0);
                match next {
                    Some(c) => {
                        node = c;
                        depth += 1;
                        break;
                    }
                    None if node == 0 => break,
                    None => {
                        node = self.nodes[node].suffix_link as usize;
                        depth -= 1;
                    }
                }
            }
        }
        (depth, node)
    }

    /// Greedy highest-weight-child walk from `start`: repeatedly step to
    /// the child with the largest visible weight (ties broken toward the
    /// smallest token id via ascending iteration + strict `>`), up to
    /// `budget` tokens. Returns the draft and per-token empirical
    /// confidence `weight(child)/weight(node)`.
    pub fn greedy_walk(
        &self,
        start: usize,
        budget: usize,
        filter: S::Filter,
    ) -> (Vec<TokenId>, Vec<f32>) {
        let mut node = start;
        let mut draft = Vec::with_capacity(budget);
        let mut conf = Vec::with_capacity(budget);
        for _ in 0..budget {
            let parent_w = self.store.weight(node, filter);
            let mut best: Option<(TokenId, usize, u64)> = None;
            self.nodes[node].children.for_each(|tok, child| {
                let w = self.store.weight(child as usize, filter);
                if w == 0 {
                    return; // invisible under this filter
                }
                match best {
                    None => best = Some((tok, child as usize, w)),
                    Some((_, _, bw)) => {
                        if w > bw {
                            best = Some((tok, child as usize, w));
                        }
                    }
                }
            });
            let Some((tok, child, w)) = best else { break };
            draft.push(tok);
            conf.push((w as f64 / parent_w.max(1) as f64) as f32);
            node = child;
        }
        (draft, conf)
    }

    /// Rebuild the arena keeping only nodes for which `keep` is true
    /// (liveness must be ancestor-closed: a kept node's parent is kept).
    /// Payloads are copied verbatim via [`CountStore::copy_node_from`] and
    /// suffix links are recomputed in one BFS — valid because the kept
    /// string set stays substring-closed.
    pub fn compact<F: Fn(&S, usize) -> bool>(&mut self, keep: F) {
        let mut new_nodes: Vec<Node> = Vec::with_capacity(self.nodes.len() / 2 + 1);
        let mut new_store = self.store.new_empty();
        new_nodes.push(Node::default());
        new_store.copy_node_from(&self.store, 0);
        let mut stack: Vec<(usize, usize)> = vec![(0, 0)];
        let mut kept: Vec<(TokenId, usize)> = Vec::new();
        while let Some((old_id, new_id)) = stack.pop() {
            kept.clear();
            self.nodes[old_id].children.for_each(|tok, child| {
                if keep(&self.store, child as usize) {
                    kept.push((tok, child as usize));
                }
            });
            for &(tok, child_old) in &kept {
                let child_new = new_nodes.len();
                new_nodes.push(Node::default());
                new_store.copy_node_from(&self.store, child_old);
                new_nodes[new_id].children.insert(tok, child_new as u32);
                stack.push((child_old, child_new));
            }
        }
        self.nodes = new_nodes;
        self.store = new_store;
        self.rebuild_suffix_links();
    }

    /// BFS recomputation of every suffix link after compaction:
    /// `link(child(u, t)) = child(link(u), t)`. Substring-closure of the
    /// kept set guarantees the target exists; the defensive root fallback
    /// can only shorten matches, never corrupt them.
    fn rebuild_suffix_links(&mut self) {
        let mut queue = std::collections::VecDeque::new();
        let mut kids: Vec<(TokenId, usize)> = Vec::new();
        self.nodes[0].children.for_each(|_tok, c| queue.push_back(c as usize));
        // Depth-1 nodes link to root unconditionally.
        for i in 0..queue.len() {
            let c = queue[i];
            self.nodes[c].suffix_link = 0;
        }
        while let Some(u) = queue.pop_front() {
            let link_u = self.nodes[u].suffix_link as usize;
            kids.clear();
            self.nodes[u].children.for_each(|tok, c| kids.push((tok, c as usize)));
            for &(tok, c) in &kids {
                let target = self.nodes[link_u].children.get(tok);
                debug_assert!(
                    target.is_some(),
                    "substring closure violated: missing suffix-link target"
                );
                self.nodes[c].suffix_link = target.unwrap_or(0);
                queue.push_back(c);
            }
        }
    }

    /// Approximate heap bytes (arena + child spill + store).
    pub fn approx_bytes(&self) -> usize {
        self.nodes.len() * std::mem::size_of::<Node>()
            + self
                .nodes
                .iter()
                .map(|n| n.children.heap_bytes())
                .sum::<usize>()
            + self.store.heap_bytes()
    }

    /// Total child-table entries (diagnostics).
    pub fn edge_count(&self) -> usize {
        self.nodes.iter().map(|n| n.children.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn plain(max_depth: usize) -> ArenaTrie<Counts> {
        ArenaTrie::new(max_depth, Counts::default())
    }

    #[test]
    fn child_table_inline_and_spill_paths() {
        let mut t = ChildTable::default();
        for (i, tok) in [7u32, 3, 9, 1, 12, 5, 20, 15].iter().enumerate() {
            t.insert(*tok, i as u32 + 10);
        }
        assert_eq!(t.len(), 8);
        assert_eq!(t.get(3), Some(11));
        assert_eq!(t.get(2), None);
        // Ninth child spills to the sorted vector.
        t.insert(4, 99);
        assert_eq!(t.len(), 9);
        let mut order = Vec::new();
        t.for_each(|tok, _| order.push(tok));
        assert_eq!(order, vec![1, 3, 4, 5, 7, 9, 12, 15, 20]);
        assert_eq!(t.get(4), Some(99));
        assert_eq!(t.get(7), Some(10));
        assert!(t.heap_bytes() > 0);
    }

    #[test]
    fn child_table_branchless_probe_matches_linear() {
        // The masked probe must behave exactly like a linear scan for every
        // fill level, including token id 0 in and out of the table.
        for fill in 0..=INLINE_CHILDREN {
            let mut t = ChildTable::default();
            let toks: Vec<u32> = (0..fill as u32).map(|i| i * 3).collect();
            for (i, &tok) in toks.iter().enumerate() {
                t.insert(tok, 100 + i as u32);
            }
            for probe in 0..30u32 {
                let expect = toks.iter().position(|&x| x == probe).map(|i| 100 + i as u32);
                assert_eq!(t.get(probe), expect, "fill={fill} probe={probe}");
            }
        }
    }

    #[test]
    fn insert_suffixes_counts_are_occurrences() {
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 1, 2, 3], ());
        let count = |p: &[u32]| t.locate(p).map(|n| t.store().get(n)).unwrap_or(0);
        assert_eq!(count(&[1, 2]), 2);
        assert_eq!(count(&[1, 2, 3]), 1);
        assert_eq!(count(&[2, 1]), 1);
        assert_eq!(count(&[3, 1]), 0);
        assert_eq!(t.store().get(0), 5, "root counts one per position");
    }

    #[test]
    fn suffix_links_point_to_one_shorter_suffix() {
        let mut t = plain(6);
        t.insert_suffixes(&[4, 7, 9, 7, 9], ());
        // Node for [4,7,9] links to [7,9] links to [9] links to root.
        let n479 = t.locate(&[4, 7, 9]).unwrap();
        let n79 = t.locate(&[7, 9]).unwrap();
        let n9 = t.locate(&[9]).unwrap();
        assert_eq!(t.suffix_link(n479), n79);
        assert_eq!(t.suffix_link(n79), n9);
        assert_eq!(t.suffix_link(n9), 0);
    }

    #[test]
    fn deepest_suffix_single_pass_matches_bruteforce() {
        let mut t = plain(6);
        t.insert_suffixes(&[1, 2, 3, 4], ());
        t.insert_suffixes(&[9, 2, 3, 7], ());
        // Context ends ...2,3,4 → longest suffix [2,3,4] (depth 3).
        let (len, node) = t.deepest_suffix(&[8, 8, 2, 3, 4], 6, ());
        assert_eq!(len, 3);
        assert_eq!(node, t.locate(&[2, 3, 4]).unwrap());
        // max_len cap applies.
        let (len, node) = t.deepest_suffix(&[8, 8, 2, 3, 4], 2, ());
        assert_eq!(len, 2);
        assert_eq!(node, t.locate(&[3, 4]).unwrap());
        // Unseen suffix falls back through links to the seen tail.
        let (len, _) = t.deepest_suffix(&[1, 2, 99], 6, ());
        assert_eq!(len, 0);
        let (len, _) = t.deepest_suffix(&[99, 2, 3], 6, ());
        assert_eq!(len, 2);
    }

    #[test]
    fn greedy_walk_majority_and_tiebreak() {
        let mut t = plain(8);
        t.insert_suffixes(&[5, 7, 1], ());
        t.insert_suffixes(&[5, 7, 2], ());
        t.insert_suffixes(&[5, 9, 3], ());
        let n5 = t.locate(&[5]).unwrap();
        let (draft, conf) = t.greedy_walk(n5, 1, ());
        assert_eq!(draft, vec![7]);
        assert!((conf[0] - 2.0 / 3.0).abs() < 1e-6);
        // Equal counts: smallest token id wins.
        let mut t = plain(8);
        t.insert_suffixes(&[5, 7], ());
        t.insert_suffixes(&[5, 3], ());
        let n5 = t.locate(&[5]).unwrap();
        assert_eq!(t.greedy_walk(n5, 4, ()).0, vec![3, /* then nothing */]);
    }

    #[test]
    fn prefix_insert_and_visible_prefix() {
        let mut t = plain(4);
        t.insert_prefix(&[10, 11, 12, 13, 99], ()); // truncated at depth 4
        assert!(t.locate(&[10, 11, 12, 13]).is_some());
        assert!(t.locate(&[10, 11, 12, 13, 99]).is_none());
        let (node, depth) = t.deepest_visible_prefix(&[10, 11, 20], ()).unwrap();
        assert_eq!(depth, 2);
        assert_eq!(node, t.locate(&[10, 11]).unwrap());
        assert!(t.deepest_visible_prefix(&[7], ()).is_none());
        let mut seen = Vec::new();
        let matched = t.walk_prefix_path(&[10, 11, 77], |n| seen.push(n));
        assert_eq!(matched, 2);
        assert_eq!(seen.len(), 2);
    }

    #[test]
    fn compact_keeps_weighted_nodes_and_links() {
        let mut t = plain(6);
        t.insert_suffixes(&[1, 2, 3], ());
        t.insert_suffixes(&[4, 2, 3], ());
        let before = t.node_count();
        // Keep everything: structure and answers unchanged, links intact.
        t.compact(|s, n| s.weight(n, ()) > 0);
        assert_eq!(t.node_count(), before);
        let (len, node) = t.deepest_suffix(&[9, 4, 2, 3], 6, ());
        assert_eq!(len, 3);
        assert_eq!(t.suffix_link(node), t.locate(&[2, 3]).unwrap());
        // Further inserts after compaction keep working.
        t.insert_suffixes(&[4, 2, 3, 5], ());
        let (len, _) = t.deepest_suffix(&[4, 2, 3, 5], 6, ());
        assert_eq!(len, 4);
    }

    #[test]
    fn prop_deepest_suffix_equals_descending_rescan() {
        // The O(m) suffix-link pass must find exactly the length the naive
        // longest-first rescan finds.
        prop::check(128, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let depth = 2 + g.usize_in(0, 8);
            let mut t = ArenaTrie::new(depth, Counts::default());
            for _ in 0..g.usize_in(1, 4) {
                t.insert_suffixes(&g.vec_u32_nonempty(alphabet, 40), ());
            }
            let ctx = g.vec_u32_nonempty(alphabet, 20);
            let max_len = 1 + g.usize_in(0, 10);
            let naive = {
                let cap = ctx.len().min(max_len).min(t.max_depth());
                let mut best = 0;
                for take in (1..=cap).rev() {
                    if t.locate(&ctx[ctx.len() - take..]).is_some() {
                        best = take;
                        break;
                    }
                }
                best
            };
            prop::require_eq(
                t.deepest_suffix(&ctx, max_len, ()).0,
                naive,
                "suffix-link pass vs rescan",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_suffix_links_always_valid() {
        // Every non-root node's link must name the node of its string minus
        // the first token — checked by replaying paths.
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 3) as u32;
            let mut t = ArenaTrie::new(2 + g.usize_in(0, 5), Counts::default());
            let mut rollouts = Vec::new();
            for _ in 0..g.usize_in(1, 3) {
                let r = g.vec_u32_nonempty(alphabet, 25);
                t.insert_suffixes(&r, ());
                rollouts.push(r);
            }
            // Enumerate some indexed paths and verify link(path) == path[1..].
            for r in &rollouts {
                for start in 0..r.len().min(6) {
                    let end = (start + t.max_depth()).min(r.len());
                    let path = &r[start..end];
                    if path.len() < 2 {
                        continue;
                    }
                    let node = t.locate(path).expect("indexed path");
                    let link = t.suffix_link(node);
                    let expect = t.locate(&path[1..]).expect("suffix path indexed");
                    prop::require_eq(link, expect, "suffix link target")?;
                }
            }
            Ok(())
        });
    }
}

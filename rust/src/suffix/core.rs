//! The single arena-trie core every suffix walk in this crate runs on —
//! now **path-compressed** with a shared, deduplicating token-segment pool.
//!
//! Before this module existed the repo carried three hand-rolled copies of
//! the same trie machinery — [`super::trie::SuffixTrieIndex`], the fused
//! epoch trie in [`super::window`], and the HashMap prefix trie in
//! [`super::router`] — that differed only in *what they count per node*.
//! They were unified behind [`CountStore`] (PR 2); this revision collapses
//! the one-node-per-token layout into a radix-style compressed trie, because
//! rollouts of the same problem share long common prefixes and reasoning
//! boilerplate, and a per-token arena burns node count, insert time and
//! cache footprint on redundant unary chains.
//!
//! # Layout
//!
//! Nodes live in one bump-allocated arena (`Vec`, ids are indices, root is
//! node 0). A node's incoming edge carries a **multi-token label** stored as
//! a [`SegRef`] — a `(segment, start, len)` sub-range of a [`SegmentPool`]:
//! an append-only token store deduplicated by a cheap hash-cons (interning a
//! rollout that was seen before, e.g. the same problem re-sampled across
//! epochs, adds **zero** bytes). The pool is shared — one [`SharedPool`]
//! can back every shard of a drafter (and its prefix router), so identical
//! rollout content is stored once process-wide, not once per shard. Pool
//! segments are reference-counted by the edges that use them; segments
//! whose count drops to zero (trie compaction, dropped shards) are dead,
//! and the pool rewrites itself to drop dead bytes once they dominate.
//!
//! Child edges still use [`ChildTable`] keyed by the edge label's FIRST
//! token: up to [`INLINE_CHILDREN`] children as parallel sorted arrays
//! inside the node (branchless fixed-trip-count probe), spilling to a
//! sorted heap `Vec` for high-fanout nodes.
//!
//! # Counts on a compressed trie
//!
//! Per-node counts live in the [`CountStore`]. The key invariant that makes
//! counting correct with multi-token edges:
//!
//! > **Every position strictly inside an edge `u → v` has exactly the
//! > counts of `v`.**
//!
//! It holds by construction: an edge is **split** (a new explicit node is
//! inserted, its row initialized as a *copy* of the lower node's via
//! [`CountStore::split_node`]) whenever (a) two paths diverge mid-edge, or
//! (b) an insertion *terminates* mid-edge — so any bump that would have
//! differentiated an interior position forces that position to become
//! explicit first. Consequently a mid-edge position can answer weight /
//! epoch-row / owner-table queries by reading the edge's lower node, and
//! every walk below is bit-identical to the uncompressed per-token trie
//! (property-tested against an uncompressed reference).
//!
//! Positions (explicit or mid-edge) are represented as [`TriePos`].
//!
//! # Mutating walks: one [`EdgeCursor`]
//!
//! Every walk that may *modify* the trie — suffix indexing
//! ([`ArenaTrie::insert_suffixes`]), prefix registration
//! ([`ArenaTrie::insert_prefix`]) and the unregister path
//! ([`ArenaTrie::prefix_path_split`]) — is a thin driver over one shared
//! [`EdgeCursor`]: the single implementation of the
//! probe → label-compare → split-on-divergence/terminal → add-leaf step.
//! The cursor owns the *mechanics*; drivers own only *policy*. The
//! division of labor is load-bearing:
//!
//! * **Who compares:** [`EdgeCursor::probe`] classifies one step. The
//!   label comparison starts at index 1 — the [`ChildTable`] is keyed by
//!   each edge label's FIRST token, so a probed child's `label[0]` equals
//!   the next target token by construction (debug-asserted). No driver
//!   re-compares token 0.
//! * **Who retains pool segments:** the cursor, exactly once per edge it
//!   creates. [`EdgeCursor::add_leaf`] retains the driver's interned
//!   segment for the one new leaf edge; [`ArenaTrie::split_edge`] retains
//!   the split edge's segment once because one edge became two. Drivers
//!   never touch refcounts (they only `release_if_unused` the segment they
//!   interned, in case the walk created no edges).
//! * **Who bumps:** drivers, never the cursor. `insert_suffixes` bumps the
//!   root once per start position (ε occurs at every position) and every
//!   explicit node its walk touches or creates; `insert_prefix` bumps the
//!   same way but NEVER the root (the router does not count ε);
//!   `prefix_path_split` bumps nothing at all (the router un-bumps the
//!   returned path itself). Bumps always happen AFTER a split: the new
//!   upper node must copy the lower node's **pre-bump** row
//!   ([`CountStore::split_node`]) or interior positions of the old edge
//!   would inherit counts they never saw.
//! * **Who may split:** the insert drivers split on BOTH divergence and
//!   mid-edge termination (the compressed-counting invariant above).
//!   `prefix_path_split` is read-mostly: it refuses divergence (`None`,
//!   nothing modified, no leaf ever) and splits only the terminal boundary
//!   of a fully present prefix, so un-bumps hit exactly the registration's
//!   explicit nodes.
//! * **Who maintains links:** only `insert_suffixes` resolves the pending
//!   suffix links of newly created nodes (against the next start's walk)
//!   and may trigger the exact-link refresh below. Prefix-only tries are
//!   not substring-closed, so their links are meaningless and must never
//!   be rebuilt.
//!
//! # Snapshot reads
//!
//! Draft-serving reads run LOCK-FREE against published snapshots
//! ([`TrieSnapshot`], built by [`ArenaTrie::publish`]): the node arena and
//! the pool's slot table are chunked copy-on-write vectors
//! ([`crate::util::cow::CowVec`]), so publication is O(chunks touched since
//! the last publish) pointer copies, and segment content is immutable
//! behind `Arc`s — a reader can never observe a torn or moved label no
//! matter what the writer interns, splits or frees after the publish. The
//! snapshot API takes only `&self` over `Arc`-shared state, so holding a
//! lock on the draft path is unrepresentable by construction. Invariants:
//!
//! * **Single writer.** All mutation stays on `&mut ArenaTrie` (one writer
//!   per shard); readers hold `Arc<TrieSnapshot>`s and never synchronize
//!   with the writer or each other.
//! * **Publish points are the unit of visibility.** A snapshot is the trie
//!   exactly as of its `publish` call; every read against it is
//!   bit-identical to the locked walk on the writer at that instant
//!   (property-tested below), and later writer mutations are invisible.
//! * **One read implementation, two label sources.** Every read walk is a
//!   single implementation generic over [`Labels`] (locked [`SegmentPool`]
//!   vs [`PoolSnapshot`]), so the locked and lock-free paths cannot drift.
//! * **Stats ride the publish.** [`ArenaTrie::publish`] stamps the
//!   snapshot with precomputed size gauges ([`SnapshotStats`]) maintained
//!   incrementally by the writer — consumers never rescan the arena.
//!
//! # Suffix links
//!
//! Explicit node `v` stores `slink(v)`: an explicit node whose string is a
//! prefix of `str(v)` minus its first token — *at-or-above* the (possibly
//! implicit) suffix position. Root is always a valid target, so links are
//! best-effort tight, never load-bearing for correctness: the O(m)
//! deepest-suffix scan (Aho–Corasick over compressed edges) falls back via
//! `slink` and **re-descends by skip/count** — per-edge jumps choosing
//! children by first token only, with no label comparisons, because the
//! string set is substring-closed (every substring ≤ the depth cap of
//! anything inserted via [`ArenaTrie::insert_suffixes`] is itself a path).
//! [`ArenaTrie::compact`] recomputes exact links in one arena pass — and so
//! does a threshold-triggered refresh for tries that never compact: every
//! node created (leaf or split) counts toward `links_dirty`, and once the
//! approximate links cover half the arena, `insert_suffixes` runs
//! [`ArenaTrie::rebuild_suffix_links`] itself. This closes the `window_all`
//! hole (unbounded epoch tries never evict, hence never compacted, so their
//! split links used to stay parent-fallback-approximate forever); the
//! rebuild is O(arena) and the trigger is geometric, so the amortized cost
//! per created node is constant.
//!
//! # Cost model
//!
//! * `insert_suffixes`: one skip/count walk per start position — O(edges on
//!   the path) child probes plus one label comparison run; count bumps are
//!   per *explicit node*, not per token, so shared-prefix content pays a
//!   few bumps per position instead of `max_depth`.
//! * deepest-suffix match: single O(m) forward pass, amortized via links.
//! * greedy draft walk: O(budget) — inside an edge the continuation is
//!   forced (no probe at all); at nodes one branchless table scan.
//! * memory: nodes ∝ branching + termination points (not tokens); label
//!   bytes interned and deduplicated across every trie sharing the pool.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::store::wire::{Reader, StoreError, Writer};
use crate::tokens::TokenId;
use crate::util::cow::CowVec;

/// Children stored inline per node before spilling to a sorted heap vector.
/// 8 slots are one u32x8 compare, and deeper-than-root trie nodes almost
/// never exceed it.
pub(crate) const INLINE_CHILDREN: usize = 8;

/// Below this arena size the `links_dirty` exact-link refresh never fires:
/// on tiny tries the O(arena) rebuild costs more than the short re-descents
/// approximate links cause. Tries that compact get exact links there anyway.
const LINK_REBUILD_MIN_NODES: usize = 512;

/// Sorted child table: inline small-array storage with sorted-`Vec` spill.
/// Keys are the FIRST token of each child's edge label.
///
/// Iteration order is always ascending token id, which the draft walks rely
/// on for deterministic smallest-token tie-breaking.
#[derive(Debug, Clone, Default)]
pub(crate) struct ChildTable {
    inline_len: u8,
    inline_tokens: [TokenId; INLINE_CHILDREN],
    inline_children: [u32; INLINE_CHILDREN],
    /// Sorted by token; `Some` once fanout exceeds `INLINE_CHILDREN` (the
    /// inline arrays are then no longer authoritative).
    spill: Option<Box<Vec<(TokenId, u32)>>>,
}

impl ChildTable {
    #[inline]
    pub(crate) fn get(&self, tok: TokenId) -> Option<u32> {
        if let Some(spill) = &self.spill {
            match spill.binary_search_by_key(&tok, |&(t, _)| t) {
                Ok(i) => Some(spill[i].1),
                Err(_) => None,
            }
        } else {
            // Branchless probe: compare ALL slots (fixed trip count, no
            // early exit), mask to the live prefix, extract the unique hit.
            let mut mask = 0u32;
            for i in 0..INLINE_CHILDREN {
                mask |= ((self.inline_tokens[i] == tok) as u32) << i;
            }
            mask &= (1u32 << self.inline_len) - 1;
            if mask == 0 {
                None
            } else {
                Some(self.inline_children[mask.trailing_zeros() as usize])
            }
        }
    }

    /// Insert a child for a token NOT already present.
    pub(crate) fn insert(&mut self, tok: TokenId, child: u32) {
        if let Some(spill) = &mut self.spill {
            let pos = spill
                .binary_search_by_key(&tok, |&(t, _)| t)
                .unwrap_err();
            spill.insert(pos, (tok, child));
            return;
        }
        let len = self.inline_len as usize;
        if len < INLINE_CHILDREN {
            let mut pos = len;
            for i in 0..len {
                if self.inline_tokens[i] > tok {
                    pos = i;
                    break;
                }
            }
            let mut i = len;
            while i > pos {
                self.inline_tokens[i] = self.inline_tokens[i - 1];
                self.inline_children[i] = self.inline_children[i - 1];
                i -= 1;
            }
            self.inline_tokens[pos] = tok;
            self.inline_children[pos] = child;
            self.inline_len = (len + 1) as u8;
        } else {
            // Spill: move everything to one sorted heap vector.
            let mut v: Vec<(TokenId, u32)> = Vec::with_capacity(INLINE_CHILDREN * 2);
            for i in 0..len {
                v.push((self.inline_tokens[i], self.inline_children[i]));
            }
            let pos = v.binary_search_by_key(&tok, |&(t, _)| t).unwrap_err();
            v.insert(pos, (tok, child));
            self.spill = Some(Box::new(v));
            self.inline_len = 0;
        }
    }

    /// Repoint an EXISTING token's child (edge splitting rewires the upper
    /// half of the split edge in place).
    pub(crate) fn set(&mut self, tok: TokenId, child: u32) {
        if let Some(spill) = &mut self.spill {
            if let Ok(i) = spill.binary_search_by_key(&tok, |&(t, _)| t) {
                spill[i].1 = child;
                return;
            }
        } else {
            for i in 0..self.inline_len as usize {
                if self.inline_tokens[i] == tok {
                    self.inline_children[i] = child;
                    return;
                }
            }
        }
        debug_assert!(false, "ChildTable::set on a missing token");
        self.insert(tok, child);
    }

    /// Visit children in ascending token order.
    #[inline]
    pub(crate) fn for_each<F: FnMut(TokenId, u32)>(&self, mut f: F) {
        if let Some(spill) = &self.spill {
            for &(t, c) in spill.iter() {
                f(t, c);
            }
        } else {
            for i in 0..self.inline_len as usize {
                f(self.inline_tokens[i], self.inline_children[i]);
            }
        }
    }

    pub(crate) fn len(&self) -> usize {
        match &self.spill {
            Some(spill) => spill.len(),
            None => self.inline_len as usize,
        }
    }

    /// Heap bytes beyond the inline struct (the spill vector, if any).
    /// Length-based (not capacity) so the gauge is a pure function of
    /// content — a snapshot-restored table reports identical bytes.
    pub(crate) fn heap_bytes(&self) -> usize {
        match &self.spill {
            Some(spill) => {
                std::mem::size_of::<Vec<(TokenId, u32)>>()
                    + spill.len() * std::mem::size_of::<(TokenId, u32)>()
            }
            None => 0,
        }
    }
}

// ---------------------------------------------------------------------------
// Token-segment pool (interned edge labels)
// ---------------------------------------------------------------------------

/// A sub-range of one pool segment: the label of a trie edge.
/// `start`/`len` are relative to the segment, whose content is immutable
/// once interned — a `SegRef` resolves to the same tokens for its entire
/// lifetime, on the writer and on every published snapshot.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SegRef {
    seg: u32,
    start: u32,
    len: u32,
}

impl SegRef {
    pub(crate) const EMPTY: SegRef = SegRef { seg: 0, start: 0, len: 0 };
}

/// One live pool segment: its token content behind an `Arc` — shared
/// verbatim with every published [`PoolSnapshot`], so freeing or reusing
/// the slot on the writer can never invalidate a reader's label — plus the
/// edge refcount (writer-side bookkeeping only).
#[derive(Debug, Clone)]
struct SegSlot {
    data: Arc<Vec<TokenId>>,
    /// Number of trie edges referencing (a sub-range of) this segment.
    rc: u32,
}

/// Live-vs-allocated byte accounting of a [`SharedPool`] (diagnostics; the
/// node/segment/byte telemetry gauges read this).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    /// Live (referenced) interned segments.
    pub segments: usize,
    /// Tokens held by live segments.
    pub live_tokens: usize,
    /// Always 0: per-segment storage frees a segment's tokens the moment
    /// its refcount drops to zero (kept for gauge-schema stability).
    pub dead_tokens: usize,
    /// Approximate heap bytes owned by the pool (token store + metadata).
    pub heap_bytes: usize,
}

/// Hash-consed token store backing every edge label of the tries that
/// share it. Interning content that is already present returns the
/// existing segment (zero growth) — the shared-prefix win for repeated
/// same-problem rollouts. Segments are refcounted by edges and freed the
/// moment their refcount reaches zero; content lives behind per-segment
/// `Arc`s in a copy-on-write slot table, so publishing a [`PoolSnapshot`]
/// is O(chunks touched since the last publish) and snapshots keep freed
/// segments alive for as long as any reader holds them.
#[derive(Debug, Default)]
pub(crate) struct SegmentPool {
    /// Slot table; `None` is a dead/free slot.
    slots: CowVec<Option<SegSlot>>,
    /// Content hash → candidate segment ids (verified on collision).
    by_hash: HashMap<u64, Vec<u32>>,
    /// Dead slots available for reuse.
    free: Vec<u32>,
    live_segs: usize,
    live_toks: usize,
}

fn hash_tokens(toks: &[TokenId]) -> u64 {
    let mut h = DefaultHasher::new();
    toks.hash(&mut h);
    h.finish()
}

impl SegmentPool {
    /// Intern `toks`, returning a segment id whose content equals `toks`.
    /// The returned segment may have `rc == 0` (fresh); callers retain it
    /// per edge created and should [`SegmentPool::release_if_unused`] after
    /// an insertion that created no edges.
    pub(crate) fn intern(&mut self, toks: &[TokenId]) -> u32 {
        debug_assert!(!toks.is_empty());
        let h = hash_tokens(toks);
        if let Some(cands) = self.by_hash.get(&h) {
            for &id in cands {
                if let Some(slot) = &self.slots[id as usize] {
                    if slot.data.as_slice() == toks {
                        return id;
                    }
                }
            }
        }
        let slot = Some(SegSlot { data: Arc::new(toks.to_vec()), rc: 0 });
        let id = match self.free.pop() {
            Some(id) => {
                self.slots[id as usize] = slot;
                id
            }
            None => {
                self.slots.push(slot);
                (self.slots.len() - 1) as u32
            }
        };
        self.by_hash.entry(h).or_default().push(id);
        self.live_segs += 1;
        self.live_toks += toks.len();
        id
    }

    #[inline]
    pub(crate) fn retain(&mut self, seg: u32) {
        let slot = self.slots[seg as usize]
            .as_mut()
            // audit: allow(panic-path) -- refcount invariant; a dead segment here is a trie bug
            .expect("retain on a dead segment");
        slot.rc += 1;
    }

    pub(crate) fn release(&mut self, seg: u32) {
        let slot = self.slots[seg as usize]
            .as_mut()
            // audit: allow(panic-path) -- refcount invariant; a dead segment here is a trie bug
            .expect("release on a dead segment");
        debug_assert!(slot.rc > 0, "segment over-released");
        slot.rc -= 1;
        if slot.rc == 0 {
            self.kill(seg);
        }
    }

    /// Free a freshly interned segment that ended up with no edges (the
    /// inserted content was already fully present in the trie).
    pub(crate) fn release_if_unused(&mut self, seg: u32) {
        if let Some(slot) = &self.slots[seg as usize] {
            if slot.rc == 0 {
                self.kill(seg);
            }
        }
    }

    fn kill(&mut self, seg: u32) {
        let slot = self.slots[seg as usize]
            .take()
            // audit: allow(panic-path) -- refcount invariant; a dead segment here is a trie bug
            .expect("kill on a dead segment");
        let h = hash_tokens(&slot.data);
        if let Some(c) = self.by_hash.get_mut(&h) {
            c.retain(|&id| id != seg);
            if c.is_empty() {
                self.by_hash.remove(&h);
            }
        }
        self.free.push(seg);
        self.live_segs -= 1;
        self.live_toks -= slot.data.len();
        // `slot.data` drops here — unless a published snapshot still holds
        // the Arc, in which case the content outlives the slot for exactly
        // as long as readers need it.
    }

    /// Token slice of an edge label. Safe for [`SegRef::EMPTY`].
    #[inline]
    pub(crate) fn slice(&self, r: SegRef) -> &[TokenId] {
        if r.len == 0 {
            return &[];
        }
        let slot = self.slots[r.seg as usize]
            .as_ref()
            // audit: allow(panic-path) -- refcount invariant; a dead segment here is a trie bug
            .expect("slice of a dead segment");
        let a = r.start as usize;
        &slot.data[a..a + r.len as usize]
    }

    /// Publish an immutable view of the slot table: O(chunks) pointer
    /// copies, after which the writer copies only the chunks it next
    /// touches ([`CowVec`] copy-on-write).
    pub(crate) fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot { slots: self.slots.clone() }
    }

    pub(crate) fn stats(&self) -> PoolStats {
        PoolStats {
            segments: self.live_segs,
            live_tokens: self.live_toks,
            dead_tokens: 0,
            heap_bytes: self.live_toks * std::mem::size_of::<TokenId>()
                + self.slots.len() * std::mem::size_of::<Option<SegSlot>>()
                + self.by_hash.len()
                    * (std::mem::size_of::<u64>() + std::mem::size_of::<Vec<u32>>() + 16),
        }
    }

    /// Length of a live segment; `None` for dead/free/out-of-range slots
    /// (snapshot-load validation of edge `SegRef`s).
    pub(crate) fn seg_len(&self, seg: u32) -> Option<u32> {
        self.slots
            .get(seg as usize)?
            .as_ref()
            .map(|s| s.data.len() as u32)
    }

    /// Current edge refcount of a segment (0 for dead slots; `das store
    /// verify` compares these against the snapshot's recorded counts).
    pub(crate) fn refcount(&self, seg: u32) -> u32 {
        self.slots
            .get(seg as usize)
            .and_then(|s| s.as_ref())
            .map(|s| s.rc)
            .unwrap_or(0)
    }

    /// Serialize every LIVE segment — id, recorded edge refcount, content.
    /// Dead slots occupy no stream bytes: the loaded pool holds exactly the
    /// live content under the same ids.
    pub(crate) fn save_state(&self, w: &mut Writer) {
        w.str("pool");
        w.usize(self.slots.len());
        w.usize(self.live_segs);
        for (id, slot) in self.slots.iter().enumerate() {
            let Some(slot) = slot else { continue };
            w.u32(id as u32);
            w.u32(slot.rc);
            w.tokens(&slot.data);
        }
    }

    /// Rebuild a pool from [`SegmentPool::save_state`]. Segment IDS ARE
    /// PRESERVED (edge `SegRef`s in the trie sections refer to them), the
    /// hash-cons table is rebuilt, and every refcount starts at 0 — each
    /// deserialized trie edge re-retains its segment, re-deriving the
    /// counts from the structures that actually loaded. Returns the pool
    /// plus the RECORDED `(segment, refcount)` pairs for verification.
    pub(crate) fn load_state(
        r: &mut Reader<'_>,
    ) -> Result<(SegmentPool, Vec<(u32, u32)>), StoreError> {
        r.expect_str("pool", "pool section tag")?;
        // Slot-table size (NOT stream-bounded: dead slots occupy no bytes).
        // Slot ids stay compact — the free list reuses dead slots before
        // growing the table — so an absurd size is corruption, not scale.
        let slots = r.usize()?;
        if slots > (1 << 26) {
            return Err(StoreError::Corrupt(format!("pool slot table too large: {slots}")));
        }
        let live = r.count(12)?;
        if live > slots {
            return Err(StoreError::Corrupt(format!(
                "pool live segments ({live}) > slots ({slots})"
            )));
        }
        let mut pool = SegmentPool::default();
        for _ in 0..slots {
            pool.slots.push(None);
        }
        let mut recorded: Vec<(u32, u32)> = Vec::with_capacity(live);
        for _ in 0..live {
            let id = r.u32()?;
            let rc = r.u32()?;
            let toks = r.tokens()?;
            if id as usize >= slots {
                return Err(StoreError::Corrupt(format!("pool segment id {id} out of range")));
            }
            if pool.slots[id as usize].is_some() {
                return Err(StoreError::Corrupt(format!("pool segment id {id} duplicated")));
            }
            if toks.is_empty() {
                return Err(StoreError::Corrupt(format!("pool segment id {id} is empty")));
            }
            pool.by_hash.entry(hash_tokens(&toks)).or_default().push(id);
            pool.live_toks += toks.len();
            pool.slots[id as usize] = Some(SegSlot { data: Arc::new(toks), rc: 0 });
            recorded.push((id, rc));
        }
        pool.live_segs = live;
        pool.free = (0..slots as u32)
            .filter(|&i| pool.slots[i as usize].is_none())
            .collect();
        Ok((pool, recorded))
    }
}

/// An immutable, lock-free view of the pool's slot table, published by
/// [`SegmentPool::snapshot`] under the writer's lock. Segment content is
/// behind `Arc`s that are immutable once interned, so a reader can never
/// observe a torn, moved or reused label no matter what the writer interns
/// or frees after the publish — freed segments simply outlive their slot
/// until the last snapshot holding them drops.
#[derive(Debug, Clone, Default)]
pub struct PoolSnapshot {
    slots: CowVec<Option<SegSlot>>,
}

/// Where a read walk resolves edge-label [`SegRef`]s: the live (locked)
/// [`SegmentPool`] on the writer path, or a [`PoolSnapshot`] on the
/// lock-free draft path. Every read walk is one implementation generic
/// over this trait (see [`TrieRead`]), so the two paths are bit-identical
/// by construction.
pub(crate) trait Labels {
    fn slice(&self, r: SegRef) -> &[TokenId];
}

impl Labels for SegmentPool {
    #[inline]
    fn slice(&self, r: SegRef) -> &[TokenId] {
        SegmentPool::slice(self, r)
    }
}

impl Labels for PoolSnapshot {
    #[inline]
    fn slice(&self, r: SegRef) -> &[TokenId] {
        if r.len == 0 {
            return &[];
        }
        let slot = self.slots[r.seg as usize]
            .as_ref()
            // audit: allow(panic-path) -- snapshots pin their Arcs; a dead slot here is a bug
            .expect("snapshot slice of a dead segment");
        let a = r.start as usize;
        &slot.data[a..a + r.len as usize]
    }
}

/// Cloneable handle to a [`SegmentPool`] shared by any number of tries
/// (e.g. every history shard of a drafter plus its prefix router). Interior
/// mutability via a mutex: every public trie operation locks once — shards
/// are driven from one thread at a time, so the lock is uncontended; it
/// exists so the drafter stays `Send`.
#[derive(Debug, Clone, Default)]
pub struct SharedPool {
    inner: Arc<Mutex<SegmentPool>>,
}

impl SharedPool {
    pub fn new() -> Self {
        SharedPool::default()
    }

    pub(crate) fn lock(&self) -> MutexGuard<'_, SegmentPool> {
        // Poison recovery: pool mutations are self-contained, and aborting
        // inside `ArenaTrie::drop` on an unrelated panic would be worse.
        match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    pub fn stats(&self) -> PoolStats {
        self.lock().stats()
    }

    /// Publish an immutable, lock-free view of the pool (one lock for the
    /// O(chunks) slot-table clone; reads against the snapshot never lock).
    pub fn snapshot(&self) -> PoolSnapshot {
        self.lock().snapshot()
    }

    /// Serialize the pool's live segments (ids, recorded refcounts,
    /// content) as one `das-store-v1` section.
    pub fn save_state(&self, w: &mut Writer) {
        self.lock().save_state(w);
    }

    /// Rebuild a pool from [`SharedPool::save_state`] with segment ids
    /// preserved and all refcounts ZERO — deserialized trie edges re-retain
    /// as they load. Returns the recorded `(segment, refcount)` pairs;
    /// finish with [`SharedPool::reconcile_recorded`] once every consumer
    /// has loaded.
    pub fn load_state(r: &mut Reader<'_>) -> Result<(SharedPool, Vec<(u32, u32)>), StoreError> {
        let (pool, recorded) = SegmentPool::load_state(r)?;
        Ok((
            SharedPool {
                inner: Arc::new(Mutex::new(pool)),
            },
            recorded,
        ))
    }

    /// After every snapshot consumer has loaded: drop segments no loaded
    /// edge references (e.g. labels of the ephemeral request-local indexes
    /// that are not persisted) and return how many recorded refcounts
    /// disagree with the re-derived ones (0 for a quiescent snapshot —
    /// `das store verify` surfaces this).
    pub fn reconcile_recorded(&self, recorded: &[(u32, u32)]) -> usize {
        let mut pg = self.lock();
        let mut mismatches = 0usize;
        for &(id, rc) in recorded {
            if pg.refcount(id) != rc {
                mismatches += 1;
            }
            pg.release_if_unused(id);
        }
        mismatches
    }
}

// ---------------------------------------------------------------------------
// CountStore
// ---------------------------------------------------------------------------

/// What a trie counts per node. The walk code in [`ArenaTrie`] is generic
/// over this, so the counting suffix trie (plain `u64`), the fused epoch
/// trie (per-epoch rows) and the prefix router (shard-owner tables) share
/// one implementation of every traversal.
pub trait CountStore: Clone + std::fmt::Debug + Send {
    /// Insert-time context: which stream the bump belongs to (an epoch, a
    /// shard id, or `()` for plain counting).
    type Tag: Copy;
    /// Query-time context: which counts are visible (an epoch filter, or
    /// `()` when everything counts).
    type Filter: Copy;

    /// A fresh store with the same configuration and zero nodes (used by
    /// [`ArenaTrie::compact`] to rebuild).
    fn new_empty(&self) -> Self;
    /// A node was appended to the arena; extend per-node storage.
    fn push_node(&mut self);
    /// Record one occurrence at `node` under `tag`.
    fn bump(&mut self, node: usize, tag: Self::Tag);
    /// Visible weight of `node` under `filter`; 0 means "not present" for
    /// matching purposes (dead epoch, no owners, …).
    fn weight(&self, node: usize, filter: Self::Filter) -> u64;
    /// Append (a copy of) `src`'s payload for node `old` — the compaction
    /// counterpart of [`CountStore::push_node`].
    fn copy_node_from(&mut self, src: &Self, old: usize);
    /// An edge was split: append a row for the NEW upper node, initialized
    /// as a **copy of `child`'s row** — interior positions of an edge share
    /// the lower node's counts (the compressed-counting invariant, see
    /// module docs), so the split must materialize exactly that state.
    fn split_node(&mut self, child: usize);
    /// Heap bytes owned by the store (diagnostics). Length-based, not
    /// capacity-based, so a snapshot-restored store reports identical
    /// bytes to the live store it was saved from.
    fn heap_bytes(&self) -> usize;
    /// Serialize the per-node rows (and any layout config) into the
    /// `das-store-v1` node-store section of a trie snapshot.
    fn save_rows(&self, w: &mut Writer);
    /// Rebuild from a [`CountStore::save_rows`] section covering exactly
    /// `n_nodes` arena nodes (validated — a row/arena count mismatch is
    /// [`StoreError::Corrupt`], never an out-of-bounds read later).
    fn load_rows(r: &mut Reader<'_>, n_nodes: usize) -> Result<Self, StoreError>
    where
        Self: Sized;
}

/// Plain occurrence counting — the [`CountStore`] of the production
/// counting suffix trie (and the reference store for core tests). Rows
/// live in a [`CowVec`] so cloning the store at a snapshot publish is
/// O(chunks), not O(nodes).
#[derive(Debug, Clone, Default)]
pub struct Counts {
    counts: CowVec<u64>,
}

impl Counts {
    #[inline]
    pub fn get(&self, node: usize) -> u64 {
        self.counts[node]
    }
}

impl CountStore for Counts {
    type Tag = ();
    type Filter = ();

    fn new_empty(&self) -> Self {
        Counts::default()
    }

    fn push_node(&mut self) {
        self.counts.push(0);
    }

    #[inline]
    fn bump(&mut self, node: usize, _tag: ()) {
        self.counts[node] += 1;
    }

    #[inline]
    fn weight(&self, node: usize, _filter: ()) -> u64 {
        self.counts[node]
    }

    fn copy_node_from(&mut self, src: &Self, old: usize) {
        self.counts.push(src.counts[old]);
    }

    fn split_node(&mut self, child: usize) {
        let c = self.counts[child];
        self.counts.push(c);
    }

    fn heap_bytes(&self) -> usize {
        self.counts.heap_bytes()
    }

    fn save_rows(&self, w: &mut Writer) {
        w.str("counts");
        w.usize(self.counts.len());
        for &c in self.counts.iter() {
            w.u64(c);
        }
    }

    fn load_rows(r: &mut Reader<'_>, n_nodes: usize) -> Result<Self, StoreError> {
        r.expect_str("counts", "count-store tag")?;
        let n = r.count(8)?;
        if n != n_nodes {
            return Err(StoreError::Corrupt(format!(
                "counts rows ({n}) != arena nodes ({n_nodes})"
            )));
        }
        let mut counts = CowVec::new();
        for _ in 0..n {
            counts.push(r.u64()?);
        }
        Ok(Counts { counts })
    }
}

// ---------------------------------------------------------------------------
// The compressed arena trie
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct Node {
    /// Child edges keyed by the first token of the child's label.
    children: ChildTable,
    /// Incoming edge label ([`SegRef::EMPTY`] for the root).
    label: SegRef,
    parent: u32,
    /// Token depth (= parent depth + label len).
    depth: u32,
    /// Explicit node at-or-above the position of `str(self)` minus its
    /// first token; 0 (root, always valid) when unknown. Maintained
    /// best-effort by `insert_suffixes`/`split_edge`, recomputed exactly by
    /// `compact`. NOT meaningful for prefix-only tries (`insert_prefix`).
    slink: u32,
}

impl Node {
    fn root() -> Node {
        Node {
            children: ChildTable::default(),
            label: SegRef::EMPTY,
            parent: 0,
            depth: 0,
            slink: 0,
        }
    }
}

/// A position in the trie: `matched` tokens of `node`'s incoming edge label
/// are consumed (`matched == label len` ⇒ exactly at `node`; the root is
/// `{node: 0, matched: 0}`). Mid-edge positions answer count queries via
/// [`TriePos::row`] — the edge's lower node — which is exact by the
/// compressed-counting invariant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TriePos {
    node: u32,
    matched: u32,
}

impl TriePos {
    pub const ROOT: TriePos = TriePos { node: 0, matched: 0 };

    /// The [`CountStore`] row visible at this position.
    #[inline]
    pub fn row(&self) -> usize {
        self.node as usize
    }
}

/// What one [`EdgeCursor::probe`] step found, before any mutation.
#[derive(Debug, Clone, Copy)]
enum Probe {
    /// No child edge below the cursor starts with the next target token.
    NoChild,
    /// Child `child`'s whole label matches the target: the cursor may
    /// descend to that explicit node.
    FullEdge { child: u32 },
    /// The walk stops inside `child`'s edge after `matched`
    /// (1 ≤ matched < label len) label tokens: `divergent` when the next
    /// target token mismatches the label, terminal (target exhausted)
    /// otherwise.
    MidEdge { child: u32, matched: u32, divergent: bool },
}

/// THE mutating edge-walk state machine: one probe → label-compare →
/// split-on-divergence/terminal → add-leaf step, shared by
/// [`ArenaTrie::insert_suffixes`], [`ArenaTrie::insert_prefix`] and
/// [`ArenaTrie::prefix_path_split`], which keep only their policy (what to
/// bump, what to record, whether divergence aborts). See the module docs
/// ("Mutating walks") for the invariant split between cursor and drivers.
///
/// The cursor is plain state — `(node, consumed)` — so drivers can
/// interleave [`CountStore`] bumps between steps without borrow gymnastics;
/// every method takes the trie (and, for mutations, the locked pool)
/// explicitly.
#[derive(Debug, Clone, Copy)]
struct EdgeCursor {
    /// Explicit node the walk is at (or whose edge it last split into).
    node: u32,
    /// Target tokens consumed so far (= token depth of `node`).
    consumed: usize,
}

impl EdgeCursor {
    fn at_root() -> EdgeCursor {
        EdgeCursor { node: 0, consumed: 0 }
    }

    /// The walk consumed its whole target (always ends on an explicit
    /// node: mid-edge stops are split before the drivers proceed).
    fn done(&self, target: &[TokenId]) -> bool {
        self.consumed == target.len()
    }

    /// Classify the next step toward `target[self.consumed..]` without
    /// mutating anything. The label comparison starts at index 1: the
    /// [`ChildTable`] is keyed by each label's first token, so a probed
    /// child's `label[0]` equals the next target token by construction.
    fn probe<S: CountStore>(
        &self,
        trie: &ArenaTrie<S>,
        pg: &SegmentPool,
        target: &[TokenId],
    ) -> Probe {
        debug_assert!(self.consumed < target.len(), "probe past the target");
        let t = target[self.consumed];
        let Some(child) = trie.nodes[self.node as usize].children.get(t) else {
            return Probe::NoChild;
        };
        let lab = trie.nodes[child as usize].label;
        let ll = lab.len as usize;
        let lim = ll.min(target.len() - self.consumed);
        let lab_toks = pg.slice(lab);
        debug_assert_eq!(lab_toks[0], t, "child table key != first label token");
        let mut m = 1usize;
        while m < lim && lab_toks[m] == target[self.consumed + m] {
            m += 1;
        }
        if m == ll {
            Probe::FullEdge { child }
        } else {
            Probe::MidEdge { child, matched: m as u32, divergent: m < lim }
        }
    }

    /// Consume a fully matched edge ([`Probe::FullEdge`]).
    fn descend<S: CountStore>(&mut self, trie: &ArenaTrie<S>, child: u32) {
        self.consumed += trie.label_len(child) as usize;
        self.node = child;
    }

    /// Expose a mid-edge boundary ([`Probe::MidEdge`]) as an explicit node
    /// via [`ArenaTrie::split_edge`] (which retains the segment for the
    /// extra edge and copies the lower node's row pre-bump); the cursor
    /// moves onto the new upper node.
    fn split<S: CountStore>(
        &mut self,
        trie: &mut ArenaTrie<S>,
        pg: &mut SegmentPool,
        child: u32,
        matched: u32,
    ) -> u32 {
        let w = trie.split_edge(child, matched, pg);
        self.consumed += matched as usize;
        self.node = w;
        w
    }

    /// Append the rest of the target as ONE leaf edge below the cursor,
    /// retaining the driver's interned segment once for the new edge.
    /// `seg_off` is where the target starts inside `seg` (a suffix walk
    /// slices one whole-rollout segment; prefix walks intern exactly their
    /// target, offset 0). Consumes the target: the walk is done after.
    fn add_leaf<S: CountStore>(
        &mut self,
        trie: &mut ArenaTrie<S>,
        pg: &mut SegmentPool,
        seg: u32,
        seg_off: usize,
        target: &[TokenId],
    ) -> u32 {
        debug_assert!(self.consumed < target.len(), "leaf with an empty label");
        let label = SegRef {
            seg,
            start: (seg_off + self.consumed) as u32,
            len: (target.len() - self.consumed) as u32,
        };
        pg.retain(seg);
        let leaf = trie.add_leaf(self.node, target[self.consumed], label);
        self.consumed = target.len();
        self.node = leaf;
        leaf
    }
}

/// Depth-capped path-compressed arena trie, generic over what each node
/// counts, with edge labels interned in a (possibly shared) [`SegmentPool`].
///
/// This is the WRITER half of the snapshot split: all mutation happens
/// here behind `&mut`; [`ArenaTrie::publish`] hands out an immutable
/// [`TrieSnapshot`] for lock-free reads (see module docs, "Snapshot
/// reads"). The arena is a [`CowVec`] so publication shares every chunk
/// the writer hasn't touched since.
#[derive(Debug)]
pub struct ArenaTrie<S: CountStore> {
    nodes: CowVec<Node>,
    store: S,
    max_depth: usize,
    pool: SharedPool,
    /// Running sum of all edge-label lengths (splits conserve it, leaves
    /// add, compaction recomputes) so `token_positions` is O(1) — it is
    /// polled per step by the telemetry gauges.
    label_tokens: usize,
    /// Nodes created (leaves + splits) since the last exact link rebuild —
    /// each may carry an approximate (at-or-above) suffix link. Once they
    /// cover half the arena, `insert_suffixes` refreshes the links exactly
    /// (the `window_all` path never compacts, so this is its only refresh).
    links_dirty: usize,
    /// Exact link rebuilds performed (compaction or threshold-triggered) —
    /// a lifetime counter surfaced by the telemetry gauges.
    link_rebuilds: u64,
    /// Running sum of child-table spill bytes (maintained at the insert
    /// sites, recomputed by compaction/load) so [`ArenaTrie::approx_bytes`]
    /// is O(1) — snapshot publication stamps size gauges per publish.
    spill_bytes: usize,
}

impl<S: CountStore> Clone for ArenaTrie<S> {
    fn clone(&self) -> Self {
        // The clone shares the pool; every cloned edge is one more
        // reference to its segment.
        {
            let mut pg = self.pool.lock();
            for n in self.nodes.iter().skip(1) {
                pg.retain(n.label.seg);
            }
        }
        ArenaTrie {
            nodes: self.nodes.clone(),
            store: self.store.clone(),
            max_depth: self.max_depth,
            pool: self.pool.clone(),
            label_tokens: self.label_tokens,
            links_dirty: self.links_dirty,
            link_rebuilds: self.link_rebuilds,
            spill_bytes: self.spill_bytes,
        }
    }
}

impl<S: CountStore> Drop for ArenaTrie<S> {
    fn drop(&mut self) {
        let mut pg = self.pool.lock();
        for n in self.nodes.iter().skip(1) {
            pg.release(n.label.seg);
        }
    }
}

impl<S: CountStore> ArenaTrie<S> {
    pub fn new(max_depth: usize, store: S) -> Self {
        Self::with_pool(max_depth, store, SharedPool::new())
    }

    /// Build a trie whose edge labels are interned in `pool` — share one
    /// pool across shards so identical rollout content is stored once.
    pub fn with_pool(max_depth: usize, mut store: S, pool: SharedPool) -> Self {
        store.push_node(); // root payload
        let mut nodes = CowVec::new();
        nodes.push(Node::root());
        ArenaTrie {
            nodes,
            store,
            max_depth: max_depth.max(1),
            pool,
            label_tokens: 0,
            links_dirty: 0,
            link_rebuilds: 0,
            spill_bytes: 0,
        }
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    /// Explicit nodes allocated (root included). With path compression this
    /// is branching + termination points, NOT indexed token positions.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// What a one-node-per-token trie would allocate for the same content:
    /// the root plus one position per edge-label token. `token_positions()
    /// / node_count()` is the compression ratio the telemetry gauges track.
    /// O(1): maintained incrementally (splits conserve label tokens).
    pub fn token_positions(&self) -> usize {
        debug_assert_eq!(
            self.label_tokens,
            self.nodes
                .iter()
                .skip(1)
                .map(|n| n.label.len as usize)
                .sum::<usize>(),
            "label-token counter drifted"
        );
        1 + self.label_tokens
    }

    pub fn store(&self) -> &S {
        &self.store
    }

    pub fn store_mut(&mut self) -> &mut S {
        &mut self.store
    }

    /// Handle to the segment pool backing this trie's edge labels.
    pub fn pool(&self) -> SharedPool {
        self.pool.clone()
    }

    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    #[inline]
    fn label_len(&self, v: u32) -> u32 {
        self.nodes[v as usize].label.len
    }

    /// Test-only probe: the read walks use [`label_len_of`] directly.
    #[cfg(test)]
    fn at_node(&self, p: TriePos) -> bool {
        p.matched == self.label_len(p.node)
    }

    /// Append a fresh leaf under `parent`; the caller wires counts.
    fn add_leaf(&mut self, parent: u32, first_tok: TokenId, label: SegRef) -> u32 {
        let id = self.nodes.len() as u32;
        let depth = self.nodes[parent as usize].depth + label.len;
        self.nodes.push(Node {
            children: ChildTable::default(),
            label,
            parent,
            depth,
            slink: 0,
        });
        self.store.push_node();
        // The only site where an existing child table grows (splits insert
        // into a fresh, spill-free table): account spill growth here so
        // `approx_bytes` stays O(1).
        let before = self.nodes[parent as usize].children.heap_bytes();
        self.nodes[parent as usize].children.insert(first_tok, id);
        let after = self.nodes[parent as usize].children.heap_bytes();
        self.spill_bytes += after - before;
        self.label_tokens += label.len as usize;
        self.links_dirty += 1;
        id
    }

    /// Split `child`'s incoming edge after `m` label tokens (1 ≤ m < len),
    /// inserting a new explicit node `w` between parent and child. `w`'s
    /// store row is a copy of `child`'s ([`CountStore::split_node`]), which
    /// is exactly what the interior positions held implicitly.
    fn split_edge(&mut self, child: u32, m: u32, pg: &mut SegmentPool) -> u32 {
        let c = child as usize;
        let lab = self.nodes[c].label;
        debug_assert!(m >= 1 && m < lab.len);
        let parent = self.nodes[c].parent;
        let upper = SegRef { seg: lab.seg, start: lab.start, len: m };
        let lower = SegRef { seg: lab.seg, start: lab.start + m, len: lab.len - m };
        let first_upper = pg.slice(upper)[0];
        let first_lower = pg.slice(lower)[0];
        pg.retain(lab.seg); // the segment now backs two edges
        let wdepth = self.nodes[c].depth - lower.len;
        // The child's at-or-above link stays valid for `w` iff it is not
        // deeper than w's own suffix position. Otherwise fall back to the
        // PARENT's link — always valid (str(parent)[1..] is a prefix of
        // str(w)[1..], and its link sits at-or-above that) and far tighter
        // than the root for deep splits, which keeps the skip/count
        // re-descents short even in tries that never compact (window_all).
        let cslink = self.nodes[c].slink;
        let wslink = if self.nodes[cslink as usize].depth + 1 <= wdepth {
            cslink
        } else {
            self.nodes[parent as usize].slink
        };
        let w = self.nodes.len() as u32;
        self.nodes.push(Node {
            children: ChildTable::default(),
            label: upper,
            parent,
            depth: wdepth,
            slink: wslink,
        });
        self.store.split_node(c);
        self.nodes[w as usize].children.insert(first_lower, child);
        self.nodes[c].label = lower;
        self.nodes[c].parent = w;
        self.nodes[parent as usize].children.set(first_upper, w);
        self.links_dirty += 1;
        w
    }

    /// Index every suffix of `tokens` (truncated at `max_depth`), bumping
    /// counts under `tag` along each path.
    ///
    /// The whole rollout is interned ONCE; every edge created below is a
    /// sub-range of that one segment, so a repeated rollout adds zero pool
    /// bytes and (once its paths exist) zero nodes. Each start position is
    /// one [`EdgeCursor`] walk; edges are split at divergence and
    /// termination points so the compressed-counting invariant holds
    /// (module docs). Suffix links of nodes created at position `i` —
    /// leaves AND terminal-split nodes — are resolved against position
    /// `i+1`'s walk, whose path IS the one-shorter suffix, and default to
    /// the root (always valid) when the walk can't witness them.
    pub fn insert_suffixes(&mut self, tokens: &[TokenId], tag: S::Tag) {
        if tokens.is_empty() {
            return;
        }
        let pool = self.pool.clone();
        {
            let mut pg = pool.lock();
            let seg = pg.intern(tokens);
            // (node, slink target depth) created at the previous start.
            let mut pending: Vec<(u32, u32)> = Vec::new();
            let mut next_pending: Vec<(u32, u32)> = Vec::new();
            // Explicit nodes on the current walk, ascending (node, depth).
            let mut path: Vec<(u32, u32)> = Vec::new();
            for i in 0..tokens.len() {
                let slen = (tokens.len() - i).min(self.max_depth);
                let s = &tokens[i..i + slen];
                self.store.bump(0, tag); // root: one ε occurrence per position
                path.clear();
                next_pending.clear();
                let mut cur = EdgeCursor::at_root();
                while !cur.done(s) {
                    match cur.probe(self, &pg, s) {
                        Probe::FullEdge { child } => {
                            cur.descend(self, child);
                            self.store.bump(child as usize, tag);
                            path.push((child, cur.consumed as u32));
                        }
                        Probe::NoChild => {
                            let leaf = cur.add_leaf(self, &mut pg, seg, i, s);
                            self.store.bump(leaf as usize, tag);
                            path.push((leaf, slen as u32));
                            next_pending.push((leaf, (slen - 1) as u32));
                        }
                        Probe::MidEdge { child, matched, divergent } => {
                            let w = cur.split(self, &mut pg, child, matched);
                            self.store.bump(w as usize, tag);
                            let wd = cur.consumed as u32;
                            path.push((w, wd));
                            next_pending.push((w, wd - 1));
                            if divergent {
                                let leaf = cur.add_leaf(self, &mut pg, seg, i, s);
                                self.store.bump(leaf as usize, tag);
                                path.push((leaf, slen as u32));
                                next_pending.push((leaf, (slen - 1) as u32));
                            }
                        }
                    }
                }
                // Resolve the previous start's pending links: this walk's
                // path is its one-shorter suffix (possibly extended by one
                // token), so the deepest path node within each target depth
                // is a valid — and tight — link target.
                for &(node, target) in &pending {
                    let mut best = 0u32;
                    for &(p, d) in &path {
                        if d <= target {
                            best = p;
                        } else {
                            break;
                        }
                    }
                    self.nodes[node as usize].slink = best;
                }
                std::mem::swap(&mut pending, &mut next_pending);
            }
            pg.release_if_unused(seg);
        }
        // Suffix tries are substring-closed, so an exact link refresh is
        // legal here; prefix-only tries must never reach this (see
        // `insert_prefix`).
        self.maybe_refresh_links();
    }

    /// Index ONLY the prefix path of `tokens` (truncated at `max_depth`),
    /// bumping counts under `tag` along it (the router's registration —
    /// no suffix links, the root is not counted). Returns the deepest node
    /// — always explicit: the walk splits an edge it terminates inside.
    /// Empty input registers nothing and returns the root.
    pub fn insert_prefix(&mut self, tokens: &[TokenId], tag: S::Tag) -> usize {
        let want = tokens.len().min(self.max_depth);
        if want == 0 {
            return 0;
        }
        let target = &tokens[..want];
        let pool = self.pool.clone();
        let mut pg = pool.lock();
        let seg = pg.intern(target);
        let mut cur = EdgeCursor::at_root();
        while !cur.done(target) {
            match cur.probe(self, &pg, target) {
                Probe::FullEdge { child } => {
                    cur.descend(self, child);
                    self.store.bump(child as usize, tag);
                }
                Probe::NoChild => {
                    let leaf = cur.add_leaf(self, &mut pg, seg, 0, target);
                    self.store.bump(leaf as usize, tag);
                }
                Probe::MidEdge { child, matched, divergent } => {
                    let w = cur.split(self, &mut pg, child, matched);
                    self.store.bump(w as usize, tag);
                    if divergent {
                        let leaf = cur.add_leaf(self, &mut pg, seg, 0, target);
                        self.store.bump(leaf as usize, tag);
                    }
                }
            }
        }
        pg.release_if_unused(seg);
        cur.node as usize
    }

    /// One [`TrieRead`] view over this trie's current state and the given
    /// label source — the single implementation every read walk (locked
    /// writer-side AND lock-free snapshot-side) goes through.
    fn read<'a, L: Labels>(&'a self, labels: &'a L) -> TrieRead<'a, S, L> {
        TrieRead {
            nodes: &self.nodes,
            store: &self.store,
            labels,
            max_depth: self.max_depth,
        }
    }

    /// Walk `pattern` exactly from the root; `None` unless fully matched
    /// (structurally — no count filter). The match may end mid-edge.
    pub fn locate(&self, pattern: &[TokenId]) -> Option<TriePos> {
        let pg = self.pool.lock();
        self.read(&*pg).locate(pattern)
    }

    /// Walk `tokens`' depth-capped prefix; if it is fully present, ensure
    /// the walk's end sits on an EXPLICIT node (splitting the final edge
    /// once if it ends mid-edge) and return the explicit nodes along the
    /// path in ascending depth. `None` — with nothing modified — when the
    /// prefix is not fully present, and also for an EMPTY prefix: an empty
    /// generation is never registered ([`ArenaTrie::insert_prefix`] bumps
    /// nothing for it), so there is nothing to reverse — the inverse the
    /// router's unregister relies on. (Each returned node gets exactly one
    /// un-bump, mirroring how registration bumped once per explicit node
    /// on the same boundaries.)
    pub fn prefix_path_split(&mut self, tokens: &[TokenId]) -> Option<Vec<usize>> {
        let want = tokens.len().min(self.max_depth);
        if want == 0 {
            return None;
        }
        let target = &tokens[..want];
        let pool = self.pool.clone();
        let mut pg = pool.lock();
        let mut out: Vec<usize> = Vec::new();
        let mut cur = EdgeCursor::at_root();
        while !cur.done(target) {
            match cur.probe(self, &pg, target) {
                Probe::FullEdge { child } => {
                    cur.descend(self, child);
                    out.push(child as usize);
                }
                // Read-mostly policy: a miss or divergence means the prefix
                // was never (fully) registered — refuse, mutating nothing.
                Probe::NoChild | Probe::MidEdge { divergent: true, .. } => return None,
                // Terminal mid-edge: the prefix IS present; expose its
                // boundary so the caller's un-bumps hit explicit nodes.
                Probe::MidEdge { child, matched, divergent: false } => {
                    let w = cur.split(self, &mut pg, child, matched);
                    out.push(w as usize);
                }
            }
        }
        Some(out)
    }

    /// Deepest position along `context`'s prefix (≤ `max_depth`) whose
    /// weight under `filter` is nonzero; returns `(row node, depth)`.
    /// Descends through zero-weight edges (they may have been drained by
    /// eviction) but never reports one.
    pub fn deepest_visible_prefix(
        &self,
        context: &[TokenId],
        filter: S::Filter,
    ) -> Option<(usize, usize)> {
        let pg = self.pool.lock();
        self.read(&*pg).deepest_visible_prefix(context, filter)
    }

    /// Longest suffix of `context` (length ≤ `max_len`) whose position is
    /// visible under `filter`, as ONE O(m) forward pass over the last
    /// `m = min(len, max_len, max_depth)` context tokens (Aho–Corasick over
    /// compressed edges): extend inside the current edge by direct label
    /// comparison, descend to a visible child edge at nodes, and on a miss
    /// fall back one token — suffix link of the nearest explicit node, then
    /// a skip/count re-descent of the (present, by substring closure)
    /// shorter suffix. Returns `(match_len, position)`; `(0, ROOT)` when
    /// nothing matches.
    pub fn deepest_suffix(
        &self,
        context: &[TokenId],
        max_len: usize,
        filter: S::Filter,
    ) -> (usize, TriePos) {
        let pg = self.pool.lock();
        self.read(&*pg).deepest_suffix(context, max_len, filter)
    }

    /// Visit every suffix position of `matched` (the deepest matched
    /// suffix, located at `start`): the callback receives `(depth, pos)`
    /// for depth = `matched.len(), …, 1` and returns whether to continue.
    /// One suffix-link + skip/count re-descent per step — the window
    /// drafter's per-epoch chain scan. Label-free (skip/count chooses
    /// children by first token), so no pool access is needed.
    pub fn walk_suffix_chain<F: FnMut(usize, TriePos) -> bool>(
        &self,
        matched: &[TokenId],
        start: TriePos,
        f: F,
    ) {
        walk_chain_nodes(&self.nodes, matched, start, f)
    }

    /// Greedy highest-weight walk from `start`, up to `budget` tokens.
    /// Inside an edge the continuation is forced (interior positions share
    /// the lower node's counts, so per-token confidence is exactly 1); at
    /// explicit nodes the child edge with the largest visible weight wins,
    /// ties toward the smallest first token. Returns the draft and
    /// per-token empirical confidence `weight(child)/weight(node)` —
    /// bit-identical to the uncompressed per-token walk.
    pub fn greedy_walk(
        &self,
        start: TriePos,
        budget: usize,
        filter: S::Filter,
    ) -> (Vec<TokenId>, Vec<f32>) {
        let pg = self.pool.lock();
        self.read(&*pg).greedy_walk(start, budget, filter)
    }

    /// Rebuild the arena keeping only nodes for which `keep` is true
    /// (liveness must be ancestor-closed AND substring-closed — true for
    /// every store here: counts only decrease toward longer strings).
    /// Payloads are copied verbatim, dropped edges release their pool
    /// segments, and suffix links are recomputed EXACTLY in one pass.
    pub fn compact<F: Fn(&S, usize) -> bool>(&mut self, keep: F) {
        let pool = self.pool.clone();
        {
            let mut pg = pool.lock();
            let mut new_nodes: CowVec<Node> = CowVec::new();
            let mut new_store = self.store.new_empty();
            new_nodes.push(Node::root());
            new_store.copy_node_from(&self.store, 0);
            let mut stack: Vec<(usize, usize)> = vec![(0, 0)];
            let mut kept: Vec<(TokenId, usize)> = Vec::new();
            let mut kept_label_tokens = 0usize;
            while let Some((old_id, new_id)) = stack.pop() {
                kept.clear();
                self.nodes[old_id].children.for_each(|tok, child| {
                    if keep(&self.store, child as usize) {
                        kept.push((tok, child as usize));
                    }
                });
                for &(tok, child_old) in &kept {
                    let child_new = new_nodes.len();
                    let old = &self.nodes[child_old];
                    // Re-intern the label content instead of keeping the
                    // old SegRef: a kept edge must not pin the (possibly
                    // huge) original rollout segment it was sliced from —
                    // after compaction the pool holds only live label
                    // bytes, deduplicated across identical labels. The
                    // intern may hand back the old segment itself when the
                    // label IS its full content; retain/release still
                    // balance.
                    let content = pg.slice(old.label).to_vec();
                    let seg = pg.intern(&content);
                    pg.retain(seg);
                    let label = SegRef { seg, start: 0, len: old.label.len };
                    kept_label_tokens += old.label.len as usize;
                    new_nodes.push(Node {
                        children: ChildTable::default(),
                        label,
                        parent: new_id as u32,
                        depth: old.depth,
                        slink: 0,
                    });
                    new_store.copy_node_from(&self.store, child_old);
                    new_nodes[new_id].children.insert(tok, child_new as u32);
                    stack.push((child_old, child_new));
                }
            }
            // Every old edge releases its segment (kept ones re-retained
            // above, so live segments never transit through rc = 0).
            for n in self.nodes.iter().skip(1) {
                pg.release(n.label.seg);
            }
            self.spill_bytes = new_nodes.iter().map(|n| n.children.heap_bytes()).sum();
            self.nodes = new_nodes;
            self.store = new_store;
            self.label_tokens = kept_label_tokens;
        }
        self.rebuild_suffix_links();
    }

    /// Refresh links when the approximate ones cover half the arena — the
    /// exact-link path for suffix tries that never compact (`window_all`'s
    /// sparse epoch rows, the plain counting trie). The trigger is
    /// geometric (each rebuild resets `links_dirty`, which must regrow to
    /// half of an arena that grew with it), so the O(arena) rebuild costs
    /// amortized O(1) per created node. Small arenas skip it: their
    /// re-descents are short even through root fallbacks.
    fn maybe_refresh_links(&mut self) {
        if self.nodes.len() >= LINK_REBUILD_MIN_NODES && self.links_dirty * 2 >= self.nodes.len() {
            self.rebuild_suffix_links();
        }
    }

    /// Exact link rebuilds performed so far (compaction or the
    /// `links_dirty` threshold) — telemetry for the `window_all` refresh.
    pub fn link_rebuilds(&self) -> u64 {
        self.link_rebuilds
    }

    /// Exact suffix-link recomputation, O(arena): the suffix position of
    /// `v` is its parent's suffix position advanced by `v`'s label — one
    /// skip/count descent per node. Nodes are visited parent-first via the
    /// child tables, NOT in allocation order: a split allocates the upper
    /// node AFTER its lower half, so allocation order is only parent-first
    /// right after `compact`'s DFS, and this must also run on tries that
    /// never compact. Only valid on substring-closed (suffix) tries.
    pub(crate) fn rebuild_suffix_links(&mut self) {
        let pool = self.pool.clone();
        {
            let pg = pool.lock();
            let n = self.nodes.len();
            let mut spos: Vec<TriePos> = vec![TriePos::ROOT; n];
            let mut stack: Vec<u32> = Vec::new();
            self.nodes[0].children.for_each(|_, c| stack.push(c));
            while let Some(v) = stack.pop() {
                let vi = v as usize;
                self.nodes[vi].children.for_each(|_, c| stack.push(c));
                let u = self.nodes[vi].parent as usize;
                let lab = self.nodes[vi].label;
                let lt = pg.slice(lab);
                let p = if u == 0 {
                    // Depth-from-root edge: the suffix drops the first token.
                    descend_nodes(&self.nodes, TriePos::ROOT, &lt[1..])
                } else {
                    descend_nodes(&self.nodes, spos[u], lt)
                };
                let slink = if p.matched == label_len_of(&self.nodes, p.node) {
                    p.node
                } else {
                    self.nodes[p.node as usize].parent
                };
                spos[vi] = p;
                self.nodes[vi].slink = slink;
            }
        }
        self.links_dirty = 0;
        self.link_rebuilds += 1;
    }

    /// Approximate heap bytes (arena + child spill + store). Pool bytes are
    /// reported separately ([`ArenaTrie::pool_stats`]) because the pool may
    /// be shared by many tries. O(1): the spill sum is maintained
    /// incrementally so snapshot publication can stamp size gauges on
    /// every publish without rescanning the arena.
    pub fn approx_bytes(&self) -> usize {
        debug_assert_eq!(
            self.spill_bytes,
            self.nodes.iter().map(|n| n.children.heap_bytes()).sum::<usize>(),
            "child-spill byte counter drifted"
        );
        self.nodes.len() * std::mem::size_of::<Node>() + self.spill_bytes + self.store.heap_bytes()
    }

    /// Total child-table entries (diagnostics).
    pub fn edge_count(&self) -> usize {
        self.nodes.iter().map(|n| n.children.len()).sum()
    }

    /// Serialize the complete trie as one `das-store-v1` section: every
    /// arena node (edge label as a pool `SegRef`, parent, depth, suffix
    /// link), the exact-or-dirty link bookkeeping (`links_dirty`,
    /// `link_rebuilds`), and the [`CountStore`] rows. The segment pool is
    /// NOT written here — it may back many tries and is saved once by the
    /// owner (see [`SharedPool::save_state`]).
    pub fn save_state(&self, w: &mut Writer) {
        w.str("trie");
        w.usize(self.max_depth);
        w.usize(self.nodes.len());
        w.usize(self.links_dirty);
        w.u64(self.link_rebuilds);
        for n in self.nodes.iter() {
            w.u32(n.label.seg);
            w.u32(n.label.start);
            w.u32(n.label.len);
            w.u32(n.parent);
            w.u32(n.depth);
            w.u32(n.slink);
        }
        self.store.save_rows(w);
    }

    /// Rebuild a trie from [`ArenaTrie::save_state`] against `pool`, which
    /// must already hold the snapshot's segments under their original ids
    /// (load the pool section first — [`SharedPool::load_state`]). Child
    /// tables are reconstructed from parent pointers + first label tokens;
    /// every structural invariant is validated BEFORE any pool refcount is
    /// touched, so a corrupt section fails with [`StoreError::Corrupt`] and
    /// leaves the pool exactly as it was. Each loaded edge retains its
    /// segment, re-deriving refcounts from the structures that exist.
    pub fn load_state(r: &mut Reader<'_>, pool: SharedPool) -> Result<Self, StoreError> {
        r.expect_str("trie", "trie section tag")?;
        let max_depth = r.usize()?;
        let n = r.count(24)?;
        if n == 0 {
            return Err(StoreError::Corrupt("trie without a root node".into()));
        }
        let links_dirty = r.usize()?.min(n);
        let link_rebuilds = r.u64()?;
        let mut raw: Vec<(SegRef, u32, u32, u32)> = Vec::with_capacity(n);
        for _ in 0..n {
            let label = SegRef {
                seg: r.u32()?,
                start: r.u32()?,
                len: r.u32()?,
            };
            raw.push((label, r.u32()?, r.u32()?, r.u32()?));
        }
        let store = S::load_rows(r, n)?;
        fn corrupt(m: String) -> StoreError {
            StoreError::Corrupt(m)
        }
        let mut nodes: Vec<Node> = Vec::with_capacity(n);
        {
            let mut pg = pool.lock();
            let (rl, rp, rd, rs) = raw[0];
            if rl.len != 0 || rp != 0 || rd != 0 || rs != 0 {
                return Err(corrupt("trie root must be label-less at depth 0".into()));
            }
            nodes.push(Node::root());
            for (v, &(label, parent, depth, slink)) in raw.iter().enumerate().skip(1) {
                if label.len == 0 {
                    return Err(corrupt(format!("node {v}: empty edge label")));
                }
                let seg_len = pg
                    .seg_len(label.seg)
                    .ok_or_else(|| corrupt(format!("node {v}: dead pool segment {}", label.seg)))?;
                let end = label
                    .start
                    .checked_add(label.len)
                    .ok_or_else(|| corrupt(format!("node {v}: label range overflow")))?;
                if end > seg_len {
                    return Err(corrupt(format!("node {v}: label past segment end")));
                }
                if parent as usize >= n || slink as usize >= n {
                    return Err(corrupt(format!("node {v}: parent/slink out of range")));
                }
                // depth = parent depth + label len (labels are nonempty, so
                // this also rules out parent cycles), and a suffix link may
                // only point at-or-above the one-shorter suffix position.
                if depth != raw[parent as usize].2 + label.len {
                    return Err(corrupt(format!("node {v}: inconsistent depth")));
                }
                if raw[slink as usize].2 + 1 > depth {
                    return Err(corrupt(format!("node {v}: suffix link below suffix depth")));
                }
                nodes.push(Node {
                    children: ChildTable::default(),
                    label,
                    parent,
                    depth,
                    slink,
                });
            }
            // Child tables: keyed by each edge's first label token, one
            // edge per (parent, token).
            for v in 1..n {
                let label = nodes[v].label;
                let parent = nodes[v].parent as usize;
                let first = pg.slice(label)[0];
                if nodes[parent].children.get(first).is_some() {
                    return Err(corrupt(format!("node {v}: duplicate child token {first}")));
                }
                nodes[parent].children.insert(first, v as u32);
            }
            // Everything validated: NOW take the pool references.
            for node in &nodes[1..] {
                pg.retain(node.label.seg);
            }
        }
        let label_tokens = nodes[1..].iter().map(|nd| nd.label.len as usize).sum();
        let spill_bytes = nodes.iter().map(|nd| nd.children.heap_bytes()).sum();
        Ok(ArenaTrie {
            nodes: nodes.into_iter().collect(),
            store,
            max_depth: max_depth.max(1),
            pool,
            label_tokens,
            links_dirty,
            link_rebuilds,
            spill_bytes,
        })
    }

    /// Publish an immutable [`TrieSnapshot`] of the current state for
    /// lock-free concurrent reads. Cost: one pool lock plus O(chunks
    /// touched since the last publish) pointer copies (arena, count rows
    /// and pool slot table are all copy-on-write) — publication never
    /// rescans the arena; the size gauges stamped on the snapshot are the
    /// writer's incrementally maintained counters.
    pub fn publish(&self) -> TrieSnapshot<S> {
        TrieSnapshot {
            stats: SnapshotStats {
                nodes: self.node_count(),
                token_positions: self.token_positions(),
                heap_bytes: self.approx_bytes(),
                link_rebuilds: self.link_rebuilds,
            },
            nodes: self.nodes.clone(),
            store: self.store.clone(),
            labels: self.pool.snapshot(),
            max_depth: self.max_depth,
        }
    }
}

// ---------------------------------------------------------------------------
// Shared read walks + published snapshots
// ---------------------------------------------------------------------------

#[inline]
fn label_len_of(nodes: &CowVec<Node>, v: u32) -> u32 {
    nodes[v as usize].label.len
}

/// Advance a position by `toks`, skip/count (presence guaranteed by
/// substring closure of the kept set). Chooses children by first token
/// only — label-free, so it serves locked and snapshot walks alike.
fn descend_nodes(nodes: &CowVec<Node>, from: TriePos, toks: &[TokenId]) -> TriePos {
    let mut v = from.node;
    let mut k = from.matched;
    let mut i = 0usize;
    while i < toks.len() {
        let ll = label_len_of(nodes, v);
        if k == ll {
            let Some(c) = nodes[v as usize].children.get(toks[i]) else {
                debug_assert!(false, "substring closure violated in descend");
                return TriePos::ROOT;
            };
            v = c;
            k = 0;
            continue;
        }
        let step = ((ll - k) as usize).min(toks.len() - i);
        k += step as u32;
        i += step;
    }
    TriePos { node: v, matched: k }
}

/// Locate the structurally present string `s` by skip/count, starting
/// from explicit node `from` whose string is a known prefix of `s`.
/// Presence is guaranteed by substring closure, so children are chosen
/// by first token only — O(1) per edge, no label comparisons. Thin
/// wrapper over [`descend_nodes`], the one skip/count descent.
fn canonize_nodes(nodes: &CowVec<Node>, from: u32, s: &[TokenId]) -> TriePos {
    let j = nodes[from as usize].depth as usize;
    debug_assert!(j <= s.len(), "suffix link deeper than its target");
    let at_from = TriePos { node: from, matched: label_len_of(nodes, from) };
    if j >= s.len() {
        return at_from;
    }
    descend_nodes(nodes, at_from, &s[j..])
}

/// Suffix-chain visit (the body of [`ArenaTrie::walk_suffix_chain`] and
/// [`TrieSnapshot::walk_suffix_chain`]): one suffix-link + skip/count
/// re-descent per step, label-free.
fn walk_chain_nodes<F: FnMut(usize, TriePos) -> bool>(
    nodes: &CowVec<Node>,
    matched: &[TokenId],
    start: TriePos,
    mut f: F,
) {
    let mut pos = start;
    let mut d = matched.len();
    while d > 0 {
        if !f(d, pos) {
            return;
        }
        if d == 1 {
            return;
        }
        d -= 1;
        let anchor = if pos.matched == label_len_of(nodes, pos.node) {
            pos.node
        } else {
            nodes[pos.node as usize].parent
        };
        let from = nodes[anchor as usize].slink;
        pos = canonize_nodes(nodes, from, &matched[matched.len() - d..]);
    }
}

/// THE read-walk implementation, generic over where edge labels resolve
/// ([`Labels`]): [`ArenaTrie`] instantiates it with the locked
/// [`SegmentPool`] (writer path), [`TrieSnapshot`] with its
/// [`PoolSnapshot`] (lock-free draft path). Both run literally the same
/// code, so snapshot reads are bit-identical to locked reads by
/// construction — the property tests below pin this at every publish
/// point.
pub(crate) struct TrieRead<'a, S, L> {
    nodes: &'a CowVec<Node>,
    store: &'a S,
    labels: &'a L,
    max_depth: usize,
}

impl<'a, S: CountStore, L: Labels> TrieRead<'a, S, L> {
    fn locate(&self, pattern: &[TokenId]) -> Option<TriePos> {
        let mut u: u32 = 0;
        let mut j = 0usize;
        while j < pattern.len() {
            let c = self.nodes[u as usize].children.get(pattern[j])?;
            let lab = self.nodes[c as usize].label;
            let lt = self.labels.slice(lab);
            let take = (lab.len as usize).min(pattern.len() - j);
            if lt[..take] != pattern[j..j + take] {
                return None;
            }
            if take < lab.len as usize {
                return Some(TriePos { node: c, matched: take as u32 });
            }
            u = c;
            j += take;
        }
        Some(TriePos { node: u, matched: label_len_of(self.nodes, u) })
    }

    fn deepest_visible_prefix(
        &self,
        context: &[TokenId],
        filter: S::Filter,
    ) -> Option<(usize, usize)> {
        let cap = context.len().min(self.max_depth);
        let mut u: u32 = 0;
        let mut j = 0usize;
        let mut best = None;
        while j < cap {
            let Some(c) = self.nodes[u as usize].children.get(context[j]) else {
                break;
            };
            let lab = self.nodes[c as usize].label;
            let lim = (lab.len as usize).min(cap - j);
            let lt = self.labels.slice(lab);
            let mut m = 0usize;
            while m < lim && lt[m] == context[j + m] {
                m += 1;
            }
            if m > 0 && self.store.weight(c as usize, filter) > 0 {
                best = Some((c as usize, j + m));
            }
            if m < lab.len as usize {
                break;
            }
            u = c;
            j += m;
        }
        best
    }

    fn deepest_suffix(
        &self,
        context: &[TokenId],
        max_len: usize,
        filter: S::Filter,
    ) -> (usize, TriePos) {
        let cap = context.len().min(max_len).min(self.max_depth);
        if cap == 0 {
            return (0, TriePos::ROOT);
        }
        let tail = &context[context.len() - cap..];
        let mut v: u32 = 0;
        let mut k: u32 = 0;
        let mut d: usize = 0;
        for idx in 0..tail.len() {
            let t = tail[idx];
            loop {
                let ll = label_len_of(self.nodes, v);
                if k == ll {
                    // At an explicit node: probe for a visible child edge.
                    let c = self.nodes[v as usize]
                        .children
                        .get(t)
                        .filter(|&c| self.store.weight(c as usize, filter) > 0);
                    if let Some(c) = c {
                        v = c;
                        k = 1;
                        d += 1;
                        break;
                    }
                } else {
                    // Inside an edge: the next label token decides.
                    let lt = self.labels.slice(self.nodes[v as usize].label);
                    if lt[k as usize] == t {
                        k += 1;
                        d += 1;
                        break;
                    }
                }
                if d == 0 {
                    break; // token unmatched even at the root
                }
                d -= 1;
                let anchor = if k == ll { v } else { self.nodes[v as usize].parent };
                let from = self.nodes[anchor as usize].slink;
                let p = canonize_nodes(self.nodes, from, &tail[idx - d..idx]);
                v = p.node;
                k = p.matched;
            }
        }
        (d, TriePos { node: v, matched: k })
    }

    fn greedy_walk(
        &self,
        start: TriePos,
        budget: usize,
        filter: S::Filter,
    ) -> (Vec<TokenId>, Vec<f32>) {
        let mut v = start.node;
        let mut k = start.matched;
        let mut draft = Vec::with_capacity(budget);
        let mut conf = Vec::with_capacity(budget);
        while draft.len() < budget {
            let ll = label_len_of(self.nodes, v);
            if k < ll {
                if self.store.weight(v as usize, filter) == 0 {
                    break;
                }
                let lt = self.labels.slice(self.nodes[v as usize].label);
                draft.push(lt[k as usize]);
                conf.push(1.0);
                k += 1;
            } else {
                let parent_w = self.store.weight(v as usize, filter);
                let mut best: Option<(TokenId, u32, u64)> = None;
                self.nodes[v as usize].children.for_each(|tok, child| {
                    let w = self.store.weight(child as usize, filter);
                    if w == 0 {
                        return; // invisible under this filter
                    }
                    match best {
                        None => best = Some((tok, child, w)),
                        Some((_, _, bw)) => {
                            if w > bw {
                                best = Some((tok, child, w));
                            }
                        }
                    }
                });
                let Some((tok, child, w)) = best else { break };
                draft.push(tok);
                conf.push((w as f64 / parent_w.max(1) as f64) as f32);
                v = child;
                k = 1;
            }
        }
        (draft, conf)
    }
}

/// Size gauges stamped onto a [`TrieSnapshot`] at publish time —
/// precomputed from the writer's incrementally maintained counters, so
/// per-step telemetry never rescans the arena.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SnapshotStats {
    /// Explicit nodes allocated (root included).
    pub nodes: usize,
    /// Uncompressed-equivalent node count (see
    /// [`ArenaTrie::token_positions`]).
    pub token_positions: usize,
    /// Approximate heap bytes (see [`ArenaTrie::approx_bytes`]).
    pub heap_bytes: usize,
    /// Exact suffix-link rebuilds performed by the writer so far.
    pub link_rebuilds: u64,
}

/// The READ half of the snapshot split: an immutable view of one
/// [`ArenaTrie`] exactly as of its [`ArenaTrie::publish`] call. All state
/// is chunk-shared (`Arc`) with the writer; every method takes `&self` and
/// acquires no lock — a `TrieSnapshot` holds no [`SharedPool`], so lock
/// acquisition on the draft path is unrepresentable. `Send + Sync` and
/// O(chunk-table) to clone: any number of reader threads can walk one
/// snapshot (or their own clones) concurrently with the writer mutating.
#[derive(Debug, Clone)]
pub struct TrieSnapshot<S: CountStore> {
    nodes: CowVec<Node>,
    store: S,
    labels: PoolSnapshot,
    max_depth: usize,
    stats: SnapshotStats,
}

impl<S: CountStore> TrieSnapshot<S> {
    fn read(&self) -> TrieRead<'_, S, PoolSnapshot> {
        TrieRead {
            nodes: &self.nodes,
            store: &self.store,
            labels: &self.labels,
            max_depth: self.max_depth,
        }
    }

    /// The count rows as of the publish (filters evaluate against these).
    pub fn store(&self) -> &S {
        &self.store
    }

    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    /// Size gauges as of the publish (no rescan — see [`SnapshotStats`]).
    pub fn stats(&self) -> SnapshotStats {
        self.stats
    }

    pub fn node_count(&self) -> usize {
        self.stats.nodes
    }

    /// See [`ArenaTrie::locate`] — same implementation, snapshot labels.
    pub fn locate(&self, pattern: &[TokenId]) -> Option<TriePos> {
        self.read().locate(pattern)
    }

    /// See [`ArenaTrie::deepest_visible_prefix`].
    pub fn deepest_visible_prefix(
        &self,
        context: &[TokenId],
        filter: S::Filter,
    ) -> Option<(usize, usize)> {
        self.read().deepest_visible_prefix(context, filter)
    }

    /// See [`ArenaTrie::deepest_suffix`].
    pub fn deepest_suffix(
        &self,
        context: &[TokenId],
        max_len: usize,
        filter: S::Filter,
    ) -> (usize, TriePos) {
        self.read().deepest_suffix(context, max_len, filter)
    }

    /// See [`ArenaTrie::walk_suffix_chain`].
    pub fn walk_suffix_chain<F: FnMut(usize, TriePos) -> bool>(
        &self,
        matched: &[TokenId],
        start: TriePos,
        f: F,
    ) {
        walk_chain_nodes(&self.nodes, matched, start, f)
    }

    /// See [`ArenaTrie::greedy_walk`].
    pub fn greedy_walk(
        &self,
        start: TriePos,
        budget: usize,
        filter: S::Filter,
    ) -> (Vec<TokenId>, Vec<f32>) {
        self.read().greedy_walk(start, budget, filter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn plain(max_depth: usize) -> ArenaTrie<Counts> {
        ArenaTrie::new(max_depth, Counts::default())
    }

    fn count(t: &ArenaTrie<Counts>, p: &[u32]) -> u64 {
        t.locate(p).map(|pos| t.store().get(pos.row())).unwrap_or(0)
    }

    /// Reconstruct the string of an explicit node from parent pointers —
    /// test-only helper for checking suffix-link validity.
    fn string_of(t: &ArenaTrie<Counts>, node: usize) -> Vec<u32> {
        let mut parts: Vec<Vec<u32>> = Vec::new();
        let mut v = node;
        while v != 0 {
            let pg = t.pool.lock();
            parts.push(pg.slice(t.nodes[v].label).to_vec());
            v = t.nodes[v].parent as usize;
        }
        parts.reverse();
        parts.concat()
    }

    // -----------------------------------------------------------------
    // The pre-compression one-node-per-token trie, kept ONLY as the
    // executable specification the compressed walks are property-tested
    // against: same CountStore rows, same bump pattern, naive walks.
    // -----------------------------------------------------------------
    struct RefTrie {
        children: Vec<std::collections::BTreeMap<u32, usize>>,
        counts: Vec<u64>,
        max_depth: usize,
    }

    impl RefTrie {
        fn new(max_depth: usize) -> RefTrie {
            RefTrie {
                children: vec![Default::default()],
                counts: vec![0],
                max_depth: max_depth.max(1),
            }
        }

        fn child(&mut self, u: usize, t: u32) -> usize {
            if let Some(&c) = self.children[u].get(&t) {
                return c;
            }
            let id = self.children.len();
            self.children.push(Default::default());
            self.counts.push(0);
            self.children[u].insert(t, id);
            id
        }

        fn insert_suffixes(&mut self, tokens: &[u32]) {
            for i in 0..tokens.len() {
                self.counts[0] += 1;
                let mut u = 0;
                for &t in &tokens[i..(i + self.max_depth).min(tokens.len())] {
                    u = self.child(u, t);
                    self.counts[u] += 1;
                }
            }
        }

        fn locate(&self, p: &[u32]) -> Option<usize> {
            let mut u = 0;
            for t in p {
                u = *self.children[u].get(t)?;
            }
            Some(u)
        }

        fn count(&self, p: &[u32]) -> u64 {
            self.locate(p).map(|u| self.counts[u]).unwrap_or(0)
        }

        fn deepest_suffix(&self, ctx: &[u32], max_len: usize) -> usize {
            let cap = ctx.len().min(max_len).min(self.max_depth);
            for take in (1..=cap).rev() {
                if self.locate(&ctx[ctx.len() - take..]).is_some() {
                    return take;
                }
            }
            0
        }

        fn greedy(&self, ctx: &[u32], max_match: usize, budget: usize) -> (Vec<u32>, Vec<f32>) {
            let mlen = self.deepest_suffix(ctx, max_match);
            if mlen == 0 || budget == 0 {
                return (Vec::new(), Vec::new());
            }
            let mut u = self.locate(&ctx[ctx.len() - mlen..]).unwrap();
            let mut draft = Vec::new();
            let mut conf = Vec::new();
            for _ in 0..budget {
                let parent_w = self.counts[u];
                let mut best: Option<(u32, usize, u64)> = None;
                for (&t, &c) in &self.children[u] {
                    let w = self.counts[c];
                    if w == 0 {
                        continue;
                    }
                    match best {
                        None => best = Some((t, c, w)),
                        Some((_, _, bw)) => {
                            if w > bw {
                                best = Some((t, c, w));
                            }
                        }
                    }
                }
                let Some((t, c, w)) = best else { break };
                draft.push(t);
                conf.push((w as f64 / parent_w.max(1) as f64) as f32);
                u = c;
            }
            (draft, conf)
        }

        /// Rebuild keeping nodes whose count passes `pred` (threshold
        /// predicates are ancestor-closed: counts shrink with depth).
        fn compact(&mut self, min_count: u64) {
            let mut keep_children: Vec<std::collections::BTreeMap<u32, usize>> =
                vec![Default::default()];
            let mut keep_counts = vec![self.counts[0]];
            let mut stack = vec![(0usize, 0usize)];
            while let Some((old, new)) = stack.pop() {
                let kids: Vec<(u32, usize)> =
                    self.children[old].iter().map(|(&t, &c)| (t, c)).collect();
                for (t, c) in kids {
                    if self.counts[c] < min_count {
                        continue;
                    }
                    let id = keep_counts.len();
                    keep_children.push(Default::default());
                    keep_counts.push(self.counts[c]);
                    keep_children[new].insert(t, id);
                    stack.push((c, id));
                }
            }
            self.children = keep_children;
            self.counts = keep_counts;
        }
    }

    #[test]
    fn child_table_inline_and_spill_paths() {
        let mut t = ChildTable::default();
        for (i, tok) in [7u32, 3, 9, 1, 12, 5, 20, 15].iter().enumerate() {
            t.insert(*tok, i as u32 + 10);
        }
        assert_eq!(t.len(), 8);
        assert_eq!(t.get(3), Some(11));
        assert_eq!(t.get(2), None);
        t.set(3, 77);
        assert_eq!(t.get(3), Some(77));
        // Ninth child spills to the sorted vector.
        t.insert(4, 99);
        assert_eq!(t.len(), 9);
        let mut order = Vec::new();
        t.for_each(|tok, _| order.push(tok));
        assert_eq!(order, vec![1, 3, 4, 5, 7, 9, 12, 15, 20]);
        assert_eq!(t.get(4), Some(99));
        assert_eq!(t.get(7), Some(10));
        t.set(4, 100);
        assert_eq!(t.get(4), Some(100));
        assert!(t.heap_bytes() > 0);
    }

    #[test]
    fn child_table_branchless_probe_matches_linear() {
        // The masked probe must behave exactly like a linear scan for every
        // fill level, including token id 0 in and out of the table.
        for fill in 0..=INLINE_CHILDREN {
            let mut t = ChildTable::default();
            let toks: Vec<u32> = (0..fill as u32).map(|i| i * 3).collect();
            for (i, &tok) in toks.iter().enumerate() {
                t.insert(tok, 100 + i as u32);
            }
            for probe in 0..30u32 {
                let expect = toks.iter().position(|&x| x == probe).map(|i| 100 + i as u32);
                assert_eq!(t.get(probe), expect, "fill={fill} probe={probe}");
            }
        }
    }

    #[test]
    fn pool_interns_and_dedups() {
        let pool = SharedPool::new();
        let mut pg = pool.lock();
        let a = pg.intern(&[1, 2, 3, 4]);
        pg.retain(a);
        let b = pg.intern(&[1, 2, 3, 4]);
        assert_eq!(a, b, "identical content hash-conses to one segment");
        let c = pg.intern(&[9, 9]);
        pg.retain(c);
        assert_ne!(a, c);
        let st = pg.stats();
        assert_eq!(st.segments, 2);
        assert_eq!(st.live_tokens, 6);
        // Releasing the last reference kills the segment.
        pg.release(c);
        let st = pg.stats();
        assert_eq!(st.segments, 1);
        assert_eq!(st.live_tokens, 4, "death frees its tokens immediately");
        // Re-interning dead content allocates fresh bytes.
        let c2 = pg.intern(&[9, 9]);
        pg.retain(c2);
        assert_eq!(pg.stats().live_tokens, 6);
        assert_eq!(pg.stats().segments, 2);
    }

    #[test]
    fn pool_frees_dead_segments_and_preserves_survivor_slices() {
        let pool = SharedPool::new();
        let mut pg = pool.lock();
        // Many segments, then kill most of them interleaved: per-segment
        // storage frees each dead segment's tokens immediately (no deferred
        // compaction pass), and surviving SegRefs (segment id + relative
        // range) stay valid throughout.
        let mut ids = Vec::new();
        for i in 0..64u32 {
            let content: Vec<u32> = (0..128).map(|j| i * 1000 + j).collect();
            let id = pg.intern(&content);
            pg.retain(id);
            ids.push(id);
        }
        for &id in ids.iter().step_by(2) {
            pg.release(id);
        }
        for (i, &id) in ids.iter().enumerate() {
            if i % 2 == 1 && i > 32 {
                pg.release(id);
            }
        }
        // 16 odd ids ≤ 32 survive; everything else freed its tokens at
        // release time.
        assert_eq!(pg.stats().segments, 16);
        assert_eq!(pg.stats().live_tokens, 16 * 128);
        assert_eq!(pg.stats().dead_tokens, 0, "no deferred dead bytes");
        // Survivors still read back their exact content through SegRefs.
        for (i, &id) in ids.iter().enumerate() {
            if i % 2 == 1 && i <= 32 {
                let r = SegRef { seg: id, start: 5, len: 7 };
                let expect: Vec<u32> = (5..12).map(|j| i as u32 * 1000 + j).collect();
                assert_eq!(pg.slice(r), expect.as_slice(), "seg {id}");
            }
        }
    }

    #[test]
    fn insert_suffixes_counts_are_occurrences() {
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 1, 2, 3], ());
        assert_eq!(count(&t, &[1, 2]), 2);
        assert_eq!(count(&t, &[1, 2, 3]), 1);
        assert_eq!(count(&t, &[2, 1]), 1);
        assert_eq!(count(&t, &[3, 1]), 0);
        assert_eq!(t.store().get(0), 5, "root counts one per position");
        // Path compression: fewer explicit nodes than token positions.
        assert!(t.node_count() < t.token_positions());
    }

    #[test]
    fn repeated_rollout_adds_no_nodes_or_bytes() {
        let mut t = plain(12);
        let r: Vec<u32> = (0..40).map(|i| (i * 7) % 23).collect();
        t.insert_suffixes(&r, ());
        let nodes = t.node_count();
        let toks = t.pool_stats().live_tokens;
        for _ in 0..5 {
            t.insert_suffixes(&r, ());
        }
        assert_eq!(t.node_count(), nodes, "repeat inserts reuse every path");
        assert_eq!(
            t.pool_stats().live_tokens,
            toks,
            "repeat inserts hash-cons to the existing segment"
        );
        // r's period is 23, so r[..12] occurs at offsets 0 and 23 of every
        // one of the 6 inserted copies.
        assert_eq!(count(&t, &r[..12]), 12);
    }

    #[test]
    fn shared_pool_interns_across_tries() {
        let pool = SharedPool::new();
        let mut a: ArenaTrie<Counts> = ArenaTrie::with_pool(8, Counts::default(), pool.clone());
        let mut b: ArenaTrie<Counts> = ArenaTrie::with_pool(8, Counts::default(), pool.clone());
        let r: Vec<u32> = (0..30).map(|i| i % 11).collect();
        a.insert_suffixes(&r, ());
        let after_a = pool.stats().live_tokens;
        b.insert_suffixes(&r, ());
        assert_eq!(
            pool.stats().live_tokens,
            after_a,
            "second shard reuses the interned segment"
        );
        // Dropping one trie keeps the other's labels alive. ([1,2] occurs
        // at offsets 1, 12 and 23 of the period-11 rollout.)
        drop(a);
        assert_eq!(count(&b, &[1, 2]), 3);
        assert_eq!(pool.stats().live_tokens, after_a);
        drop(b);
        assert_eq!(pool.stats().segments, 0, "all references released");
    }

    #[test]
    fn clone_shares_pool_and_survives_original_drop() {
        let mut t = plain(8);
        t.insert_suffixes(&[5, 6, 7, 8], ());
        let c = t.clone();
        drop(t);
        assert_eq!(count(&c, &[6, 7, 8]), 1, "clone's labels stay live");
        let (len, _) = c.deepest_suffix(&[5, 6, 7], 8, ());
        assert_eq!(len, 3);
    }

    #[test]
    fn deepest_suffix_single_pass_matches_bruteforce() {
        let mut t = plain(6);
        t.insert_suffixes(&[1, 2, 3, 4], ());
        t.insert_suffixes(&[9, 2, 3, 7], ());
        // Context ends ...2,3,4 → longest suffix [2,3,4] (depth 3).
        let (len, pos) = t.deepest_suffix(&[8, 8, 2, 3, 4], 6, ());
        assert_eq!(len, 3);
        assert_eq!(Some(pos), t.locate(&[2, 3, 4]));
        // max_len cap applies.
        let (len, pos) = t.deepest_suffix(&[8, 8, 2, 3, 4], 2, ());
        assert_eq!(len, 2);
        assert_eq!(Some(pos), t.locate(&[3, 4]));
        // Unseen suffix falls back through links to the seen tail.
        let (len, _) = t.deepest_suffix(&[1, 2, 99], 6, ());
        assert_eq!(len, 0);
        let (len, _) = t.deepest_suffix(&[99, 2, 3], 6, ());
        assert_eq!(len, 2);
    }

    #[test]
    fn greedy_walk_majority_and_tiebreak() {
        let mut t = plain(8);
        t.insert_suffixes(&[5, 7, 1], ());
        t.insert_suffixes(&[5, 7, 2], ());
        t.insert_suffixes(&[5, 9, 3], ());
        let p5 = t.locate(&[5]).unwrap();
        let (draft, conf) = t.greedy_walk(p5, 1, ());
        assert_eq!(draft, vec![7]);
        assert!((conf[0] - 2.0 / 3.0).abs() < 1e-6);
        // Equal counts: smallest token id wins.
        let mut t = plain(8);
        t.insert_suffixes(&[5, 7], ());
        t.insert_suffixes(&[5, 3], ());
        let p5 = t.locate(&[5]).unwrap();
        assert_eq!(t.greedy_walk(p5, 4, ()).0, vec![3, /* then nothing */]);
    }

    #[test]
    fn greedy_walk_emits_through_edges() {
        // A long unary path is one edge; the walk must stream its label.
        let mut t = plain(16);
        t.insert_suffixes(&[1, 2, 3, 4, 5, 6], ());
        let (len, pos) = t.deepest_suffix(&[1], 16, ());
        assert_eq!(len, 1);
        let (draft, conf) = t.greedy_walk(pos, 4, ());
        assert_eq!(draft, vec![2, 3, 4, 5]);
        assert!(conf.iter().all(|&c| (c - 1.0).abs() < 1e-6));
    }

    #[test]
    fn prefix_insert_and_visible_prefix() {
        let mut t = plain(4);
        t.insert_prefix(&[10, 11, 12, 13, 99], ()); // truncated at depth 4
        assert!(t.locate(&[10, 11, 12, 13]).is_some());
        assert!(t.locate(&[10, 11, 12, 13, 99]).is_none());
        let (node, depth) = t.deepest_visible_prefix(&[10, 11, 20], ()).unwrap();
        assert_eq!(depth, 2);
        assert_eq!(node, t.locate(&[10, 11]).unwrap().row());
        assert!(t.deepest_visible_prefix(&[7], ()).is_none());
        // A mid-edge unregister walk splits the boundary it needs.
        let path = t.prefix_path_split(&[10, 11]).unwrap();
        assert_eq!(path.len(), 1, "one explicit node on the [10,11] path");
        assert!(t.prefix_path_split(&[10, 77]).is_none());
    }

    #[test]
    fn insert_prefix_returns_explicit_terminal() {
        let mut t = plain(8);
        let a = t.insert_prefix(&[1, 2, 3, 4], ());
        // A shorter registration terminates mid-edge → split → its own node.
        let b = t.insert_prefix(&[1, 2], ());
        assert_ne!(a, b);
        assert_eq!(t.locate(&[1, 2]).unwrap().row(), b);
        assert_eq!(t.store().get(b), 2, "split copied the deep count, then bumped");
        assert_eq!(t.store().get(a), 1);
    }

    #[test]
    fn compact_keeps_weighted_nodes_and_links() {
        let mut t = plain(6);
        t.insert_suffixes(&[1, 2, 3], ());
        t.insert_suffixes(&[4, 2, 3], ());
        let before = t.node_count();
        // Keep everything: structure and answers unchanged, links exact.
        t.compact(|s, n| s.weight(n, ()) > 0);
        assert_eq!(t.node_count(), before);
        let (len, _) = t.deepest_suffix(&[9, 4, 2, 3], 6, ());
        assert_eq!(len, 3);
        // Further inserts after compaction keep working.
        t.insert_suffixes(&[4, 2, 3, 5], ());
        let (len, _) = t.deepest_suffix(&[4, 2, 3, 5], 6, ());
        assert_eq!(len, 4);
        assert_eq!(count(&t, &[2, 3]), 3);
    }

    #[test]
    fn compact_reinterns_labels_and_frees_pinned_segments() {
        // One long rollout (one 400-token pool segment) plus a re-seen
        // 10-token prefix. Compacting away the once-seen paths must NOT
        // leave the survivors pinning the 400-token segment: labels are
        // re-interned, so the pool shrinks to the live label bytes.
        let mut t = plain(8);
        let big: Vec<u32> = (0..400).collect();
        t.insert_suffixes(&big, ());
        t.insert_suffixes(&big[..10], ());
        let before = t.pool_stats().live_tokens;
        assert!(before >= 400);
        t.compact(|s, n| s.weight(n, ()) >= 2);
        let after = t.pool_stats().live_tokens;
        assert!(
            after * 4 < before,
            "survivors must not pin the dead rollout's segment: {after} vs {before}"
        );
        // The twice-seen content still answers correctly.
        assert_eq!(count(&t, &[0, 1, 2]), 2);
        let (len, _) = t.deepest_suffix(&[99, 0, 1, 2], 8, ());
        assert_eq!(len, 3);
        assert_eq!(count(&t, &[200, 201]), 0, "once-seen paths were dropped");
    }

    #[test]
    fn prop_matches_uncompressed_reference() {
        // THE tentpole anchor: on random insert/compaction streams the
        // compressed trie must answer counts, deepest-suffix matches and
        // greedy drafts (tokens AND confidences) bit-identically to the
        // one-node-per-token reference. Small alphabets force heavy edge
        // splitting; compaction exercises the pool-release + exact-slink
        // rebuild path.
        prop::check(160, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let depth = 2 + g.usize_in(0, 8);
            let mut t = ArenaTrie::new(depth, Counts::default());
            let mut r = RefTrie::new(depth);
            for _ in 0..g.usize_in(1, 5) {
                let roll = g.vec_u32_nonempty(alphabet, 40);
                t.insert_suffixes(&roll, ());
                r.insert_suffixes(&roll);
                if g.usize_in(0, 4) == 0 {
                    // Same threshold compaction on both sides. Thresholds
                    // are substring-closed (counts shrink with length), the
                    // precondition the compressed compact requires.
                    let min = 1 + g.usize_in(0, 1) as u64;
                    t.compact(move |s, n| s.weight(n, ()) >= min);
                    r.compact(min);
                }
                for _ in 0..8 {
                    let pat = g.vec_u32_nonempty(alphabet, depth + 2);
                    prop::require_eq(count(&t, &pat), r.count(&pat), "count")?;
                }
                let ctx = g.vec_u32_nonempty(alphabet, 16);
                let max_match = 1 + g.usize_in(0, 8);
                let budget = g.usize_in(0, 6);
                prop::require_eq(
                    t.deepest_suffix(&ctx, max_match, ()).0,
                    r.deepest_suffix(&ctx, max_match),
                    "deepest suffix length",
                )?;
                let (mlen, pos) = t.deepest_suffix(&ctx, max_match, ());
                let (dt, ct) = if mlen == 0 || budget == 0 {
                    (Vec::new(), Vec::new())
                } else {
                    t.greedy_walk(pos, budget, ())
                };
                let (dr, cr) = r.greedy(&ctx, max_match, budget);
                prop::require_eq(dt, dr, "greedy draft tokens")?;
                prop::require_eq(ct, cr, "greedy draft confidences")?;
            }
            // Structural accounting: the reference's node count IS the
            // compressed trie's token-position count.
            prop::require_eq(
                t.token_positions(),
                r.counts.len(),
                "token positions == uncompressed nodes",
            )?;
            prop::require(
                t.node_count() <= t.token_positions(),
                "compression never inflates",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_suffix_links_at_or_above_their_target() {
        // Every explicit node's link must name a node whose string is a
        // prefix of the node's string minus its first token — the exact
        // invariant the O(m) walk's canonize step relies on.
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 3) as u32;
            let mut t = ArenaTrie::new(2 + g.usize_in(0, 5), Counts::default());
            for _ in 0..g.usize_in(1, 4) {
                t.insert_suffixes(&g.vec_u32_nonempty(alphabet, 25), ());
            }
            if g.bool() {
                t.compact(|s, n| s.weight(n, ()) > 0);
            }
            for v in 1..t.node_count() {
                let s = string_of(&t, v);
                let link = t.nodes[v].slink as usize;
                let ls = string_of(&t, link);
                prop::require(
                    ls.len() <= s.len() - 1,
                    "link not deeper than the suffix",
                )?;
                prop::require_eq(
                    &s[1..1 + ls.len()],
                    ls.as_slice(),
                    "link string is a prefix of the suffix",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn divergence_at_second_label_token_splits_after_one() {
        // The child table is keyed by first label tokens, so the shared
        // cursor compares labels from index 1 (a probed child's label[0]
        // matches by construction — NOT a policy difference between the
        // walks). A mismatch at the SECOND token must split after exactly
        // one matched token in both insert drivers and refuse — mutating
        // nothing — in the read-mostly driver.
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 3], ());
        t.insert_suffixes(&[1, 9], ());
        let p1 = t.locate(&[1]).expect("present");
        assert!(t.at_node(p1), "divergence after one matched token exposes [1]");
        assert_eq!(count(&t, &[1]), 2);

        let mut p = plain(8);
        p.insert_prefix(&[5, 6, 7], ());
        p.insert_prefix(&[5, 9], ());
        let p5 = p.locate(&[5]).expect("present");
        assert!(p.at_node(p5), "prefix driver splits on the same boundary");

        let before = p.node_count();
        assert!(p.prefix_path_split(&[5, 6, 9]).is_none(), "divergence refused");
        assert_eq!(before, p.node_count(), "read-mostly walk must not mutate on divergence");
    }

    #[test]
    fn root_bump_is_suffix_policy_only() {
        // Which driver bumps the root is policy, not mechanics: suffix
        // indexing counts one ε occurrence per start position, prefix
        // registration never counts the root.
        let mut t = plain(8);
        t.insert_prefix(&[1, 2], ());
        assert_eq!(t.store().get(0), 0, "prefix registration never counts the root");
        t.insert_suffixes(&[3, 4], ());
        assert_eq!(t.store().get(0), 2, "suffix indexing counts ε once per start");
    }

    #[test]
    fn split_copies_row_before_the_terminal_bump() {
        // Bump-AFTER-split is load-bearing: the upper node must copy the
        // lower node's pre-bump row, then take the terminal bump alone —
        // otherwise positions below the terminal would inherit an
        // occurrence they never saw.
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 3, 4], ());
        t.insert_suffixes(&[1, 2], ());
        assert_eq!(count(&t, &[1, 2]), 2, "terminal node: copied 1, bumped to 2");
        assert_eq!(count(&t, &[1, 2, 3]), 1, "below the terminal: pre-bump copy only");
        assert_eq!(count(&t, &[1]), 2, "mid-edge above the terminal reads the split node");
    }

    #[test]
    fn empty_prefix_is_never_registered_nor_unregisterable() {
        // Satellite regression: insert_prefix on an empty prefix lands on
        // the root without bumping, so prefix_path_split must report "was
        // never registered" (None) instead of a hollow Some(vec![]) — the
        // inverse the router relies on.
        let mut t = plain(8);
        assert_eq!(t.insert_prefix(&[], ()), 0, "empty registration lands on the root");
        assert_eq!(t.store().get(0), 0, "...without bumping it");
        assert_eq!(t.node_count(), 1);
        assert!(t.prefix_path_split(&[]).is_none(), "nothing to reverse");
    }

    #[test]
    fn pending_slinks_resolve_to_existing_deep_targets() {
        // The resolving walk creates NOTHING — it only traverses an
        // existing path — yet the previous start's pending links must land
        // on the deepest explicit node of that walk, not default to root.
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 3], ());
        t.insert_suffixes(&[9, 1, 2, 3], ());
        let leaf = t.locate(&[9, 1, 2, 3]).expect("new leaf");
        let target = t.locate(&[1, 2, 3]).expect("pre-existing path");
        assert!(t.at_node(leaf) && t.at_node(target));
        assert_eq!(
            t.nodes[leaf.row()].slink,
            target.node,
            "pending slink must land on the deepest valid target"
        );
    }

    #[test]
    fn pending_slinks_resolve_through_pure_in_edge_terminations() {
        // The resolving walk terminates INSIDE one long edge — a pure
        // in-edge termination whose only explicit path node is the
        // terminal split itself. The pending link must land on that split
        // node (a cursor driver that forgot to record terminal splits in
        // the walk path would silently default every such link to root).
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 3, 4, 5], ());
        t.insert_suffixes(&[9, 1, 2, 3], ());
        let leaf = t.locate(&[9, 1, 2, 3]).expect("new leaf");
        let split = t.locate(&[1, 2, 3]).expect("present");
        assert!(t.at_node(split), "the in-edge termination split its boundary");
        assert_eq!(t.nodes[leaf.row()].slink, split.node);
        // The chain continues through the shorter suffixes' terminal
        // splits: [1,2,3] → [2,3].
        let s23 = t.locate(&[2, 3]).expect("present");
        assert!(t.at_node(s23));
        assert_eq!(t.nodes[split.row()].slink, s23.node);
    }

    #[test]
    fn cursor_retains_one_segment_ref_per_edge() {
        // Segment refcounts are owned by the cursor (one retain per leaf
        // edge) and split_edge (one retain when one edge becomes two),
        // identically across all three drivers; dropping the trie must
        // release every reference the walks ever took.
        let pool = SharedPool::new();
        {
            let mut t: ArenaTrie<Counts> =
                ArenaTrie::with_pool(8, Counts::default(), pool.clone());
            t.insert_suffixes(&[1, 2, 3, 4], ());
            t.insert_suffixes(&[1, 2, 9, 9], ()); // divergent splits + leaves
            t.insert_prefix(&[1, 2, 3], ()); // prefix termination split
            assert!(t.prefix_path_split(&[1]).is_some()); // read-path split
            assert!(pool.stats().segments > 0);
        }
        let st = pool.stats();
        assert_eq!(st.segments, 0, "every cursor retain must match one release");
        assert_eq!(st.live_tokens, 0);
    }

    #[test]
    fn link_refresh_triggers_on_uncompacted_growth() {
        // A plain counting trie never compacts; once the arena passes the
        // minimum size with enough fresh (approximately linked) nodes, the
        // links_dirty threshold must fire the exact rebuild on its own.
        let mut t = plain(12);
        let mut rng = crate::util::rng::Rng::seed_from_u64(11);
        for _ in 0..40 {
            let r: Vec<u32> = (0..40).map(|_| rng.below(50) as u32).collect();
            t.insert_suffixes(&r, ());
        }
        assert!(t.node_count() > LINK_REBUILD_MIN_NODES);
        assert!(t.link_rebuilds() >= 1, "threshold refresh never fired");
        // Queries stay exact regardless of when the trigger last ran.
        let (len, pos) = t.deepest_suffix(&[50, 50], 8, ());
        assert_eq!((len, pos), (0, TriePos::ROOT), "token 50 was never inserted");
    }

    /// The exact link target for `v`: deepest explicit node at-or-above
    /// the position of `str(v)[1..]` — what `rebuild_suffix_links` must
    /// produce (test-only oracle via `locate`).
    fn exact_slink(t: &ArenaTrie<Counts>, v: usize) -> usize {
        let s = string_of(t, v);
        if s.len() <= 1 {
            return 0;
        }
        let p = t.locate(&s[1..]).expect("suffix present by substring closure");
        if t.at_node(p) {
            p.node as usize
        } else {
            t.nodes[p.node as usize].parent as usize
        }
    }

    #[test]
    fn prop_deepest_suffix_unchanged_by_link_rebuild() {
        // Links are an accelerator, never an answer: after a long mixed
        // insert/split stream, a trie still carrying approximate links and
        // a clone whose links were freshly rebuilt must agree on every
        // deepest-suffix query (length AND position) — and every rebuilt
        // link must name the DEEPEST valid at-or-above target.
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let depth = 2 + g.usize_in(0, 8);
            let mut t = ArenaTrie::new(depth, Counts::default());
            for _ in 0..g.usize_in(1, 6) {
                t.insert_suffixes(&g.vec_u32_nonempty(alphabet, 40), ());
            }
            let mut exact = t.clone();
            exact.rebuild_suffix_links();
            for v in 1..exact.node_count() {
                prop::require_eq(
                    exact.nodes[v].slink as usize,
                    exact_slink(&exact, v),
                    "rebuilt link must be the deepest valid target",
                )?;
            }
            for _ in 0..12 {
                let ctx = g.vec_u32_nonempty(alphabet, 18);
                let max_len = 1 + g.usize_in(0, 10);
                prop::require_eq(
                    t.deepest_suffix(&ctx, max_len, ()),
                    exact.deepest_suffix(&ctx, max_len, ()),
                    "deepest suffix approx vs exact links",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_walk_suffix_chain_rows_match_locate() {
        // The chain must visit, for every suffix length, exactly the row
        // `locate` reports for that suffix.
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let mut t = ArenaTrie::new(2 + g.usize_in(0, 8), Counts::default());
            for _ in 0..g.usize_in(1, 4) {
                t.insert_suffixes(&g.vec_u32_nonempty(alphabet, 30), ());
            }
            let ctx = g.vec_u32_nonempty(alphabet, 12);
            let (mlen, pos) = t.deepest_suffix(&ctx, 10, ());
            if mlen == 0 {
                return Ok(());
            }
            let matched = &ctx[ctx.len() - mlen..];
            let mut seen: Vec<(usize, usize)> = Vec::new();
            t.walk_suffix_chain(matched, pos, |d, p| {
                seen.push((d, p.row()));
                true
            });
            prop::require_eq(seen.len(), mlen, "chain visits every length")?;
            for &(d, row) in &seen {
                let expect = t.locate(&matched[mlen - d..]).expect("suffix present");
                prop::require_eq(row, expect.row(), "chain row == locate row")?;
            }
            Ok(())
        });
    }

    /// Save pool + trie, load into a FRESH pool, and return the restored
    /// trie (the das-store-v1 round trip at the core layer).
    fn roundtrip(t: &ArenaTrie<Counts>) -> ArenaTrie<Counts> {
        let mut w = Writer::new();
        t.pool().save_state(&mut w);
        t.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let (pool, recorded) = SharedPool::load_state(&mut r).unwrap();
        let restored = ArenaTrie::load_state(&mut r, pool.clone()).unwrap();
        assert!(r.is_empty(), "round trip consumed every byte");
        assert_eq!(
            pool.reconcile_recorded(&recorded),
            0,
            "single-trie snapshot refcounts re-derive exactly"
        );
        restored
    }

    #[test]
    fn snapshot_roundtrip_is_bit_identical() {
        let mut t = plain(10);
        t.insert_suffixes(&[1, 2, 3, 4, 2, 3, 9], ());
        t.insert_suffixes(&[1, 2, 7, 7], ());
        let r = roundtrip(&t);
        assert_eq!(r.node_count(), t.node_count());
        assert_eq!(r.token_positions(), t.token_positions());
        assert_eq!(r.approx_bytes(), t.approx_bytes(), "length-based bytes restore exactly");
        assert_eq!(r.pool_stats().live_tokens, t.pool_stats().live_tokens);
        assert_eq!(r.link_rebuilds(), t.link_rebuilds());
        for pat in [&[1u32, 2][..], &[2, 3], &[2, 3, 9], &[7], &[9, 9]] {
            assert_eq!(count(&r, pat), count(&t, pat), "counts for {pat:?}");
        }
        let ctx = [5u32, 1, 2, 3];
        assert_eq!(r.deepest_suffix(&ctx, 8, ()), t.deepest_suffix(&ctx, 8, ()));
        let (_, pos) = r.deepest_suffix(&ctx, 8, ());
        assert_eq!(r.greedy_walk(pos, 4, ()), t.greedy_walk(pos, 4, ()));
        // The restored trie keeps absorbing: inserts extend it identically.
        let mut t2 = t.clone();
        let mut r2 = r;
        t2.insert_suffixes(&[2, 3, 9, 9], ());
        r2.insert_suffixes(&[2, 3, 9, 9], ());
        assert_eq!(r2.node_count(), t2.node_count());
        assert_eq!(count(&r2, &[9, 9]), count(&t2, &[9, 9]));
    }

    #[test]
    fn corrupt_trie_sections_error_and_leave_pool_untouched() {
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 3, 4, 5], ());
        let mut w = Writer::new();
        t.pool().save_state(&mut w);
        t.save_state(&mut w);
        let bytes = w.into_bytes();
        // Damage node 1's parent pointer in the trie section: load must
        // reject with Corrupt, and the freshly loaded pool must keep every
        // refcount at zero (validation happens before any retain).
        let mut r = Reader::new(&bytes);
        let (pool, recorded) = SharedPool::load_state(&mut r).unwrap();
        let consumed = bytes.len() - r.remaining();
        let mut bad = bytes[consumed..].to_vec();
        // Section layout: "trie" tag (8) + 4 scalars (32) = 40-byte header,
        // then 24-byte node records; node 1's parent field is bytes 12..16
        // of its record.
        let off = 40 + 24 + 12;
        bad[off..off + 4].copy_from_slice(&9999u32.to_le_bytes());
        let mut br = Reader::new(&bad);
        match ArenaTrie::<Counts>::load_state(&mut br, pool.clone()) {
            Err(StoreError::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {:?}", other.map(|t| t.node_count())),
        }
        // Every recorded refcount disagrees with the (all-zero) derived
        // ones — proof that the failed load never touched the pool.
        assert_eq!(pool.reconcile_recorded(&recorded), recorded.len());
        // Whatever happened above, reloading the pristine section works.
        let mut r2 = Reader::new(&bytes);
        let (pool2, _) = SharedPool::load_state(&mut r2).unwrap();
        let t2 = ArenaTrie::<Counts>::load_state(&mut r2, pool2).unwrap();
        assert_eq!(t2.node_count(), t.node_count());
    }

    #[test]
    fn prop_snapshot_roundtrip_matches_on_random_streams() {
        // Random insert/compaction streams: the restored trie must answer
        // counts, deepest-suffix and greedy drafts exactly like the
        // original, and keep behaving identically under further inserts.
        prop::check(64, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let depth = 2 + g.usize_in(0, 8);
            let mut t = plain(depth);
            for _ in 0..g.usize_in(1, 5) {
                t.insert_suffixes(&g.vec_u32_nonempty(alphabet, 40), ());
            }
            if g.bool() {
                t.compact(|s, n| s.get(n) >= 1);
            }
            let r = roundtrip(&t);
            prop::require_eq(r.node_count(), t.node_count(), "nodes")?;
            prop::require_eq(r.token_positions(), t.token_positions(), "positions")?;
            prop::require_eq(r.approx_bytes(), t.approx_bytes(), "heap bytes")?;
            for _ in 0..8 {
                let pat = g.vec_u32_nonempty(alphabet, depth);
                prop::require_eq(count(&r, &pat), count(&t, &pat), "count")?;
            }
            let ctx = g.vec_u32_nonempty(alphabet, 16);
            let (ml, pa) = t.deepest_suffix(&ctx, 12, ());
            let (rl, pb) = r.deepest_suffix(&ctx, 12, ());
            prop::require_eq(rl, ml, "deepest suffix len")?;
            prop::require_eq(
                r.greedy_walk(pb, 6, ()),
                t.greedy_walk(pa, 6, ()),
                "greedy draft",
            )?;
            Ok(())
        });
    }

    #[test]
    fn prop_published_snapshot_reads_match_locked_reference() {
        // THE snapshot anchor: at every publish point, every read the
        // lock-free snapshot answers — locate + counts, deepest suffix,
        // greedy drafts (tokens AND confidences), visible prefixes — must
        // be bit-identical to the locked walk on the writer, across random
        // insert/compaction streams. And once the writer mutates past a
        // publish, the old snapshot must keep answering from its frozen
        // state (stats included).
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let depth = 2 + g.usize_in(0, 8);
            let mut t = plain(depth);
            for _ in 0..g.usize_in(1, 5) {
                t.insert_suffixes(&g.vec_u32_nonempty(alphabet, 40), ());
                if g.usize_in(0, 4) == 0 {
                    t.compact(|s, n| s.weight(n, ()) >= 1);
                }
                let snap = t.publish();
                prop::require_eq(snap.stats().nodes, t.node_count(), "stat nodes")?;
                prop::require_eq(
                    snap.stats().token_positions,
                    t.token_positions(),
                    "stat positions",
                )?;
                prop::require_eq(snap.stats().heap_bytes, t.approx_bytes(), "stat bytes")?;
                for _ in 0..6 {
                    let pat = g.vec_u32_nonempty(alphabet, depth + 2);
                    prop::require_eq(snap.locate(&pat), t.locate(&pat), "locate")?;
                    let sc = snap.locate(&pat).map(|p| snap.store().get(p.row()));
                    let tc = t.locate(&pat).map(|p| t.store().get(p.row()));
                    prop::require_eq(sc, tc, "count at position")?;
                    let ctx = g.vec_u32_nonempty(alphabet, 16);
                    let max_match = 1 + g.usize_in(0, 8);
                    prop::require_eq(
                        snap.deepest_suffix(&ctx, max_match, ()),
                        t.deepest_suffix(&ctx, max_match, ()),
                        "deepest suffix",
                    )?;
                    let (ml, pos) = snap.deepest_suffix(&ctx, max_match, ());
                    if ml > 0 {
                        prop::require_eq(
                            snap.greedy_walk(pos, 6, ()),
                            t.greedy_walk(pos, 6, ()),
                            "greedy draft",
                        )?;
                        let mut srows: Vec<(usize, TriePos)> = Vec::new();
                        let mut trows: Vec<(usize, TriePos)> = Vec::new();
                        let matched = &ctx[ctx.len() - ml.min(ctx.len())..];
                        snap.walk_suffix_chain(matched, pos, |d, p| {
                            srows.push((d, p));
                            true
                        });
                        t.walk_suffix_chain(matched, pos, |d, p| {
                            trows.push((d, p));
                            true
                        });
                        prop::require_eq(srows, trows, "suffix chain")?;
                    }
                    prop::require_eq(
                        snap.deepest_visible_prefix(&ctx, ()),
                        t.deepest_visible_prefix(&ctx, ()),
                        "visible prefix",
                    )?;
                }
            }
            // Staleness semantics: a snapshot is frozen at its publish.
            let snap = t.publish();
            let frozen = snap.stats();
            let probe = g.vec_u32_nonempty(alphabet, 12);
            let before = snap.locate(&probe).map(|p| snap.store().get(p.row()));
            t.insert_suffixes(&probe, ());
            prop::require_eq(snap.stats(), frozen, "stats frozen after writer mutates")?;
            prop::require_eq(
                snap.locate(&probe).map(|p| snap.store().get(p.row())),
                before,
                "reads frozen after writer mutates",
            )?;
            Ok(())
        });
    }

    #[test]
    fn snapshot_outlives_writer_and_freed_segments() {
        // A published snapshot holds its labels via per-segment Arcs: the
        // writer can compact, free every segment, and even drop entirely —
        // the snapshot keeps answering from exactly its publish state.
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 3, 4, 5], ());
        t.insert_suffixes(&[1, 2, 9], ());
        let snap = t.publish();
        let pool = t.pool();
        drop(t); // releases every edge segment in the pool
        assert_eq!(pool.stats().segments, 0, "writer drop freed all segments");
        let (len, pos) = snap.deepest_suffix(&[0, 1, 2, 3], 8, ());
        assert_eq!(len, 3);
        let (draft, conf) = snap.greedy_walk(pos, 2, ());
        assert_eq!(draft, vec![4, 5]);
        assert_eq!(conf.len(), 2);
        assert!(snap.locate(&[1, 2, 9]).is_some());
    }

    #[test]
    fn concurrent_readers_draft_while_writer_absorbs() {
        // Stress the writer/reader split: one writer keeps inserting and
        // publishing into a shared cell while reader threads draft from
        // whatever snapshot is current. Every draft must be valid against
        // the exact snapshot it came from (recomputed post-hoc on the same
        // Arc — no torn reads, no panics).
        use crate::util::cow::SnapshotCell;
        let mut t = plain(8);
        t.insert_suffixes(&[1, 2, 3, 4], ());
        let cell = Arc::new(SnapshotCell::new(Arc::new(t.publish())));
        let rolls: Vec<Vec<u32>> = (0..48)
            .map(|i| (0..20).map(|j| 1 + ((i * 7 + j) % 5) as u32).collect())
            .collect();
        std::thread::scope(|s| {
            for r in 0..4 {
                let cell = Arc::clone(&cell);
                s.spawn(move || {
                    for i in 0..400usize {
                        let snap = cell.load();
                        let ctx = [1 + ((r + i) % 5) as u32, 1 + (i % 5) as u32];
                        let (ml, pos) = snap.deepest_suffix(&ctx, 8, ());
                        let (draft, conf) = snap.greedy_walk(pos, 4, ());
                        // Recompute against the SAME snapshot: a torn read
                        // would make these disagree (or panic above).
                        assert_eq!(snap.deepest_suffix(&ctx, 8, ()), (ml, pos));
                        assert_eq!(snap.greedy_walk(pos, 4, ()), (draft, conf));
                        assert_eq!(draft.len(), conf.len());
                    }
                });
            }
            for roll in &rolls {
                t.insert_suffixes(roll, ());
                cell.store(Arc::new(t.publish()));
            }
        });
        assert_eq!(cell.generation(), rolls.len() as u64);
    }

    #[test]
    fn poisoned_pool_lock_still_serves_readers() {
        // Regression: a panic while holding the pool mutex poisons it; the
        // pool must keep serving (into_inner recovery in SharedPool::lock)
        // instead of cascading the panic into every later trie operation.
        let pool = SharedPool::new();
        let seg = {
            let mut pg = pool.lock();
            let seg = pg.intern(&[7, 8, 9]);
            pg.retain(seg);
            seg
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = pool.lock();
            panic!("injected panic while holding the pool lock");
        }));
        assert!(result.is_err(), "the injected panic must propagate");
        assert!(pool.inner.is_poisoned(), "the mutex must actually be poisoned");
        // Readers after the poisoning still see intact pool state.
        let pg = pool.lock();
        assert_eq!(pg.slice(SegRef { seg, start: 0, len: 3 }), &[7, 8, 9]);
        drop(pg);
        assert_eq!(pool.stats().segments, 1);
    }
}

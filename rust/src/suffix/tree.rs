//! Online suffix tree (Ukkonen's algorithm) over token sequences.
//!
//! This is the paper's §4.1.2 data structure: amortized O(1) per appended
//! token, O(m) longest-match queries for a query of length m, and it supports
//! the *generalized* form (many rollouts in one tree) by appending each
//! rollout followed by a unique sentinel token that never occurs in the
//! vocabulary.
//!
//! Drafting uses the retrieval semantics of suffix-structure speculators
//! (SuffixDecoding, PLD): `longest_suffix_match` returns the text position
//! where (one occurrence of) the longest matching context suffix ends; the
//! proposed draft is simply the tokens that followed that occurrence. The
//! frequency-weighted variant lives in [`super::trie`], which keeps explicit
//! counts; this tree is the exact-match engine and the Fig. 5 subject.

use std::collections::HashMap;

use crate::tokens::TokenId;

/// First token id reserved for rollout terminators. Real vocabulary ids must
/// stay below this; each inserted sequence gets the next sentinel so no
/// suffix of one rollout can match across rollout boundaries.
pub const SENTINEL_BASE: TokenId = 0xF000_0000;

const INVALID: usize = usize::MAX;

#[derive(Debug, Clone)]
struct Node {
    /// Edge label is `text[start..end(node)]` (end exclusive).
    start: usize,
    /// `usize::MAX` means "leaf: grows with the global end".
    end: usize,
    children: HashMap<TokenId, usize>,
    suffix_link: usize,
}

impl Node {
    fn new(start: usize, end: usize) -> Self {
        Node {
            start,
            end,
            children: HashMap::new(),
            suffix_link: 0,
        }
    }
}

/// Ukkonen suffix tree over `u32` tokens.
#[derive(Debug, Clone)]
pub struct SuffixTree {
    text: Vec<TokenId>,
    nodes: Vec<Node>,
    root: usize,
    // Active point.
    active_node: usize,
    active_edge: usize, // index into text of the edge's first token
    active_length: usize,
    remainder: usize,
    next_sentinel: TokenId,
}

impl Default for SuffixTree {
    fn default() -> Self {
        Self::new()
    }
}

impl SuffixTree {
    pub fn new() -> Self {
        let root = Node::new(0, 0);
        SuffixTree {
            text: Vec::new(),
            nodes: vec![root],
            root: 0,
            active_node: 0,
            active_edge: 0,
            active_length: 0,
            remainder: 0,
            next_sentinel: SENTINEL_BASE,
        }
    }

    /// Build from one sequence (terminated internally).
    pub fn build(tokens: &[TokenId]) -> Self {
        let mut t = Self::new();
        t.insert(tokens);
        t
    }

    /// Rebuild a tree from a previously stored raw text (sentinels
    /// INCLUDED — [`SuffixTree::text`] of the saved tree) plus its sentinel
    /// cursor. Ukkonen construction is deterministic in the text, so the
    /// restored tree is structurally identical to the saved one — the
    /// `das-store-v1` persistence path for this substrate serializes the
    /// build input, not the node arena.
    pub fn from_text(text: &[TokenId], next_sentinel: TokenId) -> Self {
        let mut t = Self::new();
        for &tok in text {
            t.extend(tok);
        }
        t.next_sentinel = next_sentinel.max(SENTINEL_BASE);
        t
    }

    /// The sentinel id the next [`SuffixTree::insert`] will consume
    /// (persisted so restored trees keep allocating fresh sentinels).
    pub fn sentinel_cursor(&self) -> TokenId {
        self.next_sentinel
    }

    /// Number of tokens stored (including sentinels).
    pub fn text_len(&self) -> usize {
        self.text.len()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// The raw token store. Draft continuations are read straight from here.
    pub fn text(&self) -> &[TokenId] {
        &self.text
    }

    /// Append a whole rollout and terminate it with a fresh sentinel.
    pub fn insert(&mut self, tokens: &[TokenId]) {
        for &t in tokens {
            debug_assert!(t < SENTINEL_BASE, "token id collides with sentinel space");
            self.extend(t);
        }
        let s = self.next_sentinel;
        self.next_sentinel += 1;
        self.extend(s);
    }

    fn edge_end(&self, node: usize) -> usize {
        if self.nodes[node].end == usize::MAX {
            self.text.len()
        } else {
            self.nodes[node].end
        }
    }

    fn edge_len(&self, node: usize) -> usize {
        self.edge_end(node) - self.nodes[node].start
    }

    /// Ukkonen single-token extension. Amortized O(1).
    #[allow(unused_assignments)] // last_new_node bookkeeping mirrors the canonical algorithm
    pub fn extend(&mut self, token: TokenId) {
        self.text.push(token);
        let pos = self.text.len() - 1;
        self.remainder += 1;
        let mut last_new_node = INVALID;

        while self.remainder > 0 {
            if self.active_length == 0 {
                self.active_edge = pos;
            }
            let edge_tok = self.text[self.active_edge];
            let next = self.nodes[self.active_node].children.get(&edge_tok).copied();
            match next {
                None => {
                    // Rule 2: new leaf off active_node.
                    let leaf = self.nodes.len();
                    self.nodes.push(Node::new(pos, usize::MAX));
                    self.nodes[self.active_node].children.insert(edge_tok, leaf);
                    if last_new_node != INVALID {
                        self.nodes[last_new_node].suffix_link = self.active_node;
                        last_new_node = INVALID;
                    }
                }
                Some(nxt) => {
                    // Walk down if the active length exceeds this edge.
                    let el = self.edge_len(nxt);
                    if self.active_length >= el {
                        self.active_edge += el;
                        self.active_length -= el;
                        self.active_node = nxt;
                        continue;
                    }
                    // Rule 3: the token is already on the edge — stop here.
                    if self.text[self.nodes[nxt].start + self.active_length] == token {
                        if last_new_node != INVALID && self.active_node != self.root {
                            self.nodes[last_new_node].suffix_link = self.active_node;
                            last_new_node = INVALID;
                        }
                        self.active_length += 1;
                        break;
                    }
                    // Rule 2 with split: split the edge, add new leaf.
                    let split = self.nodes.len();
                    let nxt_start = self.nodes[nxt].start;
                    self.nodes
                        .push(Node::new(nxt_start, nxt_start + self.active_length));
                    self.nodes[self.active_node].children.insert(edge_tok, split);
                    let leaf = self.nodes.len();
                    self.nodes.push(Node::new(pos, usize::MAX));
                    self.nodes[split].children.insert(token, leaf);
                    self.nodes[nxt].start += self.active_length;
                    let nxt_tok = self.text[self.nodes[nxt].start];
                    self.nodes[split].children.insert(nxt_tok, nxt);
                    if last_new_node != INVALID {
                        self.nodes[last_new_node].suffix_link = split;
                    }
                    last_new_node = split;
                }
            }
            self.remainder -= 1;
            if self.active_node == self.root && self.active_length > 0 {
                self.active_length -= 1;
                self.active_edge = pos - self.remainder + 1;
            } else if self.active_node != self.root {
                self.active_node = self.nodes[self.active_node].suffix_link;
            }
        }
    }

    /// Walk `pattern` from the root. Returns the number of tokens matched and,
    /// if the whole pattern matched, a text position where one occurrence of
    /// the pattern ENDS (exclusive) — i.e. `text[end - pattern.len() .. end]`
    /// equals the matched pattern, so `text[end..]` is a real continuation.
    fn walk(&self, pattern: &[TokenId]) -> (usize, Option<usize>) {
        let mut node = self.root;
        let mut matched = 0usize;
        let mut text_pos = 0usize; // position in text aligned with `matched`
        while matched < pattern.len() {
            let tok = pattern[matched];
            let Some(&child) = self.nodes[node].children.get(&tok) else {
                return (matched, None);
            };
            let start = self.nodes[child].start;
            let end = self.edge_end(child);
            let mut i = start;
            while i < end && matched < pattern.len() {
                if self.text[i] != pattern[matched] {
                    return (matched, None);
                }
                i += 1;
                matched += 1;
            }
            text_pos = i;
            node = child;
        }
        (matched, Some(text_pos))
    }

    /// Exact containment query, O(m).
    pub fn contains(&self, pattern: &[TokenId]) -> bool {
        pattern.is_empty() || matches!(self.walk(pattern), (m, Some(_)) if m == pattern.len())
    }

    /// Longest suffix of `context` (capped at `max_len`) that occurs in the
    /// stored corpus. Returns `(match_len, text_end_pos)` where
    /// `text_end_pos` is exclusive; `text()[text_end_pos..]` is the stored
    /// continuation after one occurrence of that suffix. Returns match_len 0
    /// when nothing matches.
    ///
    /// Implementation note: we probe progressively shorter suffixes. Each
    /// probe is O(suffix_len) so the total is O(max_len²) worst case, with
    /// max_len a small constant (the configured `match_len`, ≤ 64) — in
    /// practice cheaper than maintaining a matching-statistics automaton.
    pub fn longest_suffix_match(
        &self,
        context: &[TokenId],
        max_len: usize,
    ) -> (usize, Option<usize>) {
        let cap = context.len().min(max_len);
        for take in (1..=cap).rev() {
            let suffix = &context[context.len() - take..];
            if let (m, Some(pos)) = self.walk(suffix) {
                if m == take {
                    return (take, Some(pos));
                }
            }
        }
        (0, None)
    }

    /// Retrieval draft: find the longest context-suffix occurrence and copy
    /// up to `budget` following tokens (stopping at any sentinel).
    pub fn draft(&self, context: &[TokenId], max_match: usize, budget: usize) -> Vec<TokenId> {
        self.draft_with_match(context, max_match, budget).0
    }

    /// `draft` plus the achieved match length, from ONE suffix walk —
    /// callers that need both (the `DraftSource` layer) must not pay the
    /// match twice.
    pub fn draft_with_match(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, usize) {
        let (mlen, pos) = self.longest_suffix_match(context, max_match);
        let Some(mut p) = pos else { return (Vec::new(), 0) };
        if mlen == 0 {
            return (Vec::new(), 0);
        }
        let mut out = Vec::with_capacity(budget);
        while out.len() < budget && p < self.text.len() {
            let t = self.text[p];
            if t >= SENTINEL_BASE {
                break;
            }
            out.push(t);
            p += 1;
        }
        (out, mlen)
    }

    /// All distinct first-tokens that can follow the given pattern in the
    /// corpus (used by tests and by the router's candidate analysis).
    pub fn continuations(&self, pattern: &[TokenId]) -> Vec<TokenId> {
        let (m, pos) = self.walk(pattern);
        if m != pattern.len() {
            return Vec::new();
        }
        let Some(text_pos) = pos else { return Vec::new() };
        // We're either in the middle of an edge (single continuation) or at a
        // node boundary (all children).
        // Re-walk to find the node/edge state.
        let mut node = self.root;
        let mut matched = 0;
        let mut res = Vec::new();
        while matched < pattern.len() {
            let tok = pattern[matched];
            // walk() already matched the full pattern, so the child exists;
            // bail with "no continuations" rather than panic if it doesn't.
            let Some(&child) = self.nodes[node].children.get(&tok) else {
                return Vec::new();
            };
            let el = self.edge_len(child);
            if matched + el <= pattern.len() {
                matched += el;
                node = child;
            } else {
                // Mid-edge: single determined continuation.
                let idx = self.nodes[child].start + (pattern.len() - matched);
                if idx < self.edge_end(child) {
                    let t = self.text[idx];
                    if t < SENTINEL_BASE {
                        res.push(t);
                    }
                }
                return res;
            }
        }
        let _ = text_pos;
        for (&t, _) in &self.nodes[node].children {
            if t < SENTINEL_BASE {
                res.push(t);
            }
        }
        res.sort_unstable();
        res
    }

    /// Approximate heap footprint in bytes (for the Fig. 5 space comparison).
    pub fn approx_bytes(&self) -> usize {
        // Length-based (not capacity) so the gauge is a pure function of
        // content — clones and snapshot-restored trees report identically.
        self.text.len() * std::mem::size_of::<TokenId>()
            + self.nodes.len() * std::mem::size_of::<Node>()
            + self
                .nodes
                .iter()
                .map(|n| n.children.len() * (std::mem::size_of::<(TokenId, usize)>() + 8))
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Naive O(n·m) oracle: does `pattern` occur in `text`?
    fn naive_contains(text: &[u32], pattern: &[u32]) -> bool {
        if pattern.is_empty() {
            return true;
        }
        text.windows(pattern.len()).any(|w| w == pattern)
    }

    #[test]
    fn contains_all_substrings_banana_style() {
        // "banana" analog over tokens.
        let t = [1, 2, 3, 2, 3, 2];
        let tree = SuffixTree::build(&t);
        for i in 0..t.len() {
            for j in i + 1..=t.len() {
                assert!(tree.contains(&t[i..j]), "missing substring {:?}", &t[i..j]);
            }
        }
        assert!(!tree.contains(&[3, 3]));
        assert!(!tree.contains(&[9]));
    }

    #[test]
    fn generalized_tree_spans_multiple_rollouts() {
        let mut tree = SuffixTree::new();
        tree.insert(&[1, 2, 3, 4]);
        tree.insert(&[3, 4, 5, 6]);
        assert!(tree.contains(&[1, 2, 3, 4]));
        assert!(tree.contains(&[3, 4, 5]));
        // No cross-rollout phantom match: 4 followed by 3 never happened
        // inside a single rollout (sentinels separate them).
        assert!(!tree.contains(&[2, 3, 4, 3]));
        assert!(!tree.contains(&[4, 3, 4, 5]));
    }

    #[test]
    fn longest_suffix_match_finds_real_occurrence() {
        let mut tree = SuffixTree::new();
        tree.insert(&[10, 11, 12, 13, 14, 15]);
        let (m, pos) = tree.longest_suffix_match(&[99, 98, 12, 13], 8);
        assert_eq!(m, 2);
        let p = pos.unwrap();
        assert_eq!(&tree.text()[p - 2..p], &[12, 13]);
        // The continuation after [12,13] is [14,15].
        assert_eq!(tree.draft(&[99, 98, 12, 13], 8, 2), vec![14, 15]);
    }

    #[test]
    fn draft_stops_at_sentinel() {
        let mut tree = SuffixTree::new();
        tree.insert(&[1, 2, 3]);
        // Continuation after [2,3] hits the sentinel immediately.
        assert_eq!(tree.draft(&[2, 3], 4, 8), Vec::<u32>::new());
        // After [1,2] we can still read [3] then stop.
        assert_eq!(tree.draft(&[1, 2], 4, 8), vec![3]);
    }

    #[test]
    fn draft_empty_when_no_match() {
        let tree = SuffixTree::build(&[1, 2, 3]);
        assert!(tree.draft(&[7, 8, 9], 4, 8).is_empty());
        assert!(tree.draft(&[], 4, 8).is_empty());
    }

    #[test]
    fn continuations_at_branch() {
        let mut tree = SuffixTree::new();
        tree.insert(&[1, 2, 5]);
        tree.insert(&[1, 2, 7]);
        let cs = tree.continuations(&[1, 2]);
        assert_eq!(cs, vec![5, 7]);
        assert_eq!(tree.continuations(&[1]), vec![2]);
    }

    #[test]
    fn repetitive_text_is_fine() {
        // Worst case for naive structures: one repeated token.
        let t = vec![5u32; 2000];
        let tree = SuffixTree::build(&t);
        assert!(tree.contains(&vec![5u32; 1999]));
        assert!(!tree.contains(&[5, 6]));
    }

    #[test]
    fn prop_tree_matches_naive_oracle() {
        prop::check(192, |g| {
            let alphabet = 1 + g.usize_in(1, 8) as u32;
            let text = g.vec_u32_nonempty(alphabet, 200);
            let tree = SuffixTree::build(&text);
            // Positive cases: all sampled substrings must be found.
            for _ in 0..10 {
                let i = g.rng.below(text.len());
                let j = i + 1 + g.rng.below(text.len() - i);
                prop::require(tree.contains(&text[i..j]), "substring of text must be in tree")?;
            }
            // Random patterns must agree with the oracle.
            for _ in 0..10 {
                let pat = g.vec_u32_nonempty(alphabet, 12);
                prop::require_eq(
                    tree.contains(&pat),
                    naive_contains(&text, &pat),
                    "tree/oracle disagree",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_draft_is_real_continuation() {
        // Any draft must literally appear in some inserted rollout right
        // after an occurrence of the matched context suffix.
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 6) as u32;
            let mut tree = SuffixTree::new();
            let mut rollouts: Vec<Vec<u32>> = Vec::new();
            for _ in 0..g.usize_in(1, 5) {
                let r = g.vec_u32_nonempty(alphabet, 60);
                tree.insert(&r);
                rollouts.push(r);
            }
            let ctx = g.vec_u32_nonempty(alphabet, 20);
            let draft = tree.draft(&ctx, 8, 6);
            if draft.is_empty() {
                return Ok(());
            }
            let (mlen, _) = tree.longest_suffix_match(&ctx, 8);
            let needle: Vec<u32> = ctx[ctx.len() - mlen..]
                .iter()
                .chain(draft.iter())
                .copied()
                .collect();
            let found = rollouts
                .iter()
                .any(|r| r.windows(needle.len()).any(|w| w == needle.as_slice()));
            prop::require(found, "draft must extend a real occurrence in some rollout")
        });
    }

    #[test]
    fn prop_incremental_equals_batch() {
        // extend() token-by-token must answer queries identically to build().
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let text = g.vec_u32_nonempty(alphabet, 120);
            let batch = SuffixTree::build(&text);
            let mut inc = SuffixTree::new();
            for &t in &text {
                inc.extend(t);
            }
            for _ in 0..20 {
                let pat = g.vec_u32_nonempty(alphabet, 10);
                prop::require_eq(
                    inc.contains(&pat),
                    batch.contains(&pat),
                    "incremental vs batch",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn linear_node_growth() {
        // Suffix trees have < 2n nodes; catches quadratic blowups.
        let mut r = Rng::seed_from_u64(42);
        let text: Vec<u32> = (0..5000).map(|_| r.below(16) as u32).collect();
        let tree = SuffixTree::build(&text);
        assert!(
            tree.node_count() <= 2 * (text.len() + 1) + 2,
            "nodes={} n={}",
            tree.node_count(),
            text.len()
        );
    }
}

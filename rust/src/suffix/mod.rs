//! Suffix-structure substrates for the nonparametric drafter (§4.1).
//!
//! * [`tree`] — online Ukkonen suffix tree: the paper's headline structure
//!   (amortized O(1) appends, O(m) queries, retrieval drafting).
//! * [`trie`] — depth-capped *counting* suffix trie: the production drafter
//!   index with per-path occurrence counts for frequency-weighted drafts.
//!   Flat node arena with inline sorted child storage (≤4 children in the
//!   node, sorted-Vec spill above that) — no per-probe hashing.
//! * [`array`] — suffix array + Kasai LCP: the static baseline the paper
//!   compares against in Fig. 5 (updates = full rebuilds).
//! * [`router`] — per-request prefix-trie router (§4.1.2).
//! * [`window`] — sliding-window index with age discounting (Fig. 7): one
//!   fused epoch-tagged trie per shard (per-node count ring,
//!   window-independent draft cost, O(1) whole-epoch eviction plus a
//!   compaction sweep); per-epoch buckets only for the unbounded
//!   `window_all` ablation.

pub mod array;
pub mod router;
pub mod tree;
pub mod trie;
pub mod window;

pub use array::{SuffixArray, SuffixArrayIndex};
pub use router::PrefixRouter;
pub use tree::{SuffixTree, SENTINEL_BASE};
pub use trie::SuffixTrieIndex;
pub use window::{WindowDraft, WindowedIndex};

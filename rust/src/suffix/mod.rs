//! Suffix-structure substrates for the nonparametric drafter (§4.1).
//!
//! * [`self::core`] — THE arena-trie core: one generic, depth-capped trie
//!   (`ArenaTrie<S: CountStore>`) holding the only implementation of
//!   locate / insert / deepest-match / greedy-walk in this crate. Flat node
//!   arena, branchless inline sorted child tables (8 slots before sorted-Vec
//!   spill), and per-node **suffix links** so deepest-suffix matching is one
//!   O(m) forward pass (Aho–Corasick fallback) and sliding-context
//!   insertion is a single left-to-right chain walk. Per-node counts live
//!   in a pluggable `CountStore`:
//!   - `core::Counts` — plain occurrence counts → [`trie::SuffixTrieIndex`];
//!   - `window::EpochStore` (private) — epoch-tagged count slots with a
//!     growable stride → the fused sliding-window index, including the
//!     unbounded `window_all` ablation;
//!   - `router::OwnerStore` (private) — sorted shard-owner tables → the
//!     prefix router.
//! * [`tree`] — online Ukkonen suffix tree: the paper's headline structure
//!   (amortized O(1) appends, O(m) queries, retrieval drafting).
//! * [`trie`] — depth-capped *counting* suffix trie: the production drafter
//!   index with per-path occurrence counts for frequency-weighted drafts.
//! * [`array`] — suffix array + Kasai LCP: the static baseline the paper
//!   compares against in Fig. 5 (updates = full rebuilds).
//! * [`router`] — per-request prefix-trie router (§4.1.2), now with
//!   registration eviction (`unregister`, per-shard capacity bounds).
//! * [`window`] — sliding-window index with age discounting (Fig. 7): one
//!   fused epoch-tagged arena trie per shard for EVERY window size —
//!   bounded windows get O(1) whole-epoch eviction plus a compaction sweep;
//!   `window_all` (window = 0) rides the same trie via a growable
//!   epoch-tag table. The per-epoch bucket ring survives only as the
//!   property-test reference.

pub mod array;
pub mod core;
pub mod router;
pub mod tree;
pub mod trie;
pub mod window;

pub use array::{SuffixArray, SuffixArrayIndex};
pub use self::core::{ArenaTrie, CountStore, Counts};
pub use router::PrefixRouter;
pub use tree::{SuffixTree, SENTINEL_BASE};
pub use trie::SuffixTrieIndex;
pub use window::{WindowDraft, WindowedIndex};

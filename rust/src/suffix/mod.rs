//! Suffix-structure substrates for the nonparametric drafter (§4.1).
//!
//! * [`self::core`] — THE arena-trie core: one generic, depth-capped,
//!   **path-compressed** trie (`ArenaTrie<S: CountStore>`) holding the only
//!   implementation of locate / insert / deepest-match / greedy-walk in
//!   this crate. Flat node arena whose edges carry multi-token labels —
//!   `(segment, start, len)` slices into a hash-consed, refcounted
//!   [`core::SharedPool`] token store shareable across tries — branchless
//!   inline sorted child tables (8 slots before sorted-Vec spill), node
//!   splitting on divergence/termination (so mid-edge positions share the
//!   lower node's counts exactly), and **suffix links over compressed
//!   edges** so deepest-suffix matching is one O(m) forward pass with
//!   skip/count re-descents. All three mutating walks (suffix indexing,
//!   prefix registration, the unregister path) are thin drivers over ONE
//!   internal edge cursor — a single probe/compare/split/leaf step — and
//!   suffix links are refreshed exactly on an insert-count trigger for
//!   tries that never compact (`window_all`, the plain counting trie).
//!   Per-node counts live in a pluggable
//!   `CountStore` (with a `split_node` hook for edge splits):
//!   - `core::Counts` — plain occurrence counts → [`trie::SuffixTrieIndex`];
//!   - `window::EpochStore` (private) — dense epoch rings (bounded
//!     windows) or sparse per-node (epoch, count) lists (`window_all`) →
//!     the fused sliding-window index;
//!   - `router::OwnerStore` (private) — sorted shard-owner tables → the
//!     prefix router.
//! * [`tree`] — online Ukkonen suffix tree: the paper's headline structure
//!   (amortized O(1) appends, O(m) queries, retrieval drafting).
//! * [`trie`] — depth-capped *counting* suffix trie: the production drafter
//!   index with per-path occurrence counts for frequency-weighted drafts.
//! * [`array`] — suffix array + Kasai LCP: the static baseline the paper
//!   compares against in Fig. 5 (updates = full rebuilds).
//! * [`router`] — per-request prefix-trie router (§4.1.2), with
//!   registration eviction (`unregister`, per-shard capacity bounds wired
//!   to `spec.router_capacity`) and pool sharing with the drafter shards.
//! * [`window`] — sliding-window index with age discounting (Fig. 7): one
//!   fused epoch-tagged arena trie per shard for EVERY window size —
//!   bounded windows get O(1) whole-epoch eviction plus a compaction sweep
//!   that also releases dead pool segments; `window_all` (window = 0)
//!   rides the same trie on sparse rows, linear in indexed tokens. The
//!   per-epoch bucket ring survives only as the property-test reference.

pub mod array;
pub mod core;
pub mod router;
pub mod tree;
pub mod trie;
pub mod window;

pub use array::{SuffixArray, SuffixArrayIndex};
pub use self::core::{
    ArenaTrie, CountStore, Counts, PoolSnapshot, PoolStats, SharedPool, SnapshotStats, TriePos,
    TrieSnapshot,
};
pub use router::{PrefixRouter, RouterSnapshot};
pub use tree::{SuffixTree, SENTINEL_BASE};
pub use trie::{SuffixTrieIndex, SuffixTrieSnapshot};
pub use window::{WindowDraft, WindowSnapshot, WindowedIndex};

//! Per-request prefix-trie router (§4.1.2 "Per-request suffix trees").
//!
//! The paper pairs per-problem suffix trees with a lightweight prefix trie
//! that recognizes which *prior generation* the current decode most
//! resembles, and routes the query to that generation's shard. The benefit
//! is workload/model dependent — for small models the CPU overhead can
//! outweigh the gain — so the router is a config toggle
//! (`spec.prefix_router`, exercised by the Fig. 6 scope ablation).

use std::collections::HashMap;

use crate::tokens::TokenId;

#[derive(Debug, Clone, Default)]
struct RNode {
    children: HashMap<TokenId, usize>,
    /// Shards whose indexed generations pass through this node, with visit
    /// counts (a shard here = one prior request/rollout id).
    owners: HashMap<u32, u32>,
}

/// Routes a decode context to the prior-rollout shard whose prefix it
/// matches the deepest.
#[derive(Debug, Clone)]
pub struct PrefixRouter {
    nodes: Vec<RNode>,
    max_depth: usize,
}

impl PrefixRouter {
    pub fn new(max_depth: usize) -> Self {
        PrefixRouter {
            nodes: vec![RNode::default()],
            max_depth: max_depth.max(1),
        }
    }

    /// Register a generation's PREFIX under a shard id.
    pub fn register(&mut self, shard: u32, generation: &[TokenId]) {
        let mut node = 0usize;
        for &tok in generation.iter().take(self.max_depth) {
            let next = match self.nodes[node].children.get(&tok) {
                Some(&n) => n,
                None => {
                    let id = self.nodes.len();
                    self.nodes.push(RNode::default());
                    self.nodes[node].children.insert(tok, id);
                    id
                }
            };
            node = next;
            *self.nodes[node].owners.entry(shard).or_insert(0) += 1;
        }
    }

    /// Route a context: deepest trie node the context's PREFIX reaches, then
    /// the most frequent owner there. Returns (shard, matched_depth).
    pub fn route(&self, context: &[TokenId]) -> Option<(u32, usize)> {
        let mut node = 0usize;
        let mut depth = 0usize;
        let mut last_owned: Option<(usize, usize)> = None; // (node, depth)
        for &tok in context.iter().take(self.max_depth) {
            match self.nodes[node].children.get(&tok) {
                Some(&n) => {
                    node = n;
                    depth += 1;
                    if !self.nodes[node].owners.is_empty() {
                        last_owned = Some((node, depth));
                    }
                }
                None => break,
            }
        }
        let (node, depth) = last_owned?;
        let shard = self.nodes[node]
            .owners
            .iter()
            .max_by_key(|(id, c)| (**c, std::cmp::Reverse(**id)))
            .map(|(&id, _)| id)?;
        Some((shard, depth))
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn routes_to_deepest_match() {
        let mut r = PrefixRouter::new(8);
        r.register(1, &[10, 11, 12, 13]);
        r.register(2, &[10, 11, 20, 21]);
        let (shard, depth) = r.route(&[10, 11, 20, 99]).unwrap();
        assert_eq!(shard, 2);
        assert_eq!(depth, 3);
        let (shard, _) = r.route(&[10, 11, 12]).unwrap();
        assert_eq!(shard, 1);
    }

    #[test]
    fn no_match_is_none() {
        let mut r = PrefixRouter::new(8);
        r.register(1, &[5, 6]);
        assert!(r.route(&[7, 8]).is_none());
        assert!(r.route(&[]).is_none());
    }

    #[test]
    fn frequency_breaks_ambiguity() {
        let mut r = PrefixRouter::new(4);
        r.register(1, &[3, 4]);
        r.register(2, &[3, 4]);
        r.register(2, &[3, 4]);
        let (shard, _) = r.route(&[3, 4, 9]).unwrap();
        assert_eq!(shard, 2);
    }

    #[test]
    fn deterministic_tiebreak_prefers_smaller_shard() {
        let mut r = PrefixRouter::new(4);
        r.register(2, &[3, 4]);
        r.register(1, &[3, 4]);
        let (shard, _) = r.route(&[3, 4]).unwrap();
        assert_eq!(shard, 1);
    }

    #[test]
    fn prop_route_returns_registered_shard() {
        prop::check(96, |g| {
            let mut r = PrefixRouter::new(6);
            let mut shards = Vec::new();
            for s in 0..g.usize_in(1, 5) as u32 {
                let gen = g.vec_u32_nonempty(6, 12);
                r.register(s, &gen);
                shards.push(s);
            }
            let ctx = g.vec_u32_nonempty(6, 12);
            if let Some((shard, depth)) = r.route(&ctx) {
                prop::require(shards.contains(&shard), "routed shard must exist")?;
                prop::require(depth >= 1 && depth <= 6, "depth within bounds")?;
            }
            Ok(())
        });
    }
}

//! Per-request prefix-trie router (§4.1.2 "Per-request suffix trees").
//!
//! The paper pairs per-problem suffix trees with a lightweight prefix trie
//! that recognizes which *prior generation* the current decode most
//! resembles, and routes the query to that generation's shard. The benefit
//! is workload/model dependent — for small models the CPU overhead can
//! outweigh the gain — so the router is a config toggle
//! (`spec.prefix_router`, exercised by the Fig. 6 scope ablation).
//!
//! Since the core refactor the router is the third consumer of the shared
//! [`crate::suffix::core::ArenaTrie`]: the walk machinery (now
//! path-compressed — a registered generation is typically ONE edge until
//! another generation diverges from it) is the core's, and only the
//! per-node payload — a sorted shard-owner table (`OwnerStore`) — is
//! router-specific. Mid-edge positions share the edge's lower owner table
//! (the compressed-counting invariant), and un/registration boundaries are
//! exposed by edge splitting, so routing decisions are bit-identical to the
//! old per-token trie (property-tested below). Registered (depth-capped)
//! prefixes are interned in the router's segment pool — hand the drafter's
//! [`crate::suffix::core::SharedPool`] to
//! [`PrefixRouter::with_capacity_pooled`] so repeated registrations of the
//! same prefix are stored once and the router's bytes appear in the shared
//! pool gauges. (The hash-cons works on whole token runs, so a router
//! prefix only dedups against a shard's *full-rollout* segment when the
//! generation is no longer than the router depth — cross-structure dedup
//! is a bonus, not the design goal.)
//!
//! Registrations can also be *evicted*: `unregister` reverses one
//! registration exactly, and `with_capacity` bounds the registrations kept
//! per shard FIFO-style, so a long-running router's memory no longer grows
//! with every generation ever seen (`spec.router_capacity`).

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::store::wire::{Reader, StoreError, Writer};
use crate::suffix::core::{ArenaTrie, CountStore, SharedPool, TrieSnapshot};
use crate::tokens::TokenId;

/// Per-node shard-owner tables: sorted `(shard, count)` pairs, kept small
/// (a node is owned by the few shards whose generations pass through it).
#[derive(Debug, Clone, Default)]
struct OwnerStore {
    owners: Vec<Vec<(u32, u32)>>,
}

impl OwnerStore {
    /// Remove one registration of `shard` at `node` (inverse of `bump`).
    fn unbump(&mut self, node: usize, shard: u32) {
        let v = &mut self.owners[node];
        if let Ok(i) = v.binary_search_by_key(&shard, |&(s, _)| s) {
            v[i].1 -= 1;
            if v[i].1 == 0 {
                v.remove(i);
            }
        }
    }

    /// Most frequent owner; count ties break toward the smallest shard id.
    fn top_owner(&self, node: usize) -> Option<u32> {
        self.owners[node]
            .iter()
            .max_by_key(|&&(id, c)| (c, std::cmp::Reverse(id)))
            .map(|&(id, _)| id)
    }

    fn owner_count(&self, node: usize) -> usize {
        self.owners[node].len()
    }
}

impl CountStore for OwnerStore {
    type Tag = u32; // shard id
    type Filter = ();

    fn new_empty(&self) -> Self {
        OwnerStore::default()
    }

    fn push_node(&mut self) {
        self.owners.push(Vec::new());
    }

    fn bump(&mut self, node: usize, shard: u32) {
        let v = &mut self.owners[node];
        match v.binary_search_by_key(&shard, |&(s, _)| s) {
            Ok(i) => v[i].1 += 1,
            Err(i) => v.insert(i, (shard, 1)),
        }
    }

    fn weight(&self, node: usize, _filter: ()) -> u64 {
        self.owners[node].iter().map(|&(_, c)| c as u64).sum()
    }

    fn copy_node_from(&mut self, src: &Self, old: usize) {
        self.owners.push(src.owners[old].clone());
    }

    fn split_node(&mut self, child: usize) {
        // Interior positions of an edge share the lower node's owner table;
        // the new upper node materializes exactly that.
        let row = self.owners[child].clone();
        self.owners.push(row);
    }

    fn heap_bytes(&self) -> usize {
        self.owners.len() * std::mem::size_of::<Vec<(u32, u32)>>()
            + self
                .owners
                .iter()
                .map(|v| v.len() * std::mem::size_of::<(u32, u32)>())
                .sum::<usize>()
    }

    fn save_rows(&self, w: &mut Writer) {
        w.str("owner");
        w.usize(self.owners.len());
        for row in &self.owners {
            w.usize(row.len());
            for &(shard, count) in row {
                w.u32(shard);
                w.u32(count);
            }
        }
    }

    fn load_rows(r: &mut Reader<'_>, n_nodes: usize) -> Result<Self, StoreError> {
        r.expect_str("owner", "count-store tag")?;
        let n = r.usize()?;
        if n != n_nodes {
            return Err(StoreError::Corrupt(format!(
                "owner rows ({n}) != arena nodes ({n_nodes})"
            )));
        }
        let mut owners = Vec::with_capacity(n);
        for _ in 0..n {
            let len = r.count(8)?;
            let mut row = Vec::with_capacity(len);
            let mut prev: Option<u32> = None;
            for _ in 0..len {
                let shard = r.u32()?;
                let count = r.u32()?;
                if prev.map(|p| p >= shard).unwrap_or(false) {
                    return Err(StoreError::Corrupt("owner row not sorted by shard".into()));
                }
                if count == 0 {
                    return Err(StoreError::Corrupt("zero-count owner entry".into()));
                }
                prev = Some(shard);
                row.push((shard, count));
            }
            owners.push(row);
        }
        Ok(OwnerStore { owners })
    }
}

/// Routes a decode context to the prior-rollout shard whose prefix it
/// matches the deepest.
#[derive(Debug, Clone)]
pub struct PrefixRouter {
    trie: ArenaTrie<OwnerStore>,
    /// Per-shard FIFO of registered (truncated) prefixes, kept only when a
    /// capacity bound is set so eviction can unregister the oldest.
    recent: HashMap<u32, VecDeque<Vec<TokenId>>>,
    max_gens_per_shard: usize,
    /// Cached published read view; invalidated by register/unregister so
    /// [`PrefixRouter::publish`] re-snapshots once per mutation boundary.
    snap: Option<Arc<RouterSnapshot>>,
}

impl PrefixRouter {
    /// Unbounded router (the historical behavior: registrations are never
    /// forgotten).
    pub fn new(max_depth: usize) -> Self {
        Self::with_capacity(max_depth, usize::MAX)
    }

    /// Router that keeps at most `max_gens_per_shard` registrations per
    /// shard; registering beyond the bound evicts the shard's oldest
    /// registration first (FIFO), bounding memory on long runs.
    pub fn with_capacity(max_depth: usize, max_gens_per_shard: usize) -> Self {
        Self::with_capacity_pooled(max_depth, max_gens_per_shard, SharedPool::new())
    }

    /// [`PrefixRouter::with_capacity`] with the label-segment pool shared
    /// with the caller (the drafter passes its shard pool, so registered
    /// generations reuse the bytes the shards already interned).
    pub fn with_capacity_pooled(
        max_depth: usize,
        max_gens_per_shard: usize,
        pool: SharedPool,
    ) -> Self {
        PrefixRouter {
            trie: ArenaTrie::with_pool(max_depth.max(1), OwnerStore::default(), pool),
            recent: HashMap::new(),
            max_gens_per_shard: max_gens_per_shard.max(1),
            snap: None,
        }
    }

    /// Register a generation's PREFIX under a shard id. An empty
    /// generation registers nothing (and occupies no capacity slot), so
    /// `unregister` on the same input reporting `false` keeps the pair
    /// exactly inverse.
    pub fn register(&mut self, shard: u32, generation: &[TokenId]) {
        if generation.is_empty() {
            return;
        }
        self.snap = None;
        if self.max_gens_per_shard != usize::MAX {
            let prefix: Vec<TokenId> = generation
                .iter()
                .take(self.trie.max_depth())
                .copied()
                .collect();
            let q = self.recent.entry(shard).or_default();
            if q.len() == self.max_gens_per_shard {
                if let Some(oldest) = q.pop_front() {
                    Self::unregister_on(&mut self.trie, shard, &oldest);
                }
            }
            q.push_back(prefix);
        }
        self.trie.insert_prefix(generation, shard);
    }

    /// Reverse one `register(shard, generation)` exactly: decrement the
    /// shard's ownership along the generation's (depth-capped) prefix path,
    /// dropping zeroed entries. Returns false (and changes nothing) if that
    /// prefix was never fully registered — including the empty generation,
    /// which `register` never registers.
    pub fn unregister(&mut self, shard: u32, generation: &[TokenId]) -> bool {
        self.snap = None;
        Self::unregister_on(&mut self.trie, shard, generation)
    }

    /// Publish (or reuse) the immutable lock-free routing view covering
    /// every un/registration so far.
    pub fn publish(&mut self) -> Arc<RouterSnapshot> {
        if let Some(s) = &self.snap {
            return Arc::clone(s);
        }
        let s = Arc::new(RouterSnapshot {
            trie: self.trie.publish(),
        });
        self.snap = Some(Arc::clone(&s));
        s
    }

    /// Associated form so `register`'s capacity eviction can run it while
    /// holding a borrow of the `recent` FIFO. The path walk splits the
    /// final edge if the prefix ends mid-edge, so the un-bumps hit exactly
    /// the explicit-node boundaries the registration's bumps (plus later
    /// splits, which copy owner rows) established.
    fn unregister_on(trie: &mut ArenaTrie<OwnerStore>, shard: u32, generation: &[TokenId]) -> bool {
        let Some(path) = trie.prefix_path_split(generation) else {
            return false;
        };
        for n in path {
            trie.store_mut().unbump(n, shard);
        }
        true
    }

    /// Route a context: deepest position the context's PREFIX reaches with
    /// any owners left, then the most frequent owner there (count ties →
    /// smallest shard id). Returns (shard, matched_depth).
    pub fn route(&self, context: &[TokenId]) -> Option<(u32, usize)> {
        let (node, depth) = self.trie.deepest_visible_prefix(context, ())?;
        let shard = self.trie.store().top_owner(node)?;
        Some((shard, depth))
    }

    /// Distinct shards owning the deepest routed position for this context
    /// (diagnostics for routing ambiguity).
    pub fn owner_count(&self, context: &[TokenId]) -> usize {
        match self.trie.deepest_visible_prefix(context, ()) {
            Some((node, _)) => self.trie.store().owner_count(node),
            None => 0,
        }
    }

    /// Explicit (compressed) trie nodes.
    pub fn node_count(&self) -> usize {
        self.trie.node_count()
    }

    /// Registrations kept per shard (`usize::MAX` = unbounded).
    pub fn capacity(&self) -> usize {
        self.max_gens_per_shard
    }

    /// Serialize the router — capacity bound, owner trie, per-shard FIFO of
    /// registered prefixes — as one `das-store-v1` section (the pool is
    /// saved once by the owner).
    pub fn save_state(&self, w: &mut Writer) {
        w.str("router");
        w.u64(self.max_gens_per_shard as u64);
        w.usize(self.trie.max_depth());
        self.trie.save_state(w);
        w.usize(self.recent.len());
        // Deterministic output: shards in ascending id order.
        let mut shards: Vec<&u32> = self.recent.keys().collect();
        shards.sort_unstable();
        for &shard in shards {
            w.u32(shard);
            let q = &self.recent[&shard];
            w.usize(q.len());
            for prefix in q {
                w.tokens(prefix);
            }
        }
    }

    /// Restore a router from [`PrefixRouter::save_state`] against `pool`
    /// (which must already hold the snapshot's segments).
    pub fn load_state(r: &mut Reader<'_>, pool: SharedPool) -> Result<PrefixRouter, StoreError> {
        r.expect_str("router", "router section tag")?;
        let cap = r.u64()?;
        let max_gens_per_shard = usize::try_from(cap).unwrap_or(usize::MAX).max(1);
        let max_depth = r.usize()?;
        let trie = ArenaTrie::load_state(r, pool)?;
        if trie.max_depth() != max_depth.max(1) {
            return Err(StoreError::Corrupt("router depth disagrees with trie".into()));
        }
        let n_shards = r.count(12)?;
        let mut recent: HashMap<u32, VecDeque<Vec<TokenId>>> = HashMap::with_capacity(n_shards);
        for _ in 0..n_shards {
            let shard = r.u32()?;
            let len = r.count(4)?;
            if len > max_gens_per_shard {
                return Err(StoreError::Corrupt(format!(
                    "shard {shard} FIFO over capacity ({len} > {max_gens_per_shard})"
                )));
            }
            let mut q = VecDeque::with_capacity(len);
            for _ in 0..len {
                q.push_back(r.tokens()?);
            }
            if recent.insert(shard, q).is_some() {
                return Err(StoreError::Corrupt(format!("shard {shard} FIFO duplicated")));
            }
        }
        Ok(PrefixRouter {
            trie,
            recent,
            max_gens_per_shard,
            snap: None,
        })
    }
}

/// Immutable published view of one [`PrefixRouter`]: the owner trie's
/// [`TrieSnapshot`], frozen at the publish. Routing takes `&self` over
/// `Arc`-shared state and acquires no lock — draft-path routing runs on
/// reader threads while the writer registers/unregisters concurrently.
#[derive(Debug, Clone)]
pub struct RouterSnapshot {
    trie: TrieSnapshot<OwnerStore>,
}

impl RouterSnapshot {
    /// See [`PrefixRouter::route`] — same decision, snapshot state.
    pub fn route(&self, context: &[TokenId]) -> Option<(u32, usize)> {
        let (node, depth) = self.trie.deepest_visible_prefix(context, ())?;
        let shard = self.trie.store().top_owner(node)?;
        Some((shard, depth))
    }

    /// See [`PrefixRouter::owner_count`].
    pub fn owner_count(&self, context: &[TokenId]) -> usize {
        match self.trie.deepest_visible_prefix(context, ()) {
            Some((node, _)) => self.trie.store().owner_count(node),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn snapshot_roundtrip_preserves_routing_and_capacity() {
        // das-store-v1 round trip of the router: registration stream with
        // an unregister (forces the OwnerStore rows through real churn),
        // then save → fresh-pool load. Routing decisions, node count and
        // the capacity FIFO must survive, and post-restore registrations
        // (incl. FIFO eviction) must land identically on both routers.
        let mut r = PrefixRouter::with_capacity(8, 2);
        r.register(1, &[10, 11, 12, 13]);
        r.register(2, &[10, 11, 20, 21]);
        r.register(1, &[10, 11, 12, 99]);
        assert!(r.unregister(2, &[10, 11, 20, 21]));
        let mut w = Writer::new();
        let pool = r.trie.pool();
        pool.save_state(&mut w);
        r.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut rd = Reader::new(&bytes);
        let (pool2, recorded) = SharedPool::load_state(&mut rd).unwrap();
        let mut restored = PrefixRouter::load_state(&mut rd, pool2.clone()).unwrap();
        assert!(rd.is_empty(), "round trip consumed every byte");
        assert_eq!(pool2.reconcile_recorded(&recorded), 0, "refcounts re-derive");
        assert_eq!(restored.capacity(), 2);
        assert_eq!(restored.node_count(), r.node_count());
        for ctx in [&[10u32, 11, 12][..], &[10, 11, 20, 21], &[10, 11, 12, 99], &[7]] {
            assert_eq!(restored.route(ctx), r.route(ctx), "route for {ctx:?}");
        }
        // Third registration for shard 1: the restored FIFO must evict the
        // same oldest prefix the live one does.
        r.register(1, &[50, 51]);
        restored.register(1, &[50, 51]);
        assert_eq!(restored.route(&[10, 11, 12, 13]), r.route(&[10, 11, 12, 13]));
        assert_eq!(restored.route(&[50, 51]), r.route(&[50, 51]));
        assert_eq!(restored.node_count(), r.node_count());
    }

    #[test]
    fn published_snapshot_routes_like_live_router_and_freezes() {
        let mut r = PrefixRouter::new(8);
        r.register(1, &[10, 11, 12, 13]);
        r.register(2, &[10, 11, 20, 21]);
        let snap = r.publish();
        for ctx in [&[10u32, 11, 12][..], &[10, 11, 20, 99], &[10, 11], &[7]] {
            assert_eq!(snap.route(ctx), r.route(ctx), "route for {ctx:?}");
            assert_eq!(snap.owner_count(ctx), r.owner_count(ctx), "owners for {ctx:?}");
        }
        let again = r.publish();
        assert!(Arc::ptr_eq(&snap, &again), "no mutation → cached snapshot");
        // The writer mutates; the snapshot keeps its publish-point answers.
        assert!(r.unregister(1, &[10, 11, 12, 13]));
        assert_eq!(snap.route(&[10, 11, 12]), Some((1, 3)), "frozen at publish");
        let fresh = r.publish();
        assert!(!Arc::ptr_eq(&snap, &fresh), "mutation → fresh snapshot");
        assert_eq!(fresh.route(&[10, 11, 12]), Some((2, 2)));
    }

    #[test]
    fn routes_to_deepest_match() {
        let mut r = PrefixRouter::new(8);
        r.register(1, &[10, 11, 12, 13]);
        r.register(2, &[10, 11, 20, 21]);
        let (shard, depth) = r.route(&[10, 11, 20, 99]).unwrap();
        assert_eq!(shard, 2);
        assert_eq!(depth, 3);
        let (shard, _) = r.route(&[10, 11, 12]).unwrap();
        assert_eq!(shard, 1);
    }

    #[test]
    fn no_match_is_none() {
        let mut r = PrefixRouter::new(8);
        r.register(1, &[5, 6]);
        assert!(r.route(&[7, 8]).is_none());
        assert!(r.route(&[]).is_none());
    }

    #[test]
    fn frequency_breaks_ambiguity() {
        let mut r = PrefixRouter::new(4);
        r.register(1, &[3, 4]);
        r.register(2, &[3, 4]);
        r.register(2, &[3, 4]);
        let (shard, _) = r.route(&[3, 4, 9]).unwrap();
        assert_eq!(shard, 2);
    }

    #[test]
    fn deterministic_tiebreak_prefers_smaller_shard() {
        let mut r = PrefixRouter::new(4);
        r.register(2, &[3, 4]);
        r.register(1, &[3, 4]);
        let (shard, _) = r.route(&[3, 4]).unwrap();
        assert_eq!(shard, 1);
    }

    #[test]
    fn routing_depth_is_deepest_owned_prefix() {
        let mut r = PrefixRouter::new(8);
        r.register(7, &[1, 2, 3, 4, 5, 6]);
        // Full-prefix context routes at full depth…
        assert_eq!(r.route(&[1, 2, 3, 4, 5, 6]).unwrap(), (7, 6));
        // …a diverging context at the divergence point (mid-edge: the
        // position shares the edge's owner table)…
        assert_eq!(r.route(&[1, 2, 3, 99]).unwrap(), (7, 3));
        // …and depth never exceeds max_depth.
        let mut r = PrefixRouter::new(3);
        r.register(7, &[1, 2, 3, 4, 5, 6]);
        assert_eq!(r.route(&[1, 2, 3, 4, 5, 6]).unwrap(), (7, 3));
    }

    #[test]
    fn owner_count_reports_ambiguity() {
        let mut r = PrefixRouter::new(4);
        assert_eq!(r.owner_count(&[3, 4]), 0);
        r.register(1, &[3, 4]);
        r.register(2, &[3, 4]);
        assert_eq!(r.owner_count(&[3, 4]), 2);
        r.register(3, &[3, 5]);
        // Deepest position for [3,4] still has exactly shards {1,2}.
        assert_eq!(r.owner_count(&[3, 4]), 2);
    }

    #[test]
    fn unregister_reverses_registration() {
        let mut r = PrefixRouter::new(8);
        r.register(1, &[5, 6, 7]);
        r.register(2, &[5, 6, 8]);
        assert!(r.unregister(1, &[5, 6, 7]));
        // Shard 1's route is gone; shard 2 still reachable.
        assert_eq!(r.route(&[5, 6, 7]).unwrap().0, 2);
        assert_eq!(r.route(&[5, 6, 8]).unwrap(), (2, 3));
        // Unregistering an unknown prefix is a no-op.
        assert!(!r.unregister(2, &[9, 9, 9]));
        assert_eq!(r.route(&[5, 6, 8]).unwrap(), (2, 3));
    }

    #[test]
    fn unregister_shorter_prefix_splits_the_boundary() {
        // Registering a long generation makes ONE edge; unregistering a
        // shorter prefix of it must only strip ownership of the shallow
        // part — the deeper half keeps its registration.
        let mut r = PrefixRouter::new(8);
        r.register(1, &[1, 2, 3, 4]);
        r.register(1, &[1, 2]);
        assert!(r.unregister(1, &[1, 2]));
        // The deep registration still owns the full path…
        assert_eq!(r.route(&[1, 2, 3, 4]).unwrap(), (1, 4));
        // …and the shallow levels still carry the deep registration's
        // ownership (exactly one each), so a second unregister of the deep
        // generation empties the router.
        assert!(r.unregister(1, &[1, 2, 3, 4]));
        assert!(r.route(&[1, 2, 3, 4]).is_none());
    }

    #[test]
    fn register_unregister_inverse_on_empty_and_overdepth_inputs() {
        // Satellite regression: register(&[]) used to be a silent no-op
        // while unregister(&[]) reported success (Some(vec![]) from the
        // core walk) — and, worse, an empty registration occupied a
        // capacity FIFO slot whose eviction could unregister a REAL
        // generation. Both directions must now be exactly inverse.
        let mut r = PrefixRouter::new(4);
        r.register(1, &[]);
        assert_eq!(r.node_count(), 1, "empty registration allocates nothing");
        assert!(!r.unregister(1, &[]), "nothing to reverse for an empty generation");
        // Over-max_depth inputs truncate identically on both sides.
        r.register(2, &[7, 8, 9, 10, 11, 12]);
        assert_eq!(r.route(&[7, 8, 9, 10]).unwrap(), (2, 4));
        assert!(r.unregister(2, &[7, 8, 9, 10, 11, 12]));
        assert!(r.route(&[7, 8, 9, 10]).is_none(), "inverse through truncation");
        // Capacity bookkeeping: an empty registration must not occupy a
        // FIFO slot (it used to evict the newest real registration here).
        let mut r = PrefixRouter::with_capacity(4, 1);
        r.register(1, &[5, 6]);
        r.register(1, &[]);
        assert_eq!(r.route(&[5, 6]).unwrap(), (1, 2), "real registration survives");
    }

    #[test]
    fn capacity_evicts_oldest_registration_fifo() {
        let mut r = PrefixRouter::with_capacity(8, 2);
        r.register(1, &[10, 11]);
        r.register(1, &[20, 21]);
        r.register(1, &[30, 31]); // evicts [10, 11]
        assert!(r.route(&[10, 11]).is_none(), "oldest registration evicted");
        assert_eq!(r.route(&[20, 21]).unwrap(), (1, 2));
        assert_eq!(r.route(&[30, 31]).unwrap(), (1, 2));
        // Other shards are unaffected by shard 1's churn.
        let mut r = PrefixRouter::with_capacity(8, 1);
        r.register(1, &[10, 11]);
        r.register(2, &[10, 12]);
        r.register(1, &[20, 21]); // evicts shard 1's [10, 11] only
        assert_eq!(r.route(&[10, 12]).unwrap(), (2, 2));
        assert_eq!(r.route(&[10, 11]).unwrap(), (2, 1), "routes to the shared [10] node");
    }

    #[test]
    fn pooled_router_shares_label_bytes() {
        let pool = SharedPool::new();
        let mut a = PrefixRouter::with_capacity_pooled(8, usize::MAX, pool.clone());
        let mut b = PrefixRouter::with_capacity_pooled(8, usize::MAX, pool.clone());
        let generation: Vec<u32> = (0..8).collect();
        a.register(1, &generation);
        let after_a = pool.stats().live_tokens;
        b.register(2, &generation);
        assert_eq!(pool.stats().live_tokens, after_a, "same prefix, same segment");
        assert_eq!(a.route(&generation).unwrap().0, 1);
        assert_eq!(b.route(&generation).unwrap().0, 2);
    }

    #[test]
    fn prop_route_returns_registered_shard() {
        prop::check(96, |g| {
            let mut r = PrefixRouter::new(6);
            let mut shards = Vec::new();
            for s in 0..g.usize_in(1, 5) as u32 {
                let gen = g.vec_u32_nonempty(6, 12);
                r.register(s, &gen);
                shards.push(s);
            }
            let ctx = g.vec_u32_nonempty(6, 12);
            if let Some((shard, depth)) = r.route(&ctx) {
                prop::require(shards.contains(&shard), "routed shard must exist")?;
                prop::require(depth >= 1 && depth <= 6, "depth within bounds")?;
            }
            Ok(())
        });
    }

    // -----------------------------------------------------------------
    // Equivalence with the pre-CountStore HashMap implementation: same
    // registrations AND unregistrations ⇒ identical routing decisions
    // (shard AND depth). Unregister streams force edge splits on the
    // compressed side; the per-token reference never needs them.
    // -----------------------------------------------------------------
    #[derive(Default)]
    struct HashNode {
        children: HashMap<TokenId, usize>,
        owners: HashMap<u32, u32>,
    }

    struct HashRouterRef {
        nodes: Vec<HashNode>,
        max_depth: usize,
    }

    impl HashRouterRef {
        fn new(max_depth: usize) -> Self {
            HashRouterRef {
                nodes: vec![HashNode::default()],
                max_depth: max_depth.max(1),
            }
        }

        fn register(&mut self, shard: u32, generation: &[TokenId]) {
            let mut node = 0usize;
            for &tok in generation.iter().take(self.max_depth) {
                let next = match self.nodes[node].children.get(&tok) {
                    Some(&n) => n,
                    None => {
                        let id = self.nodes.len();
                        self.nodes.push(HashNode::default());
                        self.nodes[node].children.insert(tok, id);
                        id
                    }
                };
                node = next;
                *self.nodes[node].owners.entry(shard).or_insert(0) += 1;
            }
        }

        fn unregister(&mut self, shard: u32, generation: &[TokenId]) -> bool {
            let want = generation.len().min(self.max_depth);
            if want == 0 {
                // Mirrors the production router: empty generations are
                // never registered, so there is nothing to reverse.
                return false;
            }
            let mut node = 0usize;
            let mut path = Vec::with_capacity(want);
            for &tok in generation.iter().take(want) {
                match self.nodes[node].children.get(&tok) {
                    Some(&n) => {
                        node = n;
                        path.push(n);
                    }
                    None => return false,
                }
            }
            for n in path {
                if let Some(c) = self.nodes[n].owners.get_mut(&shard) {
                    *c -= 1;
                    if *c == 0 {
                        self.nodes[n].owners.remove(&shard);
                    }
                }
            }
            true
        }

        fn route(&self, context: &[TokenId]) -> Option<(u32, usize)> {
            let mut node = 0usize;
            let mut depth = 0usize;
            let mut last_owned: Option<(usize, usize)> = None;
            for &tok in context.iter().take(self.max_depth) {
                match self.nodes[node].children.get(&tok) {
                    Some(&n) => {
                        node = n;
                        depth += 1;
                        if !self.nodes[node].owners.is_empty() {
                            last_owned = Some((node, depth));
                        }
                    }
                    None => break,
                }
            }
            let (node, depth) = last_owned?;
            let shard = self.nodes[node]
                .owners
                .iter()
                .max_by_key(|(id, c)| (**c, std::cmp::Reverse(**id)))
                .map(|(&id, _)| id)?;
            Some((shard, depth))
        }
    }

    #[test]
    fn prop_matches_hashmap_reference_router() {
        prop::check(96, |g| {
            let depth = 1 + g.usize_in(0, 7);
            let alphabet = 1 + g.usize_in(1, 5) as u32;
            let mut new = PrefixRouter::new(depth);
            let mut old = HashRouterRef::new(depth);
            let mut registered: Vec<(u32, Vec<u32>)> = Vec::new();
            for _ in 0..g.usize_in(1, 16) {
                if !registered.is_empty() && g.usize_in(0, 3) == 0 {
                    // Unregister something that was registered (or a random
                    // never-registered — possibly empty — prefix; both
                    // sides must agree, including that empty generations
                    // always report false).
                    let (shard, gen) = if g.bool() {
                        registered.remove(g.usize_in(0, registered.len() - 1))
                    } else {
                        (g.usize_in(0, 4) as u32, g.vec_u32(alphabet, 10))
                    };
                    prop::require_eq(
                        new.unregister(shard, &gen),
                        old.unregister(shard, &gen),
                        "unregister outcome",
                    )?;
                    if gen.is_empty() {
                        prop::require(!new.unregister(shard, &gen), "empty is never registered")?;
                    }
                } else {
                    // Occasionally an empty generation: a no-op on both
                    // sides (and on the capacity FIFO).
                    let shard = g.usize_in(0, 4) as u32;
                    let gen = if g.usize_in(0, 7) == 0 {
                        Vec::new()
                    } else {
                        g.vec_u32_nonempty(alphabet, 10)
                    };
                    new.register(shard, &gen);
                    old.register(shard, &gen);
                    registered.push((shard, gen));
                }
                for _ in 0..6 {
                    let ctx = g.vec_u32_nonempty(alphabet, 10);
                    prop::require_eq(new.route(&ctx), old.route(&ctx), "routing decision")?;
                }
            }
            Ok(())
        });
    }
}

//! Suffix array baseline (§4.1.2 "Suffix tree and suffix array").
//!
//! Implements the alternative the paper evaluates and rejects for online RL
//! training: an SA built by prefix-doubling (O(n log² n)), a Kasai LCP array,
//! and O(m log n) binary-search pattern lookup. The crucial property for
//! Fig. 5 is that *updates require a full rebuild* — suffix arrays are
//! static — which is exactly what `SuffixArrayIndex::insert` does.

use crate::tokens::TokenId;

use super::tree::SENTINEL_BASE;

/// Plain suffix array over a token slice with LCP support.
#[derive(Debug, Clone)]
pub struct SuffixArray {
    text: Vec<TokenId>,
    /// `sa[i]` = start position of the i-th smallest suffix.
    sa: Vec<usize>,
    /// `lcp[i]` = LCP(text[sa[i]..], text[sa[i-1]..]); lcp[0] = 0.
    lcp: Vec<usize>,
}

impl SuffixArray {
    pub fn build(text: &[TokenId]) -> Self {
        let sa = build_sa(text);
        let lcp = kasai(text, &sa);
        SuffixArray {
            text: text.to_vec(),
            sa,
            lcp,
        }
    }

    pub fn text(&self) -> &[TokenId] {
        &self.text
    }

    pub fn sa(&self) -> &[usize] {
        &self.sa
    }

    pub fn lcp(&self) -> &[usize] {
        &self.lcp
    }

    /// Is `pattern` a substring? O(m log n) via two binary searches.
    pub fn contains(&self, pattern: &[TokenId]) -> bool {
        !pattern.is_empty() && self.range(pattern).is_some() || pattern.is_empty()
    }

    /// Range [lo, hi) of suffixes starting with `pattern`.
    pub fn range(&self, pattern: &[TokenId]) -> Option<(usize, usize)> {
        if pattern.is_empty() || self.text.is_empty() {
            return None;
        }
        let cmp_ge = |suf: &[TokenId]| -> bool {
            // suffix >= pattern (prefix-wise)
            let n = suf.len().min(pattern.len());
            match suf[..n].cmp(&pattern[..n]) {
                std::cmp::Ordering::Less => false,
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Equal => suf.len() >= pattern.len(),
            }
        };
        let cmp_gt = |suf: &[TokenId]| -> bool {
            // suffix > pattern and does NOT start with pattern
            let n = suf.len().min(pattern.len());
            match suf[..n].cmp(&pattern[..n]) {
                std::cmp::Ordering::Less => false,
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Equal => false, // prefix or equal -> not greater
            }
        };
        let lo = partition_point(&self.sa, |&p| !cmp_ge(&self.text[p..]));
        let hi = partition_point(&self.sa, |&p| !cmp_gt(&self.text[p..]));
        if lo < hi {
            Some((lo, hi))
        } else {
            None
        }
    }

    /// Number of occurrences of `pattern`.
    pub fn count(&self, pattern: &[TokenId]) -> usize {
        self.range(pattern).map(|(l, h)| h - l).unwrap_or(0)
    }

    /// Longest suffix of `context` (≤ `max_len`) present in the text, plus
    /// the end position of one occurrence (mirrors `SuffixTree`).
    pub fn longest_suffix_match(
        &self,
        context: &[TokenId],
        max_len: usize,
    ) -> (usize, Option<usize>) {
        let cap = context.len().min(max_len);
        for take in (1..=cap).rev() {
            let suffix = &context[context.len() - take..];
            if let Some((lo, _)) = self.range(suffix) {
                return (take, Some(self.sa[lo] + take));
            }
        }
        (0, None)
    }

    /// Retrieval draft, same semantics as `SuffixTree::draft`.
    pub fn draft(&self, context: &[TokenId], max_match: usize, budget: usize) -> Vec<TokenId> {
        self.draft_with_match(context, max_match, budget).0
    }

    /// `draft` plus the achieved match length, from ONE binary-search pass.
    pub fn draft_with_match(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, usize) {
        let (mlen, pos) = self.longest_suffix_match(context, max_match);
        let Some(mut p) = pos else { return (Vec::new(), 0) };
        if mlen == 0 {
            return (Vec::new(), 0);
        }
        let mut out = Vec::with_capacity(budget);
        while out.len() < budget && p < self.text.len() {
            let t = self.text[p];
            if t >= SENTINEL_BASE {
                break;
            }
            out.push(t);
            p += 1;
        }
        (out, mlen)
    }
}

fn partition_point(sa: &[usize], mut pred: impl FnMut(&usize) -> bool) -> usize {
    // std's partition_point on a slice of indices.
    let mut lo = 0;
    let mut hi = sa.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if pred(&sa[mid]) {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Prefix-doubling suffix array construction, O(n log² n).
fn build_sa(text: &[TokenId]) -> Vec<usize> {
    let n = text.len();
    if n == 0 {
        return Vec::new();
    }
    let mut sa: Vec<usize> = (0..n).collect();
    // Initial ranks = token values (u32 fits in i64 rank space).
    let mut rank: Vec<i64> = text.iter().map(|&t| t as i64).collect();
    let mut tmp = vec![0i64; n];
    let mut k = 1usize;
    loop {
        let key = |i: usize| {
            (
                rank[i],
                if i + k < n { rank[i + k] } else { -1 },
            )
        };
        sa.sort_unstable_by(|&a, &b| key(a).cmp(&key(b)));
        tmp[sa[0]] = 0;
        for w in 1..n {
            tmp[sa[w]] = tmp[sa[w - 1]] + if key(sa[w]) != key(sa[w - 1]) { 1 } else { 0 };
        }
        rank.copy_from_slice(&tmp);
        if rank[sa[n - 1]] as usize == n - 1 {
            break;
        }
        k *= 2;
        if k >= n {
            break;
        }
    }
    sa
}

/// Kasai's linear-time LCP construction.
fn kasai(text: &[TokenId], sa: &[usize]) -> Vec<usize> {
    let n = text.len();
    let mut lcp = vec![0usize; n];
    if n == 0 {
        return lcp;
    }
    let mut rank = vec![0usize; n];
    for (i, &p) in sa.iter().enumerate() {
        rank[p] = i;
    }
    let mut h = 0usize;
    for i in 0..n {
        if rank[i] > 0 {
            let j = sa[rank[i] - 1];
            while i + h < n && j + h < n && text[i + h] == text[j + h] {
                h += 1;
            }
            lcp[rank[i]] = h;
            h = h.saturating_sub(1);
        } else {
            h = 0;
        }
    }
    lcp
}

/// The "suffix array as an online index" strawman from Fig. 5: it stores all
/// rollouts in one corpus and REBUILDS the SA + LCP on every insert. Used by
/// `figures::fig05` and the `suffix_ops` bench to quantify why this loses.
#[derive(Debug, Clone, Default)]
pub struct SuffixArrayIndex {
    corpus: Vec<TokenId>,
    built: Option<SuffixArray>,
    next_sentinel: TokenId,
    pub rebuilds: usize,
}

impl SuffixArrayIndex {
    pub fn new() -> Self {
        SuffixArrayIndex {
            corpus: Vec::new(),
            built: None,
            next_sentinel: SENTINEL_BASE,
            rebuilds: 0,
        }
    }

    /// Insert = append + FULL REBUILD (suffix arrays are static structures).
    pub fn insert(&mut self, tokens: &[TokenId]) {
        self.corpus.extend_from_slice(tokens);
        self.corpus.push(self.next_sentinel);
        self.next_sentinel += 1;
        self.built = Some(SuffixArray::build(&self.corpus));
        self.rebuilds += 1;
    }

    /// The raw sentinel-terminated corpus (the `das-store-v1` persistence
    /// payload for this substrate — SA + LCP are derived data).
    pub fn corpus(&self) -> &[TokenId] {
        &self.corpus
    }

    /// Sentinel id the next insert will consume.
    pub fn sentinel_cursor(&self) -> TokenId {
        self.next_sentinel
    }

    /// Rebuild from a stored corpus: ONE build (not one per historical
    /// insert — the restored index answers identically either way; the
    /// `rebuilds` diagnostic is restored to the saved lifetime count).
    pub fn from_parts(corpus: Vec<TokenId>, next_sentinel: TokenId, rebuilds: usize) -> Self {
        let built = if corpus.is_empty() {
            None
        } else {
            Some(SuffixArray::build(&corpus))
        };
        SuffixArrayIndex {
            corpus,
            built,
            next_sentinel: next_sentinel.max(SENTINEL_BASE),
            rebuilds,
        }
    }

    pub fn len_tokens(&self) -> usize {
        self.corpus.len()
    }

    pub fn draft(&self, context: &[TokenId], max_match: usize, budget: usize) -> Vec<TokenId> {
        match &self.built {
            Some(sa) => sa.draft(context, max_match, budget),
            None => Vec::new(),
        }
    }

    /// `draft` plus the achieved match length in one pass.
    pub fn draft_with_match(
        &self,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> (Vec<TokenId>, usize) {
        match &self.built {
            Some(sa) => sa.draft_with_match(context, max_match, budget),
            None => (Vec::new(), 0),
        }
    }

    /// Longest context-suffix match length against the built index
    /// (mirrors `SuffixTree`/`SuffixTrieIndex` diagnostics).
    pub fn match_len(&self, context: &[TokenId], max_match: usize) -> usize {
        match &self.built {
            Some(sa) => sa.longest_suffix_match(context, max_match).0,
            None => 0,
        }
    }

    pub fn contains(&self, pattern: &[TokenId]) -> bool {
        match &self.built {
            Some(sa) => sa.contains(pattern),
            None => pattern.is_empty(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn sa_of_known_text() {
        // banana analog: 1=a 2=b 3=n -> b a n a n a = [2,1,3,1,3,1]
        let text = [2u32, 1, 3, 1, 3, 1];
        let sa = SuffixArray::build(&text);
        // Sorted suffixes: a(5), ana(3), anana(1), banana(0), na(4), nana(2)
        assert_eq!(sa.sa(), &[5, 3, 1, 0, 4, 2]);
        // LCPs: -,1,3,0,0,2
        assert_eq!(sa.lcp(), &[0, 1, 3, 0, 0, 2]);
    }

    #[test]
    fn contains_and_count() {
        let text = [2u32, 1, 3, 1, 3, 1];
        let sa = SuffixArray::build(&text);
        assert!(sa.contains(&[1, 3, 1]));
        assert!(!sa.contains(&[3, 3]));
        assert_eq!(sa.count(&[1]), 3);
        assert_eq!(sa.count(&[3, 1]), 2);
        assert_eq!(sa.count(&[9]), 0);
    }

    #[test]
    fn index_rebuilds_on_insert() {
        let mut idx = SuffixArrayIndex::new();
        idx.insert(&[1, 2, 3]);
        idx.insert(&[2, 3, 4]);
        assert_eq!(idx.rebuilds, 2);
        assert!(idx.contains(&[2, 3, 4]));
        assert!(!idx.contains(&[3, 2]));
        assert_eq!(idx.draft(&[9, 1, 2], 4, 2), vec![3]);
        assert_eq!(idx.match_len(&[9, 1, 2], 4), 2);
        assert_eq!(idx.match_len(&[9, 9], 4), 0);
        assert_eq!(SuffixArrayIndex::new().match_len(&[1], 4), 0);
    }

    #[test]
    fn prop_sa_is_sorted_permutation() {
        prop::check(128, |g| {
            let alphabet = 1 + g.usize_in(1, 8) as u32;
            let text = g.vec_u32_nonempty(alphabet, 150);
            let sa = SuffixArray::build(&text);
            let mut seen = vec![false; text.len()];
            for &p in sa.sa() {
                prop::require(!seen[p], "sa must be a permutation")?;
                seen[p] = true;
            }
            for w in sa.sa().windows(2) {
                prop::require(text[w[0]..] <= text[w[1]..], "sa must be sorted")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_lcp_matches_naive() {
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 4) as u32;
            let text = g.vec_u32_nonempty(alphabet, 80);
            let sa = SuffixArray::build(&text);
            for i in 1..sa.sa().len() {
                let a = &text[sa.sa()[i - 1]..];
                let b = &text[sa.sa()[i]..];
                let naive = a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count();
                prop::require_eq(sa.lcp()[i], naive, "lcp mismatch")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sa_agrees_with_tree() {
        use crate::suffix::tree::SuffixTree;
        prop::check(96, |g| {
            let alphabet = 1 + g.usize_in(1, 6) as u32;
            let text = g.vec_u32_nonempty(alphabet, 100);
            let sa = SuffixArray::build(&text);
            let tree = SuffixTree::build(&text);
            for _ in 0..15 {
                let pat = g.vec_u32_nonempty(alphabet, 8);
                prop::require_eq(sa.contains(&pat), tree.contains(&pat), "sa vs tree")?;
            }
            Ok(())
        });
    }
}

//! # DAS — Distribution-Aware Speculative Decoding for RL Training
//!
//! A from-scratch reproduction of the DAS system (Shao, Srivatsa et al.,
//! 2025) as a three-layer Rust + JAX + Pallas stack. This crate is Layer 3:
//! the Rust rollout coordinator — continuous batching, the adaptive
//! nonparametric drafter built on online suffix structures, the
//! length-aware speculation policy, lossless draft verification, and a GRPO
//! training loop driving either a real AOT-compiled policy (via PJRT) or a
//! calibrated simulator.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! reproduced figures.

// The crate has zero `unsafe`; freeze that property (`das audit` and the
// gating CI job keep the rest of the invariant surface honest).
#![deny(unsafe_code)]

pub mod analysis;
pub mod config;
pub mod cost;
pub mod model;
pub mod rollout;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod telemetry;
pub mod history;
pub mod workload;
pub mod rl;
pub mod figures;
pub mod drafter;
pub mod draftsvc;
pub mod spec;
pub mod store;
pub mod suffix;
pub mod tokens;
pub mod util;

//! Minimal JSON parser/serializer.
//!
//! The offline registry has no `serde` facade crate, so the config system and
//! the artifact metadata loader (`artifacts/meta.json` written by
//! `python/compile/aot.py`) use this self-contained implementation. It covers
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bool, null) — more than enough for config files and AOT metadata.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None when not an object or key missing).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Dotted-path lookup: `cfg.get_path("model.vocab_size")`.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        // Surrogate pairs: decode \uD800-\uDBFF + \uDC00-\uDFFF.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex in \\u"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: collect the full sequence.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get_path("d"), Some(&Json::Null));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("c".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s\n",true,null],"m":{"x":-1}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\nd\u{41}");
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "f": 1.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
    }
}

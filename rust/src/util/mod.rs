//! Self-contained utility substrates.
//!
//! The build environment resolves crates only from the image's vendored set
//! (the `xla` dependency tree), so the usual ecosystem crates (`rand`,
//! `serde`, `clap`, `criterion`, `proptest`) are written from scratch here in
//! minimal form: [`rng`] (Xoshiro256**), [`json`], [`argparse`], [`stats`],
//! [`bench`] (a criterion-style harness used by `benches/`), and [`prop`]
//! (a property-testing helper used by the test suite).

pub mod argparse;
pub mod bench;
pub mod cow;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

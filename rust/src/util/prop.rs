//! Minimal property-testing harness (`proptest` is not in the offline
//! registry). Runs a property against many seeded random cases and, on
//! failure, retries with progressively simpler inputs (size-based shrinking)
//! before reporting the smallest failing seed/size it saw.
//!
//! Usage:
//! ```ignore
//! prop::check(256, |g| {
//!     let xs = g.vec_u32(0..1000, 0..64);
//!     let t = SuffixTree::build(&xs);
//!     prop::require(t.contains(&xs[..]), "tree must contain its own text")
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to properties; wraps an RNG plus a size hint that
/// the harness lowers while shrinking.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        // Respect the shrink size: cap the span.
        let span = (hi - lo).min(self.size.max(1));
        self.rng.range(lo, lo + span + 1).min(hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector of token ids drawn from `[0, alphabet)`, length in `len_range`.
    pub fn vec_u32(&mut self, alphabet: u32, max_len: usize) -> Vec<u32> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| self.rng.below(alphabet as usize) as u32).collect()
    }

    /// Non-empty variant.
    pub fn vec_u32_nonempty(&mut self, alphabet: u32, max_len: usize) -> Vec<u32> {
        let len = self.usize_in(1, max_len.max(1));
        (0..len).map(|_| self.rng.below(alphabet as usize) as u32).collect()
    }
}

#[derive(Debug)]
pub struct CaseFailure {
    pub message: String,
}

pub type PropResult = Result<(), CaseFailure>;

/// Assertion helper for use inside properties.
pub fn require(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(CaseFailure {
            message: msg.to_string(),
        })
    }
}

pub fn require_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, msg: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(CaseFailure {
            message: format!("{msg}: {a:?} != {b:?}"),
        })
    }
}

/// Run `prop` on `cases` random inputs. Panics (failing the enclosing
/// `#[test]`) with the seed, size and message of the smallest failure found.
pub fn check<F>(cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    check_seeded(0xDA5_0001, cases, &mut prop);
}

pub fn check_seeded<F>(base_seed: u64, cases: u64, prop: &mut F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        // Grow input sizes as cases progress (small cases first — cheap
        // built-in shrinking bias).
        let size = 2 + (case as usize * 64) / cases.max(1) as usize;
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen {
            rng: Rng::seed_from_u64(seed),
            size,
        };
        if let Err(fail) = prop(&mut g) {
            // Shrink: replay with smaller sizes on the same seed and report
            // the smallest size that still fails.
            let mut min_fail = (size, fail.message.clone());
            for s in (1..size).rev() {
                let mut g2 = Gen {
                    rng: Rng::seed_from_u64(seed),
                    size: s,
                };
                if let Err(f2) = prop(&mut g2) {
                    min_fail = (s, f2.message);
                }
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}, minimal size {}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(64, |g| {
            let v = g.vec_u32(100, 32);
            require(v.iter().all(|&t| t < 100), "tokens within alphabet")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_context() {
        check(64, |g| {
            let v = g.vec_u32_nonempty(10, 32);
            require(v.len() < 5, "length always < 5 (false)")
        });
    }

    #[test]
    fn sizes_grow_over_cases() {
        let mut max_len = 0;
        check(64, |g| {
            let v = g.vec_u32(10, 64);
            max_len = max_len.max(v.len());
            Ok(())
        });
        assert!(max_len > 16, "later cases should generate larger inputs");
    }
}

//! Small statistics helpers used across the cost model, figure harness and
//! benchmarks: summary stats, percentiles, least-squares line fits.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for slices shorter than 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: a NaN in the series must not panic the figure harness —
    // it sorts to the end instead (IEEE total order).
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Ordinary least squares fit `y = a + b x`. Returns `(a, b)`.
/// Falls back to a flat line through the mean when x has no variance.
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx == 0.0 {
        return (my, 0.0);
    }
    let sxy: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (x - mx) * (y - my))
        .sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let _ = n;
    (a, b)
}

/// Coefficient of determination R^2 for a fitted line.
pub fn r_squared(xs: &[f64], ys: &[f64], a: f64, b: f64) -> f64 {
    let my = mean(ys);
    let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    if ss_tot == 0.0 {
        return 1.0;
    }
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| {
            let e = y - (a + b * x);
            e * e
        })
        .sum();
    1.0 - ss_res / ss_tot
}

/// Mean relative error of a fitted line — the paper reports MRE ≈ 12% for
/// its `t_fwd = c_base + c_tok·n` model (Fig. 8 / Eq. 1).
pub fn mean_relative_error(xs: &[f64], ys: &[f64], a: f64, b: f64) -> f64 {
    let mut acc = 0.0;
    let mut n = 0usize;
    for (x, y) in xs.iter().zip(ys) {
        if *y != 0.0 {
            acc += ((a + b * x) - y).abs() / y.abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        acc / n as f64
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let sx = stddev(xs);
    let sy = stddev(ys);
    if sx == 0.0 || sy == 0.0 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let cov = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (x - mx) * (y - my))
        .sum::<f64>()
        / xs.len() as f64;
    cov / (sx * sy)
}

/// Exponential moving average over a series (smoothing for figures).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let v = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(v);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // Regression: sort_by(partial_cmp().unwrap()) panicked here. NaN now
        // sorts to the top of the order, so finite percentiles stay sane.
        let xs = [f64::NAN, 2.0, 1.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.0).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
        assert!((r_squared(&xs, &ys, a, b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_degenerate_x() {
        let (a, b) = linreg(&[2.0, 2.0], &[1.0, 3.0]);
        assert_eq!(b, 0.0);
        assert!((a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mre_zero_for_exact_fit() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!(mean_relative_error(&xs, &ys, 0.0, 2.0) < 1e-12);
    }

    #[test]
    fn pearson_sign() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let up = [1.0, 2.0, 3.0, 4.5];
        let down = [4.0, 3.0, 2.0, 1.0];
        assert!(pearson(&xs, &up) > 0.95);
        assert!(pearson(&xs, &down) < -0.95);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 10.0], 0.5);
        assert_eq!(out, vec![0.0, 5.0]);
    }
}

//! Tiny declarative CLI argument parser (no `clap` in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands; generates usage text from the declared options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub args: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            args: Vec::new(),
        }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    pub fn flag_opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for a in &self.args {
            let val = if a.takes_value { " <value>" } else { "" };
            let dfl = a
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{val}\t{}{dfl}\n", a.name, a.help));
        }
        s
    }

    /// Parse raw argv (already past the subcommand). Unknown `--opts` error.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        // Seed defaults.
        for spec in &self.args {
            if let Some(d) = spec.default {
                out.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    out.values.insert(key.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{key} does not take a value"));
                    }
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "a test command")
            .opt("steps", "number of steps", Some("10"))
            .opt("out", "output path", None)
            .flag_opt("verbose", "chatty mode")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_values() {
        let a = cmd().parse(&s(&["--out", "x.csv"])).unwrap();
        assert_eq!(a.get_usize("steps"), Some(10));
        assert_eq!(a.get("out"), Some("x.csv"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cmd().parse(&s(&["--steps=25", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get_usize("steps"), Some(25));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&s(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&s(&["--out"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cmd().parse(&s(&["--verbose=1"])).is_err());
    }

    #[test]
    fn usage_lists_options() {
        let u = cmd().usage();
        assert!(u.contains("--steps"));
        assert!(u.contains("default: 10"));
    }
}

//! Criterion-style micro-benchmark harness.
//!
//! `criterion` is not in the offline registry, so `benches/*.rs` use this
//! harness (`[[bench]] harness = false` in Cargo.toml). It does what we need
//! from criterion: warmup, adaptive iteration counts targeting a fixed
//! measurement window, and median/mean/p99 reporting with throughput.
//!
//! Results can be persisted as machine-readable JSON for the repo's perf
//! trajectory (`BENCH_*.json` at the repo root): pass `--json <path>` to the
//! bench binary (`cargo bench --bench suffix_ops -- --json BENCH_suffix.json`)
//! or set the `BENCH_JSON` env var. See [`Bencher::finish`].

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;

pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    results: Vec<BenchResult>,
    /// Named scalar measurements (node counts, byte footprints, ratios)
    /// recorded alongside the timings and persisted into the JSON under
    /// `"gauges"`. `bench_compare.py` only diffs `"results"`, so gauges
    /// never trip the regression gate — they make memory wins observable.
    gauges: Vec<(String, f64)>,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<u64>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            results: Vec::new(),
            gauges: Vec::new(),
        }
    }
}

/// Prevent the optimizer from deleting a computed value (stable-Rust
/// equivalent of `std::hint::black_box` — which we also call through to).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick mode for CI: tiny windows, still statistically usable.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_iters: 5,
            results: Vec::new(),
            gauges: Vec::new(),
        }
    }

    /// Record a named scalar gauge (memory footprint, node count, ratio).
    pub fn gauge(&mut self, name: &str, value: f64) {
        println!("{:<44} gauge  {:>14.2}", name, value);
        self.gauges.push((name.to_string(), value));
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_elems(name, None, &mut f)
    }

    /// Benchmark with a throughput denominator (elements per iteration).
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        elems: u64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_elems(name, Some(elems), &mut f)
    }

    fn bench_elems(&mut self, name: &str, elems: Option<u64>, f: &mut dyn FnMut()) -> &BenchResult {
        // Warmup + estimate per-iter cost.
        let wstart = Instant::now();
        let mut witers = 0u64;
        while wstart.elapsed() < self.warmup || witers < 3 {
            f();
            witers += 1;
            if witers > 1_000_000 {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_nanos() as f64 / witers as f64;
        // Batch so each sample is >= ~50us to avoid timer noise.
        let batch = ((50_000.0 / per_iter.max(1.0)).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let mstart = Instant::now();
        let mut total_iters = 0u64;
        while mstart.elapsed() < self.measure || samples.len() < self.min_iters as usize {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples.len() > 100_000 {
                break;
            }
        }
        sort_samples(&mut samples);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let median = samples[samples.len() / 2];
        let p99 = samples[((samples.len() as f64 * 0.99) as usize).min(samples.len() - 1)];
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            median_ns: median,
            p99_ns: p99,
            elems,
        };
        println!("{}", format_result(&res));
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn summary(&self) {
        println!("\n== bench summary ==");
        for r in &self.results {
            println!("{}", format_result(r));
        }
        for (name, value) in &self.gauges {
            println!("{:<44} gauge  {:>14.2}", name, value);
        }
    }

    /// Serialize all results as JSON (schema `das-bench-v1`).
    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(&r.name)),
                    ("iters", Json::num(r.iters as f64)),
                    ("mean_ns", Json::num(r.mean_ns)),
                    ("median_ns", Json::num(r.median_ns)),
                    ("p99_ns", Json::num(r.p99_ns)),
                    (
                        "elems",
                        r.elems.map(|e| Json::num(e as f64)).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        let gauges: Vec<Json> = self
            .gauges
            .iter()
            .map(|(name, value)| {
                Json::obj(vec![("name", Json::str(name)), ("value", Json::num(*value))])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str("das-bench-v1")),
            ("results", Json::Arr(results)),
            ("gauges", Json::Arr(gauges)),
        ])
    }

    /// Write results to `path` as JSON.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Print the summary and, when a JSON sink was requested via
    /// `--json <path>` (or the `BENCH_JSON` env fallback), persist the
    /// results there. `default_name` is used for a bare `--json` /
    /// `BENCH_JSON=1`, or when `BENCH_JSON` names a directory.
    pub fn finish(&self, default_name: &str) {
        self.summary();
        if let Some(path) = json_sink(default_name) {
            match self.write_json(&path) {
                Ok(()) => println!("bench json written to {}", path.display()),
                Err(e) => eprintln!("failed to write {}: {e}", path.display()),
            }
        }
    }
}

/// Resolve the requested JSON output path for a bench run: the `--json
/// <path>` CLI flag wins, the `BENCH_JSON` env var is the fallback, `None`
/// means no JSON was requested.
pub fn json_sink(default_name: &str) -> Option<PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    for (i, a) in args.iter().enumerate() {
        if a == "--json" {
            return Some(match args.get(i + 1) {
                Some(p) if !p.starts_with("--") => PathBuf::from(p),
                _ => PathBuf::from(default_name),
            });
        }
    }
    match std::env::var("BENCH_JSON") {
        Ok(v) if v.is_empty() || v == "1" => Some(PathBuf::from(default_name)),
        Ok(v) => {
            let p = PathBuf::from(v);
            if p.is_dir() {
                Some(p.join(default_name))
            } else {
                Some(p)
            }
        }
        Err(_) => None,
    }
}

/// NaN-safe ascending sort for timing samples: `total_cmp` imposes the IEEE
/// total order, so a non-finite sample lands at an end of the slice instead
/// of panicking the harness mid-benchmark.
fn sort_samples(samples: &mut [f64]) {
    samples.sort_by(|a, b| a.total_cmp(b));
}

pub fn format_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

fn format_result(r: &BenchResult) -> String {
    let mut s = format!(
        "{:<44} median {:>10}  mean {:>10}  p99 {:>10}",
        r.name,
        format_ns(r.median_ns),
        format_ns(r.mean_ns),
        format_ns(r.p99_ns),
    );
    if let Some(e) = r.elems {
        let per_sec = e as f64 / (r.median_ns * 1e-9);
        s.push_str(&format!("  ({:.2} Melem/s)", per_sec / 1e6));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 3,
            results: Vec::new(),
            gauges: Vec::new(),
        };
        let mut acc = 0u64;
        let r = b
            .bench("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p99_ns * 1.001);
        assert!(r.iters > 0);
    }

    #[test]
    fn json_roundtrips_results() {
        let mut b = Bencher {
            warmup: Duration::from_millis(2),
            measure: Duration::from_millis(8),
            min_iters: 3,
            results: Vec::new(),
            gauges: Vec::new(),
        };
        let mut acc = 0u64;
        b.bench_throughput("t", 128, || {
            acc = black_box(acc.wrapping_add(3));
        });
        b.gauge("trie_nodes", 1234.0);
        let j = b.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("das-bench-v1"));
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("t"));
        assert_eq!(results[0].get("elems").unwrap().as_f64(), Some(128.0));
        assert!(results[0].get("median_ns").unwrap().as_f64().unwrap() > 0.0);
        let gauges = j.get("gauges").unwrap().as_arr().unwrap();
        assert_eq!(gauges.len(), 1);
        assert_eq!(gauges[0].get("name").unwrap().as_str(), Some("trie_nodes"));
        assert_eq!(gauges[0].get("value").unwrap().as_f64(), Some(1234.0));
        // Serialized text parses back.
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn sample_sort_tolerates_non_finite_values() {
        // Regression: sort_by(partial_cmp().unwrap()) panicked on NaN.
        let mut s = [f64::NAN, 1.0, f64::NEG_INFINITY, 0.5];
        sort_samples(&mut s);
        assert_eq!(s[0], f64::NEG_INFINITY);
        assert_eq!(s[1], 0.5);
        assert_eq!(s[2], 1.0);
        assert!(s[3].is_nan());
    }

    #[test]
    fn format_ns_units() {
        assert!(format_ns(500.0).contains("ns"));
        assert!(format_ns(5_000.0).contains("µs"));
        assert!(format_ns(5_000_000.0).contains("ms"));
        assert!(format_ns(5_000_000_000.0).ends_with("s"));
    }
}

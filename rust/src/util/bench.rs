//! Criterion-style micro-benchmark harness.
//!
//! `criterion` is not in the offline registry, so `benches/*.rs` use this
//! harness (`[[bench]] harness = false` in Cargo.toml). It does what we need
//! from criterion: warmup, adaptive iteration counts targeting a fixed
//! measurement window, and median/mean/p99 reporting with throughput.

use std::time::{Duration, Instant};

pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    results: Vec<BenchResult>,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<u64>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from deleting a computed value (stable-Rust
/// equivalent of `std::hint::black_box` — which we also call through to).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick mode for CI: tiny windows, still statistically usable.
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_iters: 5,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_elems(name, None, &mut f)
    }

    /// Benchmark with a throughput denominator (elements per iteration).
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        elems: u64,
        mut f: F,
    ) -> &BenchResult {
        self.bench_elems(name, Some(elems), &mut f)
    }

    fn bench_elems(&mut self, name: &str, elems: Option<u64>, f: &mut dyn FnMut()) -> &BenchResult {
        // Warmup + estimate per-iter cost.
        let wstart = Instant::now();
        let mut witers = 0u64;
        while wstart.elapsed() < self.warmup || witers < 3 {
            f();
            witers += 1;
            if witers > 1_000_000 {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_nanos() as f64 / witers as f64;
        // Batch so each sample is >= ~50us to avoid timer noise.
        let batch = ((50_000.0 / per_iter.max(1.0)).ceil() as u64).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let mstart = Instant::now();
        let mut total_iters = 0u64;
        while mstart.elapsed() < self.measure || samples.len() < self.min_iters as usize {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if samples.len() > 100_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let median = samples[samples.len() / 2];
        let p99 = samples[((samples.len() as f64 * 0.99) as usize).min(samples.len() - 1)];
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            median_ns: median,
            p99_ns: p99,
            elems,
        };
        println!("{}", format_result(&res));
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn summary(&self) {
        println!("\n== bench summary ==");
        for r in &self.results {
            println!("{}", format_result(r));
        }
    }
}

pub fn format_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

fn format_result(r: &BenchResult) -> String {
    let mut s = format!(
        "{:<44} median {:>10}  mean {:>10}  p99 {:>10}",
        r.name,
        format_ns(r.median_ns),
        format_ns(r.mean_ns),
        format_ns(r.p99_ns),
    );
    if let Some(e) = r.elems {
        let per_sec = e as f64 / (r.median_ns * 1e-9);
        s.push_str(&format!("  ({:.2} Melem/s)", per_sec / 1e6));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 3,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let r = b
            .bench("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(r.mean_ns > 0.0);
        assert!(r.median_ns <= r.p99_ns * 1.001);
        assert!(r.iters > 0);
    }

    #[test]
    fn format_ns_units() {
        assert!(format_ns(500.0).contains("ns"));
        assert!(format_ns(5_000.0).contains("µs"));
        assert!(format_ns(5_000_000.0).contains("ms"));
        assert!(format_ns(5_000_000_000.0).ends_with("s"));
    }
}

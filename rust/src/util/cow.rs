//! Copy-on-write building blocks for the snapshot-published drafter.
//!
//! [`CowVec`] is a chunked vector whose clone is O(len / CHUNK) pointer
//! copies: chunks are `Arc<Vec<T>>`, so a published snapshot shares every
//! chunk with the writer until the writer next mutates into one
//! (`Arc::make_mut` then copies that single chunk). This bounds
//! copy-on-publish work to the chunks actually touched since the last
//! publish — the property the arena snapshots rely on.
//!
//! [`SnapshotCell`] is the writer→reader handoff: the writer `store`s a
//! fresh `Arc<T>` under a tiny mutex (held only for the pointer swap, never
//! for reads of `T` itself) and bumps a generation counter; readers `load`
//! an `Arc<T>` clone and then walk the snapshot with zero further
//! synchronization. Draft walks themselves take `&T` — the type system
//! keeps locks off the read path entirely.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Chunk size in elements. Small enough that a writer touching a handful of
/// nodes between publishes copies a handful of chunks; large enough that the
/// chunk table stays tiny relative to the payload.
const CHUNK: usize = 256;

/// A chunked vector with O(len / CHUNK) clone and per-chunk copy-on-write.
///
/// Indexing is `chunks[i / CHUNK][i % CHUNK]`; `index_mut` goes through
/// `Arc::make_mut`, so a chunk shared with a published snapshot is copied
/// exactly once per publish cycle and an unshared chunk mutates in place
/// (the steady state between publishes).
#[derive(Debug)]
pub struct CowVec<T> {
    chunks: Vec<Arc<Vec<T>>>,
    len: usize,
}

impl<T> Default for CowVec<T> {
    fn default() -> Self {
        CowVec { chunks: Vec::new(), len: 0 }
    }
}

impl<T> Clone for CowVec<T> {
    fn clone(&self) -> Self {
        CowVec { chunks: self.chunks.clone(), len: self.len }
    }
}

impl<T: Clone> CowVec<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn push(&mut self, value: T) {
        if self.len % CHUNK == 0 {
            self.chunks.push(Arc::new(Vec::with_capacity(CHUNK)));
        }
        let last = self.chunks.last_mut().expect("chunk pushed above");
        Arc::make_mut(last).push(value);
        self.len += 1;
    }

    pub fn get(&self, i: usize) -> Option<&T> {
        if i < self.len {
            Some(&self.chunks[i / CHUNK][i % CHUNK])
        } else {
            None
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.chunks.iter().flat_map(|c| c.iter())
    }

    /// Length-based heap accounting (pure function of content, so a
    /// save/load round trip reports identical sizes).
    pub fn heap_bytes(&self) -> usize {
        self.len * std::mem::size_of::<T>()
    }
}

impl<T: Clone> std::ops::Index<usize> for CowVec<T> {
    type Output = T;

    fn index(&self, i: usize) -> &T {
        assert!(i < self.len, "CowVec index {i} out of bounds (len {})", self.len);
        &self.chunks[i / CHUNK][i % CHUNK]
    }
}

impl<T: Clone> std::ops::IndexMut<usize> for CowVec<T> {
    fn index_mut(&mut self, i: usize) -> &mut T {
        assert!(i < self.len, "CowVec index {i} out of bounds (len {})", self.len);
        &mut Arc::make_mut(&mut self.chunks[i / CHUNK])[i % CHUNK]
    }
}

impl<T: Clone> FromIterator<T> for CowVec<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        let mut v = CowVec::new();
        for x in iter {
            v.push(x);
        }
        v
    }
}

/// Writer→reader snapshot handoff: one `Arc<T>` slot plus a generation
/// counter. `store` is writer-only; `load` hands readers a shared pointer
/// they walk without further synchronization. The mutex guards only the
/// pointer swap (nanoseconds), never a draft walk — the snapshot types'
/// read APIs take `&T`, so holding any lock during a read is unrepresentable.
#[derive(Debug)]
pub struct SnapshotCell<T> {
    slot: Mutex<Arc<T>>,
    generation: AtomicU64,
}

impl<T> SnapshotCell<T> {
    pub fn new(initial: Arc<T>) -> Self {
        SnapshotCell { slot: Mutex::new(initial), generation: AtomicU64::new(0) }
    }

    /// Publish a new snapshot; returns the new generation number.
    pub fn store(&self, value: Arc<T>) -> u64 {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        *slot = value;
        // Bump inside the critical section so (generation, pointer) pairs
        // observed by `load_with_gen` are consistent.
        self.generation.fetch_add(1, Ordering::Release) + 1
    }

    /// Current snapshot (an `Arc` clone; the reader owns it from here on).
    pub fn load(&self) -> Arc<T> {
        self.slot.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Current snapshot plus the generation it was published at.
    pub fn load_with_gen(&self) -> (Arc<T>, u64) {
        let slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        // Acquire pairs with the Release bump in `store`: the generation
        // read here cannot be newer than the pointer read under the lock.
        (slot.clone(), self.generation.load(Ordering::Acquire))
    }

    pub fn generation(&self) -> u64 {
        // Acquire pairs with the Release bump in `store` (monotone gauge).
        self.generation.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn push_index_iter_roundtrip() {
        let mut v: CowVec<u32> = CowVec::new();
        for i in 0..1000u32 {
            v.push(i * 3);
        }
        assert_eq!(v.len(), 1000);
        for i in 0..1000 {
            assert_eq!(v[i], i as u32 * 3);
        }
        let collected: Vec<u32> = v.iter().copied().collect();
        assert_eq!(collected.len(), 1000);
        assert_eq!(collected[999], 999 * 3);
        assert_eq!(v.get(1000), None);
        assert_eq!(v.get(999), Some(&(999 * 3)));
    }

    #[test]
    fn clone_shares_chunks_until_written() {
        let mut v: CowVec<u64> = (0..600u64).collect();
        let snap = v.clone();
        // Mutating one element must not be visible through the snapshot...
        v[5] = 9999;
        assert_eq!(snap[5], 5);
        assert_eq!(v[5], 9999);
        // ...and only the touched chunk was copied: the other chunks are
        // still literally shared pointers.
        let shared = v
            .chunks
            .iter()
            .zip(snap.chunks.iter())
            .filter(|(a, b)| Arc::ptr_eq(a, b))
            .count();
        assert_eq!(shared, v.chunks.len() - 1, "exactly one chunk copied");
        // Growth after a publish never disturbs the snapshot.
        for i in 0..300 {
            v.push(i);
        }
        assert_eq!(snap.len(), 600);
        assert_eq!(v.len(), 900);
    }

    #[test]
    fn writes_without_snapshot_mutate_in_place() {
        let mut v: CowVec<u32> = (0..300u32).collect();
        let before: Vec<*const Vec<u32>> = v.chunks.iter().map(|c| Arc::as_ptr(c)).collect();
        for i in 0..300 {
            v[i] = 1;
        }
        let after: Vec<*const Vec<u32>> = v.chunks.iter().map(|c| Arc::as_ptr(c)).collect();
        assert_eq!(before, after, "unshared chunks must not reallocate on write");
    }

    #[test]
    fn snapshot_cell_store_load_generations() {
        let cell = SnapshotCell::new(Arc::new(0u32));
        assert_eq!(cell.generation(), 0);
        assert_eq!(*cell.load(), 0);
        let g1 = cell.store(Arc::new(7));
        assert_eq!(g1, 1);
        let (v, g) = cell.load_with_gen();
        assert_eq!((*v, g), (7, 1));
        cell.store(Arc::new(8));
        assert_eq!(*cell.load(), 8);
        assert_eq!(cell.generation(), 2);
    }

    #[test]
    fn poisoned_slot_still_serves_store_and_load() {
        // Regression: a panic while the publish lock is held poisons the
        // mutex; store/load recover via into_inner instead of cascading.
        let cell = SnapshotCell::new(Arc::new(1u32));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = cell.slot.lock().unwrap_or_else(|e| e.into_inner());
            panic!("injected panic while holding the publish lock");
        }));
        assert!(result.is_err(), "the injected panic must propagate");
        assert!(cell.slot.is_poisoned(), "the mutex must actually be poisoned");
        assert_eq!(*cell.load(), 1);
        assert_eq!(cell.store(Arc::new(2)), 1);
        let (v, g) = cell.load_with_gen();
        assert_eq!((*v, g), (2, 1));
    }

    /// Seeded interleaving test for the publish/swap path (the satellite's
    /// loom stand-in — loom is not in the offline registry). A writer
    /// publishes generation-stamped values in order while readers
    /// concurrently load; every observation must be self-consistent
    /// (value == generation it was published under) and generations must be
    /// monotone per reader. The seed varies the writer's publish cadence so
    /// repeated runs explore different interleavings deterministically.
    #[test]
    fn seeded_interleaving_readers_never_observe_torn_publishes() {
        for seed in 0..8u64 {
            let mut rng = Rng::seed_from_u64(0xC0F3 ^ seed);
            let cadence: Vec<u32> = (0..64).map(|_| rng.below(50) as u32).collect();
            let cell = Arc::new(SnapshotCell::new(Arc::new(0u64)));
            std::thread::scope(|s| {
                for _ in 0..4 {
                    let cell = Arc::clone(&cell);
                    s.spawn(move || {
                        let mut last_gen = 0u64;
                        for _ in 0..2000 {
                            let (v, g) = cell.load_with_gen();
                            // Published value i goes out at generation i:
                            // a torn pair would break this equality.
                            assert_eq!(*v, g, "value must match its generation");
                            assert!(g >= last_gen, "generations are monotone");
                            last_gen = g;
                        }
                    });
                }
                let cell = Arc::clone(&cell);
                s.spawn(move || {
                    for (i, &spin) in cadence.iter().enumerate() {
                        for _ in 0..spin {
                            std::hint::spin_loop();
                        }
                        let g = cell.store(Arc::new((i + 1) as u64));
                        assert_eq!(g, (i + 1) as u64);
                    }
                });
            });
            assert_eq!(cell.generation(), 64);
        }
    }
}

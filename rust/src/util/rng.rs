//! Deterministic pseudo-random number generation.
//!
//! The registry available to this build has no `rand` crate, so we carry a
//! small, well-known generator of our own: SplitMix64 for seeding and
//! Xoshiro256** for the stream. Determinism matters here beyond
//! reproducibility: the lossless-speculation property tests require that the
//! baseline and the speculative decoder consume *identical* random streams
//! per request (see `spec::verify`).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG. Small, fast, and good enough for sampling workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Raw generator state, for checkpoint serialization. Restoring via
    /// [`Rng::from_state`] continues the exact stream — required for
    /// bit-identical resume of a migrated request.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from previously captured [`Rng::state`] words.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent stream for a sub-task (e.g. one per request).
    /// Mixes the label into fresh state so streams don't overlap in practice.
    pub fn fork(&mut self, label: u64) -> Rng {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::seed_from_u64(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free mapping is fine at our scales.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Returns `None` if all weights are zero/empty.
    pub fn categorical(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// Sample from a (normalized or unnormalized) f32 probability slice.
    pub fn categorical_f32(&mut self, probs: &[f32]) -> Option<usize> {
        let total: f64 = probs.iter().map(|&p| p as f64).sum();
        if !(total > 0.0) {
            return None;
        }
        let mut u = self.next_f64() * total;
        for (i, &p) in probs.iter().enumerate() {
            u -= p as f64;
            if u < 0.0 {
                return Some(i);
            }
        }
        Some(probs.len() - 1)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample with the given log-space mean and std.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.below(xs.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::seed_from_u64(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seed_from_u64(3);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn categorical_all_zero_is_none() {
        let mut r = Rng::seed_from_u64(3);
        assert!(r.categorical(&[0.0, 0.0]).is_none());
        assert!(r.categorical(&[]).is_none());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trip_continues_stream() {
        let mut a = Rng::seed_from_u64(2024);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::seed_from_u64(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}

//! Comment/string-aware lexical scanner behind `das audit`.
//!
//! The audit rules are lexical, so their precision rests entirely on this
//! module: a violation token inside a string literal, a raw string, a char
//! literal, or any flavor of comment must be invisible to the rules, and a
//! token inside `#[cfg(test)]` / `mod tests` regions must be attributable
//! as test code. The scanner therefore produces, per source line:
//!
//! - `code`: the line with every comment and literal *content* blanked to
//!   spaces (delimiters kept), so rules can do plain substring matching
//!   without literal false positives;
//! - `comment`: the concatenated comment text of the line (pragmas like
//!   `// audit: allow(panic-path) -- reason` live here);
//! - `has_comment`: whether any part of the line is commented (the
//!   `atomic-ordering` rule accepts a justification on the same line or the
//!   line directly above);
//! - `in_test`: whether the line sits inside a test region, tracked by
//!   brace depth from the `#[cfg(test)]` attribute or `mod tests` item that
//!   opened it.
//!
//! Handled literal forms: `"…"` with escapes, byte strings `b"…"`, raw
//! strings `r"…"` / `r#"…"#` (any hash count, `br#"…"#` too), char and byte
//! char literals (`'a'`, `'\n'`, `b'x'`) disambiguated from lifetimes
//! (`'static`), line comments (incl. `///` and `//!` doc forms) and nested
//! block comments.

/// One scanned source line (see module docs for field semantics).
#[derive(Debug, Default)]
pub struct LineInfo {
    pub code: String,
    pub comment: String,
    pub has_comment: bool,
    pub in_test: bool,
}

/// A whole scanned file: lines are 0-indexed here, findings report 1-based.
#[derive(Debug, Default)]
pub struct LexedFile {
    pub lines: Vec<LineInfo>,
}

/// A suppression pragma found in a comment: `audit:` followed by the rule
/// in `allow(…)` and a `-- <reason>` tail (the module docs show the full
/// form). A pragma suppresses findings of `rule` on its own line and on
/// the line directly below — and is itself a violation when `reason_ok` is
/// false (no `--` reason, or an empty one).
#[derive(Debug)]
pub struct Pragma {
    /// 0-based line the pragma's comment sits on.
    pub line: usize,
    pub rule: String,
    pub reason_ok: bool,
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

/// Match a string-literal opener at `i`: optional `b`, optional `r` +
/// hashes, then `"`. Returns (prefix length including the quote, raw hash
/// count — `None` for an escaping string).
fn string_opener(chars: &[char], i: usize) -> Option<(usize, Option<u32>)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) == Some(&'r') {
        j += 1;
        let mut hashes = 0u32;
        while chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if chars.get(j) == Some(&'"') {
            return Some((j + 1 - i, Some(hashes)));
        }
        return None;
    }
    if chars.get(j) == Some(&'"') {
        return Some((j + 1 - i, None));
    }
    None
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan `source` into per-line code/comment views (see module docs).
pub fn lex(source: &str) -> LexedFile {
    let chars: Vec<char> = source.chars().collect();
    let mut lines: Vec<LineInfo> = Vec::new();
    let mut line = LineInfo::default();
    let mut state = State::Code;
    // The char last appended to `code` — a raw-string prefix (`r`/`b`) is
    // only an opener when it does not continue an identifier (`for`,
    // `attr` end in valid prefix letters).
    let mut prev_code: char = '\n';
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            if matches!(state, State::BlockComment(_)) {
                line.has_comment = true;
            }
            lines.push(std::mem::take(&mut line));
            if let State::BlockComment(_) = state {
                line.has_comment = true;
            }
            prev_code = '\n';
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    line.has_comment = true;
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(1);
                    line.has_comment = true;
                    i += 2;
                    continue;
                }
                let may_open = c == '"' || ((c == 'r' || c == 'b') && !is_ident(prev_code));
                if may_open {
                    if let Some((len, raw)) = string_opener(&chars, i) {
                        for k in 0..len {
                            line.code.push(chars[i + k]);
                        }
                        state = match raw {
                            Some(h) => State::RawStr(h),
                            None => State::Str,
                        };
                        prev_code = '"';
                        i += len;
                        continue;
                    }
                }
                let byte_quote =
                    c == 'b' && chars.get(i + 1) == Some(&'\'') && !is_ident(prev_code);
                if c == '\'' || byte_quote {
                    let q = if byte_quote { i + 1 } else { i };
                    if chars.get(q) == Some(&'\'') {
                        let next = chars.get(q + 1);
                        let is_char = next == Some(&'\\')
                            || (next.is_some() && chars.get(q + 2) == Some(&'\''));
                        if is_char {
                            for k in i..=q {
                                line.code.push(chars[k]);
                            }
                            state = State::CharLit;
                            prev_code = '\'';
                            i = q + 1;
                            continue;
                        }
                    }
                    // Lifetime (or lone quote): plain code.
                    line.code.push(c);
                    prev_code = c;
                    i += 1;
                    continue;
                }
                line.code.push(c);
                prev_code = c;
                i += 1;
            }
            State::LineComment => {
                line.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else {
                    line.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    line.code.push(' ');
                    if chars.get(i + 1).is_some_and(|&n| n != '\n') {
                        line.code.push(' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    line.code.push('"');
                    state = State::Code;
                    prev_code = '"';
                    i += 1;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                let closes = c == '"'
                    && (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'));
                if closes {
                    line.code.push('"');
                    for _ in 0..hashes {
                        line.code.push('#');
                    }
                    state = State::Code;
                    prev_code = '"';
                    i += 1 + hashes as usize;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    line.code.push(' ');
                    if chars.get(i + 1).is_some_and(|&n| n != '\n') {
                        line.code.push(' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '\'' {
                    line.code.push('\'');
                    state = State::Code;
                    prev_code = '\'';
                    i += 1;
                } else {
                    line.code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if matches!(state, State::BlockComment(_)) {
        line.has_comment = true;
    }
    if !line.code.is_empty() || !line.comment.is_empty() || line.has_comment {
        lines.push(line);
    }
    let mut file = LexedFile { lines };
    mark_test_regions(&mut file);
    file
}

/// Does `hay[at..]` start with `needle`, with identifier boundaries on both
/// sides (so `mod tests` never matches inside `mod tests_util`)?
fn token_at(hay: &[char], at: usize, needle: &str) -> bool {
    let n: Vec<char> = needle.chars().collect();
    if at + n.len() > hay.len() || hay[at..at + n.len()] != n[..] {
        return false;
    }
    let before_ok = at == 0 || !is_ident(hay[at - 1]);
    let last = n[n.len() - 1];
    let after_ok = !is_ident(last) || hay.get(at + n.len()).is_none_or(|&c| !is_ident(c));
    before_ok && after_ok
}

/// Second pass: brace-depth tracking of `#[cfg(test)]` / `mod tests`
/// regions over the scrubbed code (string/comment occurrences can no
/// longer confuse it). A pending marker attaches to the next `{` opened at
/// its own depth and is cancelled by a `;` there (attribute on a bodyless
/// item); the region ends when depth returns to the opening level.
fn mark_test_regions(file: &mut LexedFile) {
    let mut depth: i64 = 0;
    let mut pending: Option<i64> = None;
    let mut regions: Vec<i64> = Vec::new();
    for line in &mut file.lines {
        let code: Vec<char> = line.code.chars().collect();
        let mut in_test = !regions.is_empty();
        let mut k = 0usize;
        while k < code.len() {
            if token_at(&code, k, "#[cfg(test)]") {
                pending = Some(depth);
                k += "#[cfg(test)]".chars().count();
                continue;
            }
            if token_at(&code, k, "mod tests") {
                pending = Some(depth);
                k += "mod tests".chars().count();
                continue;
            }
            match code[k] {
                '{' => {
                    if pending == Some(depth) {
                        regions.push(depth);
                        pending = None;
                        in_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if regions.last() == Some(&depth) {
                        regions.pop();
                    }
                }
                ';' => {
                    if pending == Some(depth) {
                        pending = None;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        if !regions.is_empty() {
            in_test = true;
        }
        line.in_test = in_test;
    }
}

/// Extract every suppression pragma from the file's comment text.
///
/// A pragma must LEAD its comment (`// audit: allow(rule) -- why`): prose
/// that merely *mentions* the form — doc comments describing the syntax,
/// rule tables — stays inert instead of registering as a live (and, under
/// `unused-pragma`, stale) exemption.
pub fn pragmas(file: &LexedFile) -> Vec<Pragma> {
    let mut out = Vec::new();
    for (lineno, line) in file.lines.iter().enumerate() {
        let text = line.comment.trim_start();
        let Some(after) = text.strip_prefix("audit:") else {
            continue;
        };
        let trimmed = after.trim_start();
        let Some(rest) = trimmed.strip_prefix("allow(") else {
            continue;
        };
        let Some(close) = rest.find(')') else {
            continue;
        };
        let rule = rest[..close].trim().to_string();
        let tail = rest[close + 1..].trim_start();
        let reason_ok = tail
            .strip_prefix("--")
            .is_some_and(|r| !r.trim().is_empty());
        out.push(Pragma {
            line: lineno,
            rule,
            reason_ok,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        lex(src).lines.iter().map(|l| l.code.clone()).collect()
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let a = \"x.unwrap()\"; // panic!(\nlet b = 1; /* todo!() */ let c = 2;\n";
        let code = code_of(src);
        assert!(!code[0].contains("unwrap"), "{:?}", code[0]);
        assert!(!code[0].contains("panic"), "{:?}", code[0]);
        assert!(code[1].contains("let b = 1;"));
        assert!(code[1].contains("let c = 2;"));
        assert!(!code[1].contains("todo"));
    }

    #[test]
    fn raw_strings_any_hash_depth() {
        let src = "let a = r#\"one \" two .unwrap()\"# + r\"x.expect(\" + b;\n";
        let code = code_of(src);
        assert!(!code[0].contains("unwrap"));
        assert!(!code[0].contains("expect"));
        assert!(code[0].contains("+ b;"), "{:?}", code[0]);
        let src2 = "let a = br##\"nested \"# still inside panic!(\"##;\nlet x = 3;\n";
        let code2 = code_of(src2);
        assert!(!code2[0].contains("panic"));
        assert!(code2[1].contains("let x = 3;"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        // The escaped quote must not open a string state that swallows the
        // rest of the line.
        let src = "let q = '\\''; let s: &'static str = x; y.unwrap();\n";
        let code = code_of(src);
        assert!(code[0].contains(".unwrap()"), "{:?}", code[0]);
        assert!(code[0].contains("'static"));
        let src2 = "let c = 'a'; let b = b'\\n'; z.expect(\"m\");\n";
        let code2 = code_of(src2);
        assert!(code2[0].contains(".expect("));
        assert!(!code2[0].contains('a'), "char content blanked: {:?}", code2[0]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "a(); /* one /* two */ still comment .unwrap() */ b();\n";
        let code = code_of(src);
        assert!(code[0].contains("a();"));
        assert!(code[0].contains("b();"));
        assert!(!code[0].contains("unwrap"));
    }

    #[test]
    fn escaped_backslash_does_not_extend_string() {
        let src = "let p = \"tail\\\\\"; q.unwrap();\n";
        let code = code_of(src);
        assert!(code[0].contains(".unwrap()"), "{:?}", code[0]);
    }

    #[test]
    fn multiline_string_spans_lines() {
        let src = "let s = \"line one\nline .unwrap() two\"; f();\n";
        let code = code_of(src);
        assert!(!code[1].contains("unwrap"));
        assert!(code[1].contains("f();"));
    }

    #[test]
    fn identifier_r_is_not_a_raw_string() {
        let src = "for x in iter { attr\"lit\"; }\n";
        // `for` ends in r, `attr` ends in r: neither may open a raw string
        // (the \"lit\" content is a plain string and gets blanked; the
        // brace structure must survive).
        let code = code_of(src);
        assert!(code[0].contains('{') && code[0].contains('}'), "{:?}", code[0]);
    }

    #[test]
    fn test_regions_cover_cfg_test_and_mod_tests() {
        let src = "fn live() { a(); }\n#[cfg(test)]\nmod tests {\n    fn t() { x(); }\n}\nfn live2() {}\n";
        let f = lex(src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[2].in_test, "mod tests opening line");
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test, "closing brace line");
        assert!(!f.lines[5].in_test, "region ended");
    }

    #[test]
    fn cfg_test_on_bodyless_item_does_not_leak() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() { x(); }\n";
        let f = lex(src);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn cfg_test_in_string_is_inert() {
        let src = "let s = \"#[cfg(test)]\";\nfn live() { x(); }\n";
        let f = lex(src);
        assert!(!f.lines[1].in_test);
    }

    #[test]
    fn pragma_parse_with_and_without_reason() {
        let src = "// audit: allow(panic-path) -- invariant: checked above\nx();\n// audit: allow(raw-rng)\ny();\n";
        let f = lex(src);
        let p = pragmas(&f);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].rule, "panic-path");
        assert!(p[0].reason_ok);
        assert_eq!(p[1].rule, "raw-rng");
        assert!(!p[1].reason_ok, "missing -- reason must be rejected");
    }

    #[test]
    fn pragma_mentions_in_prose_are_inert() {
        // Doc comments *describing* the pragma form must not register as
        // live exemptions (they would all be stale under unused-pragma).
        let src = "//! Suppress with `// audit: allow(panic-path) -- why`.\n\
                   /// see: audit: allow(raw-rng) -- example\n\
                   // audit: allow(panic-path) -- a real one leads its comment\n\
                   x();\n";
        let p = pragmas(&lex(src));
        assert_eq!(p.len(), 1, "{p:?}");
        assert_eq!(p[0].line, 2);
        assert_eq!(p[0].rule, "panic-path");
    }

    #[test]
    fn has_comment_tracks_block_spans() {
        let src = "let a = 1; /* start\nmiddle\n*/ let b = 2;\nlet c = 3;\n";
        let f = lex(src);
        assert!(f.lines[0].has_comment);
        assert!(f.lines[1].has_comment);
        assert!(f.lines[2].has_comment);
        assert!(!f.lines[3].has_comment);
    }
}

//! `das audit` — in-tree static analysis proving the source-level
//! invariants the chaos/equivalence gates lean on.
//!
//! The byte-identical-replay guarantee (chaos gate, PRs 6–8) silently rests
//! on properties no test can see: panic-freedom in supervised paths,
//! poison-safe locking under `catch_unwind`, no wall-clock or ambient-RNG
//! state leaking into replayed decisions, justified atomic orderings in the
//! lock-free snapshot layer, and checked narrowing in the `das-store-v1` /
//! `das-ckpt-v1` codecs. This module enforces them mechanically on every
//! commit: a [`lexer`] pass scrubs strings/comments and attributes test
//! regions, a [`rules`] pass emits findings, and the `das audit` CLI verb
//! exits nonzero on any finding.
//!
//! Suppression is per-site and must be justified:
//!
//! ```text
//! // audit: allow(panic-path) -- pool refcount invariant: segment is live
//! ```
//!
//! A pragma suppresses its named rule on the pragma's own line and the line
//! directly below. A pragma without a `-- <reason>`, or naming an unknown
//! rule, is itself a finding (rule `pragma`) and suppresses nothing —
//! malformed exemptions may not silently widen. Pragma hygiene is checked
//! in test code too. A well-formed pragma that suppresses *nothing* is a
//! finding as well (rule `unused-pragma`): exemptions may not outlive the
//! code they excused, where they would silently cover the next regression
//! on those lines. Pragmas must lead their comment — prose that merely
//! mentions the form (like this module's docs) is inert.
//!
//! Serialization files (wire codecs, JSON/report emitters) additionally
//! ban unordered hash-container iteration (rule `hashmap-order-leak`):
//! HashMap/HashSet order would leak ambient hash-seed state into bytes the
//! store/chaos gates compare for equality. Sort first or use a BTree.
//!
//! JSON output (`--json <path>`) uses the `das-audit-v1` schema: an object
//! with `schema`, `root`, `files_scanned`, `suppressed`, `findings`
//! (`rule`/`file`/`line`/`message`/`excerpt` per entry, sorted by file then
//! line) and the `rules` registry, serialized deterministically via
//! [`crate::util::json`].

pub mod lexer;
pub mod rules;

use std::io;
use std::path::Path;

pub use rules::{Finding, RuleInfo, RULES};

use crate::util::json::Json;

/// Result of one audit run over a scan root.
#[derive(Debug)]
pub struct AuditReport {
    /// Scan root as given (display form, `/`-separated members below it).
    pub root: String,
    pub files_scanned: usize,
    /// Surviving findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings silenced by a well-formed suppression pragma.
    pub suppressed: usize,
}

/// Recursively collect `.rs` files under `dir`, as `/`-separated paths
/// relative to the scan root, sorted — the walk order (and therefore the
/// report) is deterministic regardless of directory-entry order.
fn collect_rs_files(dir: &Path, prefix: &str, out: &mut Vec<String>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let rel = if prefix.is_empty() { name.to_string() } else { format!("{prefix}/{name}") };
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, &rel, out)?;
        } else if name.ends_with(".rs") {
            out.push(rel);
        }
    }
    Ok(())
}

/// Run every rule over every `.rs` file under `root` and fold the findings
/// into a deterministic [`AuditReport`].
pub fn run_audit(root: &Path) -> io::Result<AuditReport> {
    let mut files = Vec::new();
    collect_rs_files(root, "", &mut files)?;
    files.sort();

    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for rel in &files {
        let source = std::fs::read_to_string(root.join(rel))
            .map_err(|e| io::Error::other(format!("{rel}: {e}")))?;
        let raw: Vec<&str> = source.lines().collect();
        let lexed = lexer::lex(&source);
        let pragmas = lexer::pragmas(&lexed);
        // Per-pragma suppression tally — a well-formed pragma that ends the
        // run with zero hits is stale (rule `unused-pragma` below).
        let mut hits = vec![0usize; pragmas.len()];
        for f in rules::scan_file(rel, &lexed, &raw) {
            // A well-formed pragma covers its own line and the next one;
            // malformed pragmas deliberately cover nothing.
            let hit = pragmas.iter().position(|p| {
                p.reason_ok && p.rule == f.rule && (p.line + 1 == f.line || p.line + 2 == f.line)
            });
            if let Some(i) = hit {
                hits[i] += 1;
                suppressed += 1;
            } else {
                findings.push(f);
            }
        }
        for p in &pragmas {
            let excerpt = raw.get(p.line).map_or(String::new(), |l| l.trim().to_string());
            let known = RULES.iter().any(|r| r.name == p.rule && r.name != rules::PRAGMA);
            let message = if !known {
                Some(format!(
                    "pragma names unknown rule `{}` — it suppresses nothing (known: {})",
                    p.rule,
                    RULES.iter().map(|r| r.name).collect::<Vec<_>>().join(", ")
                ))
            } else if !p.reason_ok {
                Some(format!(
                    "suppression pragma without a reason — write \
                     `// audit: allow({}) -- <why>`",
                    p.rule
                ))
            } else {
                None
            };
            if let Some(message) = message {
                findings.push(Finding {
                    rule: rules::PRAGMA,
                    file: rel.clone(),
                    line: p.line + 1,
                    message,
                    excerpt,
                });
            }
        }
        // unused-pragma: a well-formed pragma naming a known rule that
        // suppressed nothing this run is stale — the code it excused was
        // fixed or moved, and a lingering exemption would silently cover
        // the next regression on those lines. An `allow(unused-pragma)`
        // pragma on the same or preceding line can excuse a deliberately
        // kept exemption (e.g. one covering cfg-gated code the scan
        // cannot see); a coverer that excuses something counts as used.
        let mut used: Vec<bool> = hits.iter().map(|&h| h > 0).collect();
        let mut stale: Vec<usize> = Vec::new();
        for (i, p) in pragmas.iter().enumerate() {
            let known = RULES.iter().any(|r| {
                r.name == p.rule && r.name != rules::PRAGMA && r.name != rules::UNUSED_PRAGMA
            });
            if !p.reason_ok || !known || used[i] {
                continue;
            }
            match pragmas.iter().position(|q| {
                q.reason_ok
                    && q.rule == rules::UNUSED_PRAGMA
                    && (q.line == p.line || q.line + 1 == p.line)
            }) {
                Some(q) => {
                    used[q] = true;
                    suppressed += 1;
                }
                None => stale.push(i),
            }
        }
        // Coverers that excused nothing are themselves stale.
        for (i, p) in pragmas.iter().enumerate() {
            if p.reason_ok && p.rule == rules::UNUSED_PRAGMA && !used[i] {
                stale.push(i);
            }
        }
        stale.sort_unstable();
        for i in stale {
            let p = &pragmas[i];
            findings.push(Finding {
                rule: rules::UNUSED_PRAGMA,
                file: rel.clone(),
                line: p.line + 1,
                message: format!(
                    "pragma `allow({})` suppressed nothing — the rule no longer \
                     fires on its covered lines; delete the stale exemption",
                    p.rule
                ),
                excerpt: raw.get(p.line).map_or(String::new(), |l| l.trim().to_string()),
            });
        }
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(AuditReport {
        root: root.display().to_string(),
        files_scanned: files.len(),
        findings,
        suppressed,
    })
}

impl AuditReport {
    /// Human rendering: one `file:line: [rule] message` block per finding,
    /// then a one-line verdict.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
            if !f.excerpt.is_empty() {
                out.push_str(&format!("    > {}\n", f.excerpt));
            }
        }
        if self.findings.is_empty() {
            out.push_str(&format!(
                "das audit: clean — {} files under {}, {} finding(s) suppressed by pragma\n",
                self.files_scanned, self.root, self.suppressed
            ));
        } else {
            out.push_str(&format!(
                "das audit: {} finding(s) across {} files under {} ({} suppressed)\n",
                self.findings.len(),
                self.files_scanned,
                self.root,
                self.suppressed
            ));
        }
        out
    }

    /// `das-audit-v1` JSON report (deterministic: BTreeMap-backed objects,
    /// findings pre-sorted).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("das-audit-v1")),
            ("root", Json::str(&self.root)),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("suppressed", Json::num(self.suppressed as f64)),
            (
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("rule", Json::str(f.rule)),
                                ("file", Json::str(&f.file)),
                                ("line", Json::num(f.line as f64)),
                                ("message", Json::str(&f.message)),
                                ("excerpt", Json::str(&f.excerpt)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "rules",
                Json::Arr(
                    RULES
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::str(r.name)),
                                ("description", Json::str(r.description)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;

    /// Write fixture files under a unique temp root and return it.
    fn fixture(name: &str, files: &[(&str, &str)]) -> PathBuf {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let root =
            std::env::temp_dir().join(format!("das-audit-{}-{name}-{n}", std::process::id()));
        for (rel, src) in files {
            let path = root.join(rel);
            std::fs::create_dir_all(path.parent().expect("fixture paths have parents")).expect(
                "create fixture dir",
            );
            std::fs::write(&path, src).expect("write fixture file");
        }
        root
    }

    fn audit(root: &PathBuf) -> AuditReport {
        let report = run_audit(root).expect("fixture audit runs");
        std::fs::remove_dir_all(root).ok();
        report
    }

    fn count(report: &AuditReport, rule: &str) -> usize {
        report.findings.iter().filter(|f| f.rule == rule).count()
    }

    #[test]
    fn every_seeded_violation_fires_exactly_once() {
        let root = fixture(
            "seeded",
            &[
                ("rollout/engine.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n"),
                (
                    "util/cow.rs",
                    "fn g(a: &std::sync::atomic::AtomicBool) { a.store(true, Ordering::SeqCst); }\n",
                ),
                ("model/sim.rs", "fn h() { let _t = std::time::Instant::now(); }\n"),
                ("workload/mod.rs", "fn r() { let _rng = thread_rng(); }\n"),
                ("store/wire.rs", "fn n(x: u64) -> u32 { x as u32 }\n"),
                (
                    "telemetry/mod.rs",
                    "fn l(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n",
                ),
                (
                    "draftsvc/wire.rs",
                    "fn w(m: &std::collections::HashMap<u32, u32>) { for k in m.keys() { emit(k); } }\n",
                ),
                (
                    "model/mod.rs",
                    "// audit: allow(panic-path) -- fixture: nothing here panics\nfn quiet() {}\n",
                ),
            ],
        );
        let report = audit(&root);
        let expected = [
            "panic-path",
            "atomic-ordering",
            "wall-clock-determinism",
            "raw-rng",
            "unchecked-narrowing",
            "poisoned-lock",
            "hashmap-order-leak",
            "unused-pragma",
        ];
        for rule in expected {
            assert_eq!(count(&report, rule), 1, "rule {rule}: {}", report.render());
        }
        assert_eq!(report.findings.len(), 8, "{}", report.render());
        assert_eq!(report.files_scanned, 8);
    }

    #[test]
    fn strings_comments_and_test_regions_do_not_fire() {
        let src = r##"
fn live() {
    let a = "x.unwrap() and Instant::now() in a string";
    let b = r#"panic!("raw string") thread_rng()"#;
    let _ = (a, b);
}
/// Doc comment: .unwrap() panic!( SystemTime thread_rng Ordering::SeqCst
// line comment: x as u32 .lock().unwrap()
#[cfg(test)]
mod tests {
    fn t(x: Option<u32>) {
        x.unwrap();
        let _t = std::time::Instant::now();
        panic!("test code is exempt");
    }
}
"##;
        let root = fixture("exempt", &[("rollout/engine.rs", src)]);
        let report = audit(&root);
        assert!(report.findings.is_empty(), "{}", report.render());
    }

    #[test]
    fn pragma_suppresses_own_line_and_next_line_only() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // audit: allow(panic-path) -- fixture: exercised invariant\n\
                   x.unwrap()\n\
                   }\n\
                   fn g(y: Option<u32>) -> u32 {\n\
                   y.unwrap() // audit: allow(panic-path) -- fixture: same line\n\
                   }\n\
                   fn far(z: Option<u32>) -> u32 {\n\
                   // audit: allow(panic-path) -- fixture: too far away\n\
                   let keep = 1;\n\
                   z.unwrap() + keep\n\
                   }\n";
        let root = fixture("pragma", &[("store/mod.rs", src)]);
        let report = audit(&root);
        assert_eq!(count(&report, "panic-path"), 1, "{}", report.render());
        let survivor = report.findings.iter().find(|f| f.rule == "panic-path").unwrap();
        assert_eq!(survivor.line, 11, "only the out-of-range site survives");
        // The too-far pragma suppressed nothing — it is stale.
        assert_eq!(count(&report, "unused-pragma"), 1, "{}", report.render());
        assert_eq!(report.suppressed, 2);
    }

    #[test]
    fn reasonless_pragma_is_a_violation_and_does_not_suppress() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // audit: allow(panic-path)\n\
                   x.unwrap()\n\
                   }\n";
        let root = fixture("reasonless", &[("suffix/core.rs", src)]);
        let report = audit(&root);
        assert_eq!(count(&report, "pragma"), 1, "{}", report.render());
        assert_eq!(count(&report, "panic-path"), 1, "malformed pragma must not suppress");
        assert_eq!(report.suppressed, 0);
    }

    #[test]
    fn unknown_rule_pragma_is_flagged() {
        let src = "// audit: allow(made-up-rule) -- reason present but rule unknown\nfn f() {}\n";
        let root = fixture("unknown", &[("drafter/mod.rs", src)]);
        let report = audit(&root);
        assert_eq!(count(&report, "pragma"), 1, "{}", report.render());
        assert!(report.findings[0].message.contains("made-up-rule"));
    }

    #[test]
    fn wrong_rule_pragma_does_not_suppress() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // audit: allow(raw-rng) -- names the wrong rule\n\
                   x.unwrap()\n\
                   }\n";
        let root = fixture("wrongrule", &[("rollout/request.rs", src)]);
        let report = audit(&root);
        assert_eq!(count(&report, "panic-path"), 1, "{}", report.render());
        assert_eq!(count(&report, "unused-pragma"), 1, "wrong-rule pragma is also stale");
        assert_eq!(report.suppressed, 0);
    }

    #[test]
    fn unused_pragma_coverage_excuses_kept_exemptions() {
        // A deliberately kept exemption (covers cfg-gated code the scan
        // cannot see) is excused by allow(unused-pragma) on the line above.
        let kept = "// audit: allow(unused-pragma) -- fixture: covers cfg-gated code\n\
                    // audit: allow(panic-path) -- fixture: cfg(feature) unwrap below\n\
                    fn quiet() {}\n";
        let root = fixture("kept", &[("model/mod.rs", kept)]);
        let report = audit(&root);
        assert!(report.findings.is_empty(), "{}", report.render());
        assert_eq!(report.suppressed, 1, "the covered exemption counts as suppressed");

        // A coverer that excuses nothing is itself stale.
        let lone = "// audit: allow(unused-pragma) -- fixture: excuses nothing\nfn lonely() {}\n";
        let root = fixture("lone-coverer", &[("model/mod.rs", lone)]);
        let report = audit(&root);
        assert_eq!(count(&report, "unused-pragma"), 1, "{}", report.render());
        assert_eq!(report.findings[0].line, 1);
    }

    #[test]
    fn findings_are_sorted_and_walk_is_deterministic() {
        let files: &[(&str, &str)] = &[
            ("store/wire.rs", "fn a(x: u64) -> u32 { x as u32 }\nfn b(y: u64) -> u8 { y as u8 }\n"),
            ("drafter/mod.rs", "fn c(x: Option<u32>) -> u32 { x.unwrap() }\n"),
        ];
        let root = fixture("sorted", files);
        let report = audit(&root);
        let keys: Vec<(String, usize)> =
            report.findings.iter().map(|f| (f.file.clone(), f.line)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "{}", report.render());
        assert_eq!(report.findings.len(), 3);
    }

    #[test]
    fn json_report_round_trips_and_carries_the_registry() {
        let root = fixture(
            "json",
            &[("rollout/engine.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n")],
        );
        let report = audit(&root);
        let parsed = Json::parse(&report.to_json().to_string()).expect("report JSON parses");
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some("das-audit-v1"));
        assert_eq!(parsed.get("files_scanned").and_then(Json::as_usize), Some(1));
        let findings = parsed.get("findings").and_then(Json::as_arr).expect("findings array");
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].get("rule").and_then(Json::as_str), Some("panic-path"));
        assert_eq!(findings[0].get("line").and_then(Json::as_usize), Some(1));
        let rules_arr = parsed.get("rules").and_then(Json::as_arr).expect("rules array");
        assert_eq!(rules_arr.len(), RULES.len());
    }

    /// The keystone: the live tree must be audit-clean. Every in-tree
    /// exemption is a reasoned pragma, so a regression anywhere in
    /// `rust/src` fails this test (and the gating CI job) immediately.
    #[test]
    fn self_audit_live_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = run_audit(&root).expect("live-tree audit runs");
        assert!(report.findings.is_empty(), "live tree has findings:\n{}", report.render());
        assert!(report.files_scanned > 20, "walk saw the whole tree");
    }
}

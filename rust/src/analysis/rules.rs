//! The `das audit` rule set — each rule mechanically enforces one of the
//! source-level invariants the chaos/equivalence gates lean on (see module
//! docs of [`super`] for the contract and the README rule table).
//!
//! Rules are lexical and run over the scrubbed per-line view produced by
//! [`super::lexer`]: string/comment occurrences never fire, `#[cfg(test)]`
//! / `mod tests` regions are exempt from every rule except `poisoned-lock`
//! (a poisoned mutex in a multi-threaded test cascades into unrelated
//! failures exactly like it would in production code).

use super::lexer::LexedFile;

/// One rule violation at a specific source line (1-based).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub excerpt: String,
}

/// Registry entry: name + the one-line contract it enforces.
pub struct RuleInfo {
    pub name: &'static str,
    pub description: &'static str,
}

pub const PANIC_PATH: &str = "panic-path";
pub const POISONED_LOCK: &str = "poisoned-lock";
pub const WALL_CLOCK: &str = "wall-clock-determinism";
pub const RAW_RNG: &str = "raw-rng";
pub const ATOMIC_ORDERING: &str = "atomic-ordering";
pub const UNCHECKED_NARROWING: &str = "unchecked-narrowing";
pub const HASHMAP_ORDER: &str = "hashmap-order-leak";
/// Meta-rule: malformed suppression pragmas are themselves violations.
pub const PRAGMA: &str = "pragma";
/// Meta-rule (enforced in [`super::run_audit`], not here): a well-formed
/// pragma whose rule no longer fires on its covered lines is stale.
pub const UNUSED_PRAGMA: &str = "unused-pragma";

pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: PANIC_PATH,
        description: "no unwrap/expect/panic!/todo!/unimplemented! in rollout/, store/, \
                      suffix/, drafter/ non-test code — supervised paths degrade, they \
                      don't abort",
    },
    RuleInfo {
        name: POISONED_LOCK,
        description: ".lock() must recover from poisoning via \
                      unwrap_or_else(|e| e.into_inner()), never .lock().unwrap() — a \
                      panic under catch_unwind while holding a shared mutex poisons it \
                      for every other worker (applies to test code too)",
    },
    RuleInfo {
        name: WALL_CLOCK,
        description: "no Instant::now/SystemTime outside rollout/parallel.rs deadline \
                      code and util/bench.rs — replay determinism is load-bearing for \
                      the chaos gate",
    },
    RuleInfo {
        name: RAW_RNG,
        description: "randomness only via util/rng — ambient entropy (thread_rng, \
                      RandomState, getrandom) breaks byte-identical replay",
    },
    RuleInfo {
        name: ATOMIC_ORDERING,
        description: "atomic Ordering:: uses must sit in an allowlisted concurrency \
                      file (util/cow.rs, rollout/faults.rs, rollout/parallel.rs) AND \
                      carry a same-line-or-above justification comment",
    },
    RuleInfo {
        name: UNCHECKED_NARROWING,
        description: "no bare `as u8/u16/u32/usize` narrowing in the das-store-v1 / \
                      das-ckpt-v1 codec files (store/wire.rs, store/mod.rs, \
                      rollout/request.rs) — use try_from or the codec's checked helpers",
    },
    RuleInfo {
        name: HASHMAP_ORDER,
        description: "no HashMap/HashSet iteration in serialization files \
                      (wire codecs, JSON/report emitters) unless the result is \
                      sorted in place or collected into a BTree — hash iteration \
                      order would leak into bytes that must be deterministic",
    },
    RuleInfo {
        name: PRAGMA,
        description: "suppression pragmas must carry a reason: \
                      `// audit: allow(<rule>) -- <why>`",
    },
    RuleInfo {
        name: UNUSED_PRAGMA,
        description: "a well-formed `// audit: allow(<rule>)` pragma whose rule \
                      no longer fires on its covered lines is stale — delete it \
                      so exemptions never outlive the code they excused",
    },
];

/// Directories whose non-test code must be panic-free.
const PANIC_DIRS: &[&str] = &["rollout/", "store/", "suffix/", "drafter/", "draftsvc/"];
const PANIC_TOKENS: &[&str] = &[".unwrap()", ".expect(", "panic!(", "todo!(", "unimplemented!("];

/// Files allowed to read the wall clock (deadline stealing needs real
/// elapsed time; the bench harness measures it by definition).
const WALL_CLOCK_ALLOW: &[&str] = &["rollout/parallel.rs", "util/bench.rs"];
const WALL_CLOCK_TOKENS: &[&str] = &["Instant::now", "SystemTime"];

const RNG_TOKENS: &[&str] = &["thread_rng", "rand::", "from_entropy", "getrandom", "RandomState"];
const RNG_EXEMPT: &[&str] = &["util/rng.rs"];

/// The audited lock-free/atomic layer; everything else routes through it.
const ATOMIC_ALLOW: &[&str] =
    &["util/cow.rs", "rollout/faults.rs", "rollout/parallel.rs", "draftsvc/server.rs"];
const ATOMIC_VARIANTS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

const NARROW_FILES: &[&str] =
    &["store/wire.rs", "store/mod.rs", "rollout/request.rs", "draftsvc/wire.rs"];
const NARROW_TYPES: &[&str] = &["u8", "u16", "u32", "usize"];

/// Files whose output bytes must be deterministic: wire codecs and the
/// JSON/report emitters. Iterating a hash container here bakes ambient
/// hash-seed order into frames, stores or reports.
const ORDER_FILES: &[&str] = &[
    "store/wire.rs",
    "store/mod.rs",
    "rollout/request.rs",
    "draftsvc/wire.rs",
    "draftsvc/server.rs",
    "util/json.rs",
    "telemetry/mod.rs",
    "analysis/mod.rs",
];
const ORDER_ITER_METHODS: &[&str] =
    &[".iter()", ".iter_mut()", ".keys()", ".values()", ".values_mut()", ".into_iter()", ".drain("];

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of boundary-checked occurrences of `needle` in `code`: the
/// char before the match and (when the needle ends in an identifier char)
/// the char after must not extend an identifier, so `.expect(` never
/// matches inside `.expect_str(` and `panic!(` never inside
/// `dont_panic!(…)`-style names.
fn token_offsets(code: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = code[from..].find(needle) {
        let at = from + rel;
        let before_ok = at == 0
            || code[..at].chars().next_back().is_none_or(|c| !is_ident(c) && c != '.');
        let last_ident = needle.chars().next_back().is_some_and(is_ident);
        let after_ok =
            !last_ident || code[at + needle.len()..].chars().next().is_none_or(|c| !is_ident(c));
        // A leading-`.` needle anchors itself; only bare-word needles need
        // the `.`-exclusion (Instant::now must not match Foo.Instant::now,
        // which cannot occur anyway — but a `.`-prefixed token like
        // `.unwrap()` legitimately follows an identifier).
        let before_ok = before_ok || needle.starts_with('.');
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + needle.len();
    }
    out
}

fn in_list(rel: &str, list: &[&str]) -> bool {
    list.iter().any(|p| *p == rel)
}

/// Collect identifiers a line declares with a HashMap/HashSet type:
/// `name: HashMap<…>` / `name: &HashSet<…>` (fields, params, annotated
/// lets) and `let name = HashMap::new()` / `HashSet::with_capacity(…)`.
fn collect_map_idents(code: &str, out: &mut Vec<String>) {
    let mut push = |ident: String| {
        if !ident.is_empty()
            && !ident.chars().next().is_some_and(|c| c.is_ascii_digit())
            && !out.contains(&ident)
        {
            out.push(ident);
        }
    };
    for ty in ["HashMap", "HashSet"] {
        for at in token_offsets(code, ty) {
            // `name: HashMap<…>` — walk back over a `std::collections::`
            // path qualifier, then `&`/`&mut`, then the colon.
            let mut before = code[..at].trim_end();
            while let Some(b) = before.strip_suffix("::") {
                let seg: usize =
                    b.chars().rev().take_while(|c| is_ident(*c)).map(char::len_utf8).sum();
                before = b[..b.len() - seg].trim_end();
            }
            before = before.strip_suffix("&mut").unwrap_or(before).trim_end();
            before = before.strip_suffix('&').unwrap_or(before).trim_end();
            if let Some(b) = before.strip_suffix(':') {
                let b = b.trim_end();
                let tail: String = b.chars().rev().take_while(|c| is_ident(*c)).collect();
                push(tail.chars().rev().collect());
            }
        }
        // `let name = HashMap::new()` — the inferred-type form.
        if code.contains(&format!("{ty}::")) {
            for at in token_offsets(code, "let") {
                let rest = code[at + 3..].trim_start();
                let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
                let ident: String = rest.chars().take_while(|c| is_ident(*c)).collect();
                push(ident);
            }
        }
    }
}

fn under_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(d))
}

/// Run every rule over one scanned file. `rel` is the path relative to the
/// scan root, `/`-separated; `raw` holds the original source lines for
/// finding excerpts. Suppression pragmas are applied by the caller.
pub fn scan_file(rel: &str, lexed: &LexedFile, raw: &[&str]) -> Vec<Finding> {
    let mut out = Vec::new();
    let excerpt = |line0: usize| -> String {
        raw.get(line0).map_or(String::new(), |l| l.trim().to_string())
    };
    let mut push = |rule: &'static str, line0: usize, message: String| {
        out.push(Finding {
            rule,
            file: rel.to_string(),
            line: line0 + 1,
            message,
            excerpt: excerpt(line0),
        });
    };

    let panic_scope = under_dirs(rel, PANIC_DIRS);
    let wall_allowed = in_list(rel, WALL_CLOCK_ALLOW);
    let rng_exempt = in_list(rel, RNG_EXEMPT);
    let atomic_allowed = in_list(rel, ATOMIC_ALLOW);
    let narrow_scope = in_list(rel, NARROW_FILES);
    let order_scope = in_list(rel, ORDER_FILES);

    for (line0, line) in lexed.lines.iter().enumerate() {
        let code = line.code.as_str();
        if line.in_test {
            continue; // poisoned-lock (cross-line) is handled below
        }
        if panic_scope {
            for tok in PANIC_TOKENS {
                for _ in token_offsets(code, tok) {
                    push(
                        PANIC_PATH,
                        line0,
                        format!("`{tok}` in supervised path code — return an error or \
                                 degrade instead of aborting the worker"),
                    );
                }
            }
        }
        if !wall_allowed {
            for tok in WALL_CLOCK_TOKENS {
                for _ in token_offsets(code, tok) {
                    push(
                        WALL_CLOCK,
                        line0,
                        format!("`{tok}` outside the deadline/bench allowlist — \
                                 wall-clock state breaks byte-identical replay"),
                    );
                }
            }
        }
        if !rng_exempt {
            for tok in RNG_TOKENS {
                for _ in token_offsets(code, tok) {
                    push(
                        RAW_RNG,
                        line0,
                        format!("`{tok}` — all randomness must flow through util/rng \
                                 so seeds replay deterministically"),
                    );
                }
            }
        }
        for variant in ATOMIC_VARIANTS {
            let needle = format!("Ordering::{variant}");
            for _ in token_offsets(code, &needle) {
                if !atomic_allowed {
                    push(
                        ATOMIC_ORDERING,
                        line0,
                        format!("`{needle}` outside the audited concurrency layer \
                                 ({}) — route through it or justify with a pragma",
                                ATOMIC_ALLOW.join(", ")),
                    );
                } else {
                    let justified = line.has_comment
                        || line0 > 0 && lexed.lines[line0 - 1].has_comment;
                    if !justified {
                        push(
                            ATOMIC_ORDERING,
                            line0,
                            format!("`{needle}` without a same-line-or-above \
                                     justification comment"),
                        );
                    }
                }
            }
        }
        if narrow_scope {
            for at in token_offsets(code, "as") {
                let rest = code[at + 2..].trim_start();
                let narrow = NARROW_TYPES.iter().find(|t| {
                    rest.strip_prefix(**t)
                        .is_some_and(|r| r.chars().next().is_none_or(|c| !is_ident(c)))
                });
                if let Some(t) = narrow {
                    push(
                        UNCHECKED_NARROWING,
                        line0,
                        format!("bare `as {t}` narrowing in codec code — use try_from \
                                 or the wire codec's checked length helpers"),
                    );
                }
            }
        }
    }

    // hashmap-order-leak: two passes — collect every ident the file
    // declares with a hash-container type, then flag iteration over them.
    // Sorting on the flagged or following line (`.sort…`) or collecting
    // into a BTree container on the flagged line is the sanctioned
    // ordered idiom and stays quiet.
    if order_scope {
        let mut idents: Vec<String> = Vec::new();
        for line in &lexed.lines {
            collect_map_idents(&line.code, &mut idents);
        }
        for (line0, line) in lexed.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let code = line.code.as_str();
            let ordered_nearby = (line0..=line0 + 1).any(|l| {
                lexed
                    .lines
                    .get(l)
                    .is_some_and(|li| li.code.contains(".sort") || li.code.contains("BTree"))
            });
            if ordered_nearby {
                continue;
            }
            for ident in &idents {
                let method_hit = ORDER_ITER_METHODS
                    .iter()
                    .any(|m| !token_offsets(code, &format!("{ident}{m}")).is_empty());
                let for_hit = [
                    format!("in &mut {ident}"),
                    format!("in &{ident}"),
                    format!("in {ident}"),
                ]
                .iter()
                .any(|p| !token_offsets(code, p).is_empty());
                if method_hit || for_hit {
                    push(
                        HASHMAP_ORDER,
                        line0,
                        format!(
                            "iteration over hash-ordered `{ident}` in a \
                             serialization file — hash order leaks into emitted \
                             bytes; sort first or use a BTree container"
                        ),
                    );
                }
            }
        }
    }

    // poisoned-lock: cross-line chain scan, test code NOT exempt.
    for line0 in 0..lexed.lines.len() {
        let code = lexed.lines[line0].code.as_str();
        for at in token_offsets(code, ".lock()") {
            if chain_hits_unwrap(lexed, line0, at + ".lock()".len()) {
                push(
                    POISONED_LOCK,
                    line0,
                    "`.lock().unwrap()` propagates mutex poisoning — use \
                     `.lock().unwrap_or_else(|e| e.into_inner())` (state is guarded \
                     by the engine's catch_unwind recovery, not by poisoning)"
                        .to_string(),
                );
            }
        }
    }
    out
}

/// Does the method chain continuing at (`line0`, byte `col`) next call
/// `.unwrap()` or `.expect(`? Follows rustfmt-style wrapped chains across
/// up to 3 continuation lines.
fn chain_hits_unwrap(lexed: &LexedFile, line0: usize, col: usize) -> bool {
    let mut line = line0;
    let mut rest: &str = lexed.lines[line0].code.get(col..).unwrap_or("");
    for _ in 0..4 {
        let t = rest.trim_start();
        if !t.is_empty() {
            return t.starts_with(".unwrap()") || t.starts_with(".expect(");
        }
        line += 1;
        match lexed.lines.get(line) {
            Some(l) => rest = l.code.as_str(),
            None => return false,
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn scan(rel: &str, src: &str) -> Vec<Finding> {
        let raw: Vec<&str> = src.lines().collect();
        scan_file(rel, &lex(src), &raw)
    }

    #[test]
    fn token_boundaries_do_not_overmatch() {
        // expect_str / unwrap_or_else / set_panic_hook must not fire.
        let src = "r.expect_str(a, b); x.unwrap_or_else(f); set_panic_hook();\n";
        assert!(scan("store/mod.rs", src).is_empty());
        let hit = scan("store/mod.rs", "x.expect(\"m\");\n");
        assert_eq!(hit.len(), 1);
        assert_eq!(hit[0].rule, PANIC_PATH);
        assert_eq!(hit[0].line, 1);
    }

    #[test]
    fn panic_path_scope_is_directory_limited() {
        assert!(scan("figures/fig01.rs", "x.unwrap();\n").is_empty());
        assert_eq!(scan("suffix/core.rs", "x.unwrap();\n").len(), 1);
    }

    #[test]
    fn cmp_ordering_is_not_atomic_ordering() {
        let src = "match a.cmp(&b) { std::cmp::Ordering::Less => 1, _ => 2 };\n";
        assert!(scan("model/sim.rs", src).is_empty());
        let hits = scan("model/sim.rs", "x.store(1, Ordering::Relaxed);\n");
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, ATOMIC_ORDERING);
    }

    #[test]
    fn atomic_in_allowlisted_file_needs_a_comment() {
        let bare = "x.store(1, Ordering::Relaxed);\n";
        let hits = scan("util/cow.rs", bare);
        assert_eq!(hits.len(), 1, "no justification comment");
        let above = "// Relaxed: gauge only, no ordering dependency.\nx.store(1, Ordering::Relaxed);\n";
        assert!(scan("util/cow.rs", above).is_empty());
        let trailing = "x.store(1, Ordering::Relaxed); // publish-only counter\n";
        assert!(scan("util/cow.rs", trailing).is_empty());
    }

    #[test]
    fn narrowing_only_in_codec_files_and_only_narrow_types() {
        assert_eq!(scan("store/wire.rs", "let n = x as u32;\n").len(), 1);
        assert!(scan("store/wire.rs", "let n = x as u64;\n").is_empty(), "widening ok");
        assert!(scan("store/wire.rs", "let n = u32::try_from(x);\n").is_empty());
        assert!(scan("suffix/core.rs", "let n = x as u32;\n").is_empty(), "out of scope");
        // `as usize` with an identifier continuation is a different token.
        assert!(scan("store/wire.rs", "let n = x as usize_like;\n").is_empty());
    }

    #[test]
    fn lock_unwrap_across_wrapped_chain() {
        let src = "let g = self.cell\n    .lock()\n    .unwrap();\n";
        let hits = scan("telemetry/mod.rs", src);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, POISONED_LOCK);
        assert_eq!(hits[0].line, 2, "reported at the .lock() line");
        let ok = "let g = self.cell.lock().unwrap_or_else(|e| e.into_inner());\n";
        assert!(scan("telemetry/mod.rs", ok).is_empty());
    }

    #[test]
    fn poisoned_lock_fires_inside_test_code_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let g = m.lock().unwrap(); }\n}\n";
        let hits = scan("drafter/mod.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, POISONED_LOCK);
        // …while panic-path stays exempt in the same region:
        let src2 = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(scan("drafter/mod.rs", src2).is_empty());
    }

    #[test]
    fn wall_clock_allowlist() {
        assert!(scan("util/bench.rs", "let t = Instant::now();\n").is_empty());
        assert!(scan("rollout/parallel.rs", "let t = Instant::now();\n").is_empty());
        let hits = scan("rollout/engine.rs", "let t = Instant::now();\n");
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, WALL_CLOCK);
        assert_eq!(scan("model/sim.rs", "let t = SystemTime::now();\n").len(), 1);
    }

    #[test]
    fn hashmap_iteration_in_serialization_files_is_flagged() {
        let src = "use std::collections::HashMap;\n\
                   fn emit(shards: &HashMap<u32, u32>, w: &mut Writer) {\n\
                   for (k, v) in shards.iter() { w.u32(*k); }\n\
                   }\n";
        let hits = scan("draftsvc/wire.rs", src);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].rule, HASHMAP_ORDER);
        assert_eq!(hits[0].line, 3);
        // Out of scope: non-serialization code may iterate maps freely
        // (order-insensitive folds are common and legitimate there).
        assert!(scan("rollout/engine.rs", src).is_empty());
        // Methods on untracked idents stay quiet.
        assert!(scan("draftsvc/wire.rs", "fn f(v: &Vec<u32>) { v.iter().count(); }\n")
            .is_empty());
        // Path-qualified declarations are tracked too.
        let qualified = "fn w(m: &std::collections::HashMap<u32, u32>) {\n\
                         for k in m.keys() { emit(k); }\n\
                         }\n";
        let hits = scan("draftsvc/wire.rs", qualified);
        assert_eq!(hits.len(), 1, "{hits:?}");
        assert_eq!(hits[0].line, 2);
    }

    #[test]
    fn sorted_or_btree_hash_iteration_is_sanctioned() {
        let src = "fn emit(shards: &HashMap<u32, u32>) {\n\
                   let mut keys: Vec<_> = shards.keys().collect();\n\
                   keys.sort();\n\
                   for k in keys { w(k); }\n\
                   }\n";
        assert!(scan("store/mod.rs", src).is_empty(), "sort on the next line sanctions");
        let btree =
            "fn emit(m: &HashMap<u32, u32>) { let b: BTreeMap<_, _> = m.iter().collect(); }\n";
        assert!(scan("store/mod.rs", btree).is_empty(), "BTree collect on the same line");
    }

    #[test]
    fn inferred_let_hash_containers_are_tracked() {
        let src = "fn f() {\n\
                   let mut seen = HashSet::new();\n\
                   for x in &seen { emit(x); }\n\
                   let total: u32 = seen.drain().sum();\n\
                   }\n";
        let hits = scan("draftsvc/server.rs", src);
        assert_eq!(hits.len(), 2, "{hits:?}");
        assert!(hits.iter().all(|h| h.rule == HASHMAP_ORDER));
        assert_eq!((hits[0].line, hits[1].line), (3, 4));
    }

    #[test]
    fn raw_rng_tokens() {
        assert_eq!(scan("workload/mod.rs", "let r = rand::thread_rng();\n").len(), 2);
        assert!(scan("util/rng.rs", "fn thread_rng() {}\n").is_empty(), "exempt file");
        assert!(scan("workload/mod.rs", "let r = util::rng::Rng::new(7);\n").is_empty());
    }
}

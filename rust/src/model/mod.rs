//! Target-model abstraction.
//!
//! The rollout engine speaks to the policy through [`TargetModel`]: a
//! batched "process context + draft block, return K+1 next-token
//! distributions" interface — exactly the shape of a speculative-decoding
//! verify pass. Two backends:
//!
//! * [`sim::SimModel`] — a synthetic, drifting policy with a calibrated
//!   virtual clock. Reproduces the paper's workload *structure* (long-tail
//!   lengths, cross-epoch similarity, policy sharpening) at paper scale in
//!   milliseconds of wall time. See DESIGN.md §3 (substitutions).
//! * [`crate::runtime::PjrtModel`] — the real thing: AOT-compiled JAX/Pallas
//!   transformer executed through the PJRT C API.

pub mod sim;

use crate::cost::LatencyModel;
use crate::tokens::{ProblemId, RequestId, TokenId};

/// One element of a batched verify pass.
#[derive(Debug, Clone)]
pub struct StepInput<'a> {
    pub request: RequestId,
    pub problem: ProblemId,
    /// Full context: prompt + committed tokens.
    pub context: &'a [TokenId],
    /// Number of leading context tokens that are the prompt.
    pub prompt_len: usize,
    /// Proposed draft block (may be empty = plain decode of one token).
    pub draft: &'a [TokenId],
}

/// Per-element output: `draft.len() + 1` temperature-adjusted probability
/// distributions over the vocabulary.
pub type StepOutput = Vec<Vec<f32>>;

pub trait TargetModel {
    fn vocab_size(&self) -> usize;
    fn eos(&self) -> TokenId;

    /// Run one batched forward pass. Implementations must charge their
    /// clock: `c_base + c_tok · Σ(draft_i + 1)` for the simulator, real
    /// wall time for PJRT.
    fn forward(&mut self, batch: &[StepInput], temperature: f64) -> Vec<StepOutput>;

    /// Cumulative generation-time clock in seconds (virtual for the
    /// simulator, wall for PJRT).
    fn elapsed(&self) -> f64;

    /// Reset the clock (per training step timing).
    fn reset_clock(&mut self);

    /// The fitted/configured latency model (drives the budget optimizer).
    fn latency_model(&self) -> LatencyModel;

    /// Total forward passes executed (N_fwd across the run).
    fn forward_passes(&self) -> u64;
}

#[cfg(test)]
mod tests {
    // Trait-level behavior is exercised through the sim backend tests and
    // the rollout engine integration tests.
}

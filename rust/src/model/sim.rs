//! Synthetic drifting-policy model with a calibrated virtual clock.
//!
//! The paper's evaluation hardware (6× 8-H100 nodes, 7–8B policies, 16k
//! contexts) is substituted per DESIGN.md §3 by a simulator that reproduces
//! the three workload properties DAS exploits, while charging time through
//! the same `t_fwd = c_base + c_tok·n` model the paper itself fits (Eq. 1):
//!
//! * **Insight-1 (long tail):** each problem has a *canonical trajectory*
//!   whose length is log-normal across problems — a few problems are much
//!   longer than the median and dominate step makespan.
//! * **Insight-2 (reuse):** the policy's next-token distribution places
//!   most of its mass on the canonical trajectory, so rollouts of the same
//!   problem repeat across epochs.
//! * **Insight-3 (drift):** each learner update mutates a `drift` fraction
//!   of every canonical trajectory and increases policy *sharpness* (the
//!   mass on the canonical continuation), modeling a policy that both
//!   changes and improves — old rollouts decay in predictive value while
//!   rewards rise.
//!
//! The distribution is an explicit dense categorical per position, so exact
//! speculative verification applies unchanged and "lossless" is testable.

use super::{StepInput, StepOutput, TargetModel};
use crate::cost::LatencyModel;
use crate::tokens::{ProblemId, TokenId};
use crate::util::rng::{splitmix64, Rng};

/// Per-problem synthetic task state.
#[derive(Debug, Clone)]
pub struct SimProblem {
    /// Canonical trajectory the current policy is converging to. Mutates on
    /// policy updates (drift) — the answer suffix is kept stable so reward
    /// improvement is learnable.
    pub canonical: Vec<TokenId>,
    /// Tokens at the end of `canonical` that constitute the verifiable
    /// answer (kept fixed under drift).
    pub answer_len: usize,
    /// Problem difficulty in (0,1]: harder problems sharpen more slowly.
    pub difficulty: f64,
    /// When set, drift may only mutate positions with `mutable[i] == true`
    /// and resamples them inside `drift_range` — used by the code workload,
    /// where filler (no-op) tokens drift lexically while the program's
    /// semantics (and thus unit-test rewards) stay intact.
    pub mutable: Option<Vec<bool>>,
    pub drift_range: (TokenId, TokenId),
}

#[derive(Debug, Clone)]
pub struct SimModelConfig {
    pub vocab_size: usize,
    pub n_problems: usize,
    /// Log-normal parameters of canonical-trajectory length.
    pub len_mu: f64,
    pub len_sigma: f64,
    pub max_len: usize,
    /// Fraction of canonical tokens re-sampled per policy update.
    pub drift: f64,
    /// Sharpness schedule: mass on the canonical token is
    /// `s0 + (s1 − s0) · (1 − exp(−updates / tau / difficulty))`.
    pub sharpness0: f64,
    pub sharpness1: f64,
    pub sharpness_tau: f64,
    pub cost: LatencyModel,
    pub seed: u64,
}

impl Default for SimModelConfig {
    fn default() -> Self {
        SimModelConfig {
            vocab_size: 512,
            n_problems: 64,
            len_mu: 6.0,
            len_sigma: 0.75,
            max_len: 2048,
            drift: 0.08,
            sharpness0: 0.45,
            sharpness1: 0.99,
            sharpness_tau: 4.0,
            cost: LatencyModel::paper_like(),
            seed: 17,
        }
    }
}

impl SimModelConfig {
    pub fn from_das(cfg: &crate::config::DasConfig) -> Self {
        SimModelConfig {
            vocab_size: cfg.model.vocab_size,
            n_problems: cfg.workload.n_problems,
            len_mu: cfg.workload.len_mu,
            len_sigma: cfg.workload.len_sigma,
            max_len: cfg.rollout.max_new_tokens,
            drift: cfg.workload.drift,
            seed: cfg.seed,
            ..SimModelConfig::default()
        }
    }
}

pub struct SimModel {
    cfg: SimModelConfig,
    problems: Vec<SimProblem>,
    /// Learner updates applied so far (drives sharpness + drift).
    pub updates: u64,
    /// Version counter for the distractor hash (bumped on each drift so
    /// noise patterns also evolve slowly).
    version: u64,
    clock: f64,
    n_fwd: u64,
    rng: Rng,
    /// Number of distractor continuations sharing the non-canonical mass.
    n_distractors: usize,
    /// Reserved: EOS = vocab-1 (never appears inside canonical bodies).
    eos: TokenId,
}

impl SimModel {
    pub fn new(cfg: SimModelConfig) -> Self {
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x51D0_CAFE);
        let eos = (cfg.vocab_size - 1) as TokenId;
        let usable_vocab = (cfg.vocab_size - 1) as u32;
        let mut problems = Vec::with_capacity(cfg.n_problems);
        for _ in 0..cfg.n_problems {
            let len = (rng.lognormal(cfg.len_mu, cfg.len_sigma) as usize)
                .clamp(8, cfg.max_len.saturating_sub(2).max(8));
            let canonical: Vec<TokenId> =
                (0..len).map(|_| rng.below(usable_vocab as usize) as u32).collect();
            let answer_len = 4.min(len / 2).max(1);
            let difficulty = 0.3 + 0.7 * rng.next_f64();
            problems.push(SimProblem {
                canonical,
                answer_len,
                difficulty,
                mutable: None,
                drift_range: (0, usable_vocab),
            });
        }
        SimModel {
            cfg,
            problems,
            updates: 0,
            version: 0,
            clock: 0.0,
            n_fwd: 0,
            rng,
            n_distractors: 6,
            eos,
        }
    }

    pub fn problems(&self) -> &[SimProblem] {
        &self.problems
    }

    pub fn config(&self) -> &SimModelConfig {
        &self.cfg
    }

    /// The answer tokens currently considered correct for a problem.
    pub fn answer(&self, problem: ProblemId) -> &[TokenId] {
        let p = &self.problems[problem as usize % self.problems.len()];
        &p.canonical[p.canonical.len() - p.answer_len..]
    }

    /// Current sharpness (mass on the canonical continuation) for a problem.
    pub fn sharpness(&self, problem: ProblemId) -> f64 {
        let p = &self.problems[problem as usize % self.problems.len()];
        let t = self.updates as f64 / (self.cfg.sharpness_tau * p.difficulty.max(0.05));
        self.cfg.sharpness0 + (self.cfg.sharpness1 - self.cfg.sharpness0) * (1.0 - (-t).exp())
    }

    /// Replace a problem's canonical trajectory (used by workloads whose
    /// canonical is semantically constrained, e.g. correct VM programs).
    /// `mutable` marks drift-eligible positions; drifted tokens are drawn
    /// from `drift_range`.
    pub fn set_canonical(
        &mut self,
        problem: ProblemId,
        canonical: Vec<TokenId>,
        answer_len: usize,
        mutable: Option<Vec<bool>>,
        drift_range: (TokenId, TokenId),
    ) {
        let n = self.problems.len();
        let p = &mut self.problems[problem as usize % n];
        if let Some(m) = &mutable {
            assert_eq!(m.len(), canonical.len(), "mask/canonical length mismatch");
        }
        p.canonical = canonical;
        p.answer_len = answer_len.max(1);
        p.mutable = mutable;
        p.drift_range = drift_range;
    }

    /// Apply one learner update: sharpen + drift canonical trajectories.
    /// `gain` scales drift (1.0 = configured value); the trainer ties it to
    /// its optimizer step scale, realizing §4.1.2's "window update rate tied
    /// to the optimizer's step scale".
    pub fn policy_update(&mut self, gain: f64) {
        self.updates += 1;
        self.version += 1;
        let drift = (self.cfg.drift * gain).clamp(0.0, 1.0);
        for p in &mut self.problems {
            let (lo, hi) = p.drift_range;
            let span = (hi.saturating_sub(lo)).max(1) as usize;
            let body = p.canonical.len() - p.answer_len;
            for i in 0..body {
                let eligible = p.mutable.as_ref().map(|m| m[i]).unwrap_or(true);
                if eligible && self.rng.chance(drift) {
                    p.canonical[i] = lo + self.rng.below(span) as u32;
                }
            }
        }
    }

    /// Deterministic distractor token for (problem, position, slot).
    fn distractor(&self, problem: usize, pos: usize, slot: usize) -> TokenId {
        let mut h = (problem as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((pos as u64) << 20)
            .wrapping_add(slot as u64)
            .wrapping_add(self.version / 4); // distractors shift slowly
        (splitmix64(&mut h) % (self.cfg.vocab_size as u64 - 1)) as TokenId
    }

    /// Dense next-token distribution for a problem at generated-position
    /// `pos` (0-based over the generation, prompts carry no extra state).
    ///
    /// NOTE on temperature: the simulator defines the *sampling*
    /// distribution directly; `temperature` rescales it through a softmax of
    /// its log (T=1 identity), keeping the greedy argmax canonical.
    fn next_dist(&self, problem: ProblemId, pos: usize, temperature: f64) -> Vec<f32> {
        let pi = problem as usize % self.problems.len();
        let p = &self.problems[pi];
        let v = self.cfg.vocab_size;
        let mut dist = vec![0f32; v];
        if pos >= p.canonical.len() {
            // Past the canonical end: overwhelmingly EOS.
            dist[self.eos as usize] = 0.98;
            let spread = 0.02 / (v - 1) as f32;
            for (i, d) in dist.iter_mut().enumerate() {
                if i != self.eos as usize {
                    *d = spread;
                }
            }
            return dist;
        }
        let s = self.sharpness(problem) as f32;
        // EOS hazard: (i) length-relative, so long trajectories aren't
        // disproportionately truncated; (ii) shrinking as the policy
        // sharpens — an under-trained policy stops rambling early, a trained
        // one completes its derivation; (iii) ramping near the canonical
        // end. This is what makes sampled lengths disperse around the
        // canonical length (Fig. 9) while keeping rewards learnable.
        let len = p.canonical.len() as f32;
        let frac = pos as f32 / len;
        let base_hazard = (1.4 * (1.0 - s) / len).clamp(0.0003, 0.05);
        let eos_p = if frac > 0.85 {
            base_hazard + 0.03 + 0.25 * (frac - 0.85) / 0.15
        } else {
            base_hazard
        };
        let canonical_tok = p.canonical[pos] as usize;
        let noise_mass = (1.0 - s) * (1.0 - eos_p);
        let canon_mass = s * (1.0 - eos_p);
        dist[self.eos as usize] += eos_p;
        dist[canonical_tok] += canon_mass;
        // Distractors: plausible alternative continuations (what a policy
        // with entropy actually does — it doesn't spread uniformly).
        let per = noise_mass * 0.85 / self.n_distractors as f32;
        for slot in 0..self.n_distractors {
            let tok = self.distractor(pi, pos, slot) as usize;
            dist[tok] += per;
        }
        // Thin uniform floor so the support is full (residual sampling
        // always has somewhere to go).
        let floor = noise_mass * 0.15 / v as f32;
        for d in dist.iter_mut() {
            *d += floor;
        }
        if (temperature - 1.0).abs() > 1e-9 {
            let logits: Vec<f32> = dist.iter().map(|&x| (x.max(1e-20)).ln()).collect();
            return crate::spec::verify::softmax_with_temperature(&logits, temperature);
        }
        // Normalize (mass bookkeeping above is approximate).
        let sum: f32 = dist.iter().sum();
        for d in dist.iter_mut() {
            *d /= sum;
        }
        dist
    }
}

impl TargetModel for SimModel {
    fn vocab_size(&self) -> usize {
        self.cfg.vocab_size
    }

    fn eos(&self) -> TokenId {
        self.eos
    }

    fn forward(&mut self, batch: &[StepInput], temperature: f64) -> Vec<StepOutput> {
        let mut outs = Vec::with_capacity(batch.len());
        let mut toks_processed = 0usize;
        for el in batch {
            let gen_len = el.context.len() - el.prompt_len;
            let k = el.draft.len();
            toks_processed += k + 1;
            let mut dists = Vec::with_capacity(k + 1);
            for t in 0..=k {
                dists.push(self.next_dist(el.problem, gen_len + t, temperature));
            }
            outs.push(dists);
        }
        self.n_fwd += 1;
        self.clock += self.cfg.cost.t_fwd(toks_processed);
        outs
    }

    fn elapsed(&self) -> f64 {
        self.clock
    }

    fn reset_clock(&mut self) {
        self.clock = 0.0;
    }

    fn latency_model(&self) -> LatencyModel {
        self.cfg.cost
    }

    fn forward_passes(&self) -> u64 {
        self.n_fwd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> SimModel {
        SimModel::new(SimModelConfig {
            vocab_size: 64,
            n_problems: 8,
            len_mu: 3.5,
            len_sigma: 0.5,
            max_len: 256,
            ..SimModelConfig::default()
        })
    }

    #[test]
    fn distributions_normalized() {
        let m = model();
        for pos in [0usize, 5, 50, 10_000] {
            let d = m.next_dist(3, pos, 1.0);
            let s: f32 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "sum={s} at pos={pos}");
            assert!(d.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn past_canonical_end_is_eos() {
        let m = model();
        let d = m.next_dist(0, 100_000, 1.0);
        assert!(d[m.eos() as usize] > 0.9);
    }

    #[test]
    fn sharpness_increases_with_updates() {
        let mut m = model();
        let s0 = m.sharpness(1);
        for _ in 0..50 {
            m.policy_update(1.0);
        }
        let s1 = m.sharpness(1);
        assert!(s1 > s0 + 0.2, "s0={s0} s1={s1}");
        assert!(s1 <= m.cfg.sharpness1 + 1e-9);
    }

    #[test]
    fn drift_mutates_body_not_answer() {
        let mut m = model();
        let before = m.problems()[2].canonical.clone();
        let ans_before = m.answer(2).to_vec();
        for _ in 0..20 {
            m.policy_update(1.0);
        }
        let after = &m.problems()[2].canonical;
        let ans_after = m.answer(2);
        assert_eq!(ans_before, ans_after, "answer must be drift-stable");
        let changed = before
            .iter()
            .zip(after.iter())
            .filter(|(a, b)| a != b)
            .count();
        assert!(changed > 0, "drift should mutate the body");
    }

    #[test]
    fn forward_charges_linear_cost() {
        let mut m = model();
        let ctx = [1u32, 2, 3];
        let draft = [4u32, 5];
        let inp = StepInput {
            request: 1,
            problem: 0,
            context: &ctx,
            prompt_len: 3,
            draft: &draft,
        };
        let before = m.elapsed();
        let outs = m.forward(&[inp], 1.0);
        let dt = m.elapsed() - before;
        let expect = m.latency_model().t_fwd(3); // draft(2) + 1
        assert!((dt - expect).abs() < 1e-12);
        assert_eq!(outs[0].len(), 3);
        assert_eq!(m.forward_passes(), 1);
    }

    #[test]
    fn lengths_are_long_tailed() {
        let m = SimModel::new(SimModelConfig {
            n_problems: 512,
            ..SimModelConfig::default()
        });
        let lens: Vec<f64> = m.problems().iter().map(|p| p.canonical.len() as f64).collect();
        let mean = crate::util::stats::mean(&lens);
        let p99 = crate::util::stats::percentile(&lens, 99.0);
        assert!(
            p99 > 2.5 * mean,
            "long tail expected: mean={mean:.0} p99={p99:.0}"
        );
    }

    #[test]
    fn greedy_path_is_canonical() {
        // With sharpness dominant the argmax at each position is the
        // canonical token — the policy "wants" to emit its trajectory.
        let mut m = model();
        for _ in 0..100 {
            m.policy_update(1.0);
        }
        let p = m.problems()[1].clone();
        for pos in 0..p.canonical.len().min(20) {
            let d = m.next_dist(1, pos, 1.0);
            let argmax = crate::spec::verify::greedy_token(&d);
            assert_eq!(argmax, p.canonical[pos], "pos={pos}");
        }
    }

    #[test]
    fn temperature_flattens() {
        let m = model();
        let d1 = m.next_dist(0, 0, 1.0);
        let d2 = m.next_dist(0, 0, 4.0);
        let max1 = d1.iter().cloned().fold(0f32, f32::max);
        let max2 = d2.iter().cloned().fold(0f32, f32::max);
        assert!(max2 < max1);
    }
}

//! Figure reproduction harness — one driver per figure in the paper's
//! evaluation (see DESIGN.md §4 for the index).
//!
//! Every driver returns one or more [`Table`]s: printed to stdout and
//! written as CSV under `results/`. Run via `das figures --fig N` or
//! `das figures --all`.

use crate::telemetry::Table;

mod common;
pub mod fig01;
pub mod fig02;
pub mod fig04;
pub mod fig05;
pub mod fig06;
pub mod fig07;
#[cfg(feature = "pjrt")]
pub mod fig08;
pub mod fig09;
pub mod fig10;
pub mod fig12;
pub mod fig13;

/// Common options for figure drivers (scaled-down defaults keep every
/// figure under a couple of minutes; `--full` uses paper-scale settings).
#[derive(Debug, Clone)]
pub struct FigOpts {
    pub seed: u64,
    pub full: bool,
    pub out_dir: std::path::PathBuf,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            seed: 17,
            full: false,
            out_dir: std::path::PathBuf::from("results"),
        }
    }
}

pub struct FigureOutput {
    pub tables: Vec<Table>,
    /// One-line summary of the reproduced claim vs the paper's.
    pub summary: String,
}

/// Which figure ids exist (11 reuses the fig10 driver with the code preset;
/// 3 is the system diagram — nothing to run).
pub fn known_figures() -> &'static [u32] {
    &[1, 2, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13]
}

pub fn run(fig: u32, opts: &FigOpts) -> anyhow::Result<FigureOutput> {
    match fig {
        1 => Ok(fig01::run(opts)),
        2 => Ok(fig02::run(opts)),
        4 => Ok(fig04::run(opts)),
        5 => Ok(fig05::run(opts)),
        6 => Ok(fig06::run(opts)),
        7 => Ok(fig07::run(opts)),
        #[cfg(feature = "pjrt")]
        8 => fig08::run(opts),
        #[cfg(not(feature = "pjrt"))]
        8 => anyhow::bail!("figure 8 runs the real PJRT model; rebuild with the pjrt feature"),
        9 => Ok(fig09::run(opts)),
        10 => Ok(fig10::run(opts, "math_rl", "fig10")),
        11 => Ok(fig10::run(opts, "code_rl", "fig11")),
        12 => Ok(fig12::run(opts)),
        13 => Ok(fig13::run(opts)),
        other => anyhow::bail!(
            "unknown figure {other}; available: {:?} (3 is the system diagram)",
            known_figures()
        ),
    }
}

/// Emit the output: print tables, write CSVs, print the summary.
pub fn emit(out: &FigureOutput, opts: &FigOpts) -> anyhow::Result<()> {
    for t in &out.tables {
        t.print();
        let path = t.write_csv(&opts.out_dir)?;
        println!("→ {}", path.display());
    }
    println!("\n{}", out.summary);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_figure_rejected() {
        assert!(run(99, &FigOpts::default()).is_err());
        assert!(run(3, &FigOpts::default()).is_err());
    }
}

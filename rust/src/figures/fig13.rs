//! Fig. 13 — robustness along sequence length and batch size.
//!
//! Paper (Qwen3-8B code): halving the max decode length (16k→8k) still
//! yields >30% rollout speedup; halving the effective batch (32→16)
//! preserves a similar fractional speedup — the benefit doesn't depend on a
//! particular batching regime.

use super::common::{scaled_config, sim_trainer, steps_for, total_gen_time};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

pub fn run(opts: &FigOpts) -> FigureOutput {
    let steps = steps_for(opts, 12, 30);
    // (label, max_new_tokens scale, batch scale)
    let axes: [(&str, f64, f64); 3] = [
        ("default", 1.0, 1.0),
        ("half_seq_len", 0.5, 1.0),
        ("half_batch", 1.0, 0.5),
    ];
    let mut rows = Vec::new();
    for (label, len_scale, batch_scale) in &axes {
        let mut speedups = Vec::new();
        let mut times = (0.0, 0.0);
        for drafter in ["none", "das"] {
            let mut cfg = scaled_config("code_rl", opts);
            cfg.spec.drafter = drafter.into();
            cfg.rollout.max_new_tokens =
                ((cfg.rollout.max_new_tokens as f64 * len_scale) as usize).max(32);
            cfg.rollout.max_batch = ((cfg.rollout.max_batch as f64 * batch_scale) as usize).max(2);
            // Shrink canonical lengths along with the cap so the workload
            // stays length-limited the same way the paper's 8k run is.
            if *len_scale < 1.0 {
                cfg.workload.len_mu += len_scale.ln();
            }
            let (mut model, mut trainer) = sim_trainer(&cfg);
            let stats = trainer.run_sim(&mut model, steps);
            let t = total_gen_time(&stats[1..]);
            if drafter == "none" {
                times.0 = t;
            } else {
                times.1 = t;
            }
        }
        let speedup = 100.0 * (1.0 - times.1 / times.0);
        speedups.push(speedup);
        rows.push((label.to_string(), times.0, times.1, speedup));
    }
    let mut t = Table::new(
        "fig13_robustness",
        &["variant", "baseline_s", "das_s", "reduction_pct"],
    );
    for (label, b, d, s) in &rows {
        t.row(vec![
            label.clone(),
            format!("{b:.3}"),
            format!("{d:.3}"),
            format!("{s:.1}"),
        ]);
    }
    let summary = format!(
        "Fig.13: rollout-time reduction — default {:.0}%, half-seq-len \
         {:.0}%, half-batch {:.0}% (paper: >30% at 8k, similar fractional \
         savings at batch 16 — the speedup is regime-robust).",
        rows[0].3, rows[1].3, rows[2].3
    );
    FigureOutput {
        tables: vec![t],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedup_survives_both_axes() {
        let out = run(&FigOpts::default());
        for row in &out.tables[0].rows {
            let red: f64 = row[3].parse().unwrap();
            assert!(
                red > 10.0,
                "variant {} lost the speedup: {red:.1}%",
                row[0]
            );
        }
    }
}

//! Fig. 4 — adaptive nonparametric drafter vs frozen parametric drafter.
//!
//! Paper: EAGLE's acceptance stays roughly flat during RL training while
//! the suffix-tree drafter's accepted-tokens-per-round keeps climbing,
//! because it is refreshed from recent rollouts.

use super::common::{scaled_config, sim_trainer, steps_for};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

pub fn run(opts: &FigOpts) -> FigureOutput {
    let steps = steps_for(opts, 16, 30);
    let mut series = Vec::new();
    for drafter in ["das", "static"] {
        let mut cfg = scaled_config("math_rl", opts);
        cfg.spec.drafter = drafter.into();
        cfg.spec.budget_policy = "uniform".into(); // isolate the drafter axis
        let (mut model, mut trainer) = sim_trainer(&cfg);
        let stats = trainer.run_sim(&mut model, steps);
        series.push(
            stats
                .iter()
                .map(|s| s.metrics.accepted_per_round())
                .collect::<Vec<_>>(),
        );
    }
    let mut table = Table::new(
        "fig04_accepted_per_round",
        &["step", "das_adaptive", "static_frozen"],
    );
    for i in 0..steps {
        table.row_f(&[i as f64, series[0][i], series[1][i]]);
    }
    let late = |xs: &[f64]| {
        let k = (xs.len() / 4).max(1);
        crate::util::stats::mean(&xs[xs.len() - k..])
    };
    let summary = format!(
        "Fig.4: accepted tokens/round at end of training — adaptive {:.2} vs \
         frozen {:.2} ({}x). Paper: EAGLE stays flat while the adaptive \
         drafter keeps improving; the adaptive curve must rise and dominate.",
        late(&series[0]),
        late(&series[1]),
        (late(&series[0]) / late(&series[1]).max(1e-9)) as u32
    );
    FigureOutput {
        tables: vec![table],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_dominates_frozen_late_in_training() {
        let out = run(&FigOpts::default());
        let t = &out.tables[0];
        let das_late: f64 = t.rows[t.rows.len() - 3..]
            .iter()
            .map(|r| r[1].parse::<f64>().unwrap())
            .sum::<f64>()
            / 3.0;
        let stat_late: f64 = t.rows[t.rows.len() - 3..]
            .iter()
            .map(|r| r[2].parse::<f64>().unwrap())
            .sum::<f64>()
            / 3.0;
        assert!(
            das_late > stat_late * 1.5,
            "adaptive should dominate: das={das_late:.3} static={stat_late:.3}"
        );
        // And the adaptive curve rises from its start.
        let das_early: f64 = t.rows[1][1].parse().unwrap();
        assert!(das_late > das_early);
    }
}

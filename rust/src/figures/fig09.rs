//! Fig. 9 — per-problem generation-length dispersion.
//!
//! Each point: a problem's MEAN generated length across epochs (x) vs its
//! MAX (y). Wide spread + high upper envelope ⇒ direct length prediction is
//! hopeless ⇒ the hierarchical class heuristic of §4.2.3.

use std::collections::HashMap;

use super::common::{scaled_config, sim_trainer, steps_for};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

pub fn run(opts: &FigOpts) -> FigureOutput {
    let mut cfg = scaled_config("math_rl", opts);
    cfg.workload.n_problems = if opts.full { 64 } else { 24 };
    cfg.train.problems_per_step = 8;
    // Dispersion comes from sampling the EOS hazard: T = 1.0 keeps the
    // simulator's hazard un-sharpened (T < 1 suppresses rare events and
    // would artificially tighten the scatter).
    cfg.rollout.temperature = 1.0;
    let steps = steps_for(opts, 18, 90);
    let (mut model, mut trainer) = sim_trainer(&cfg);
    trainer.run_sim(&mut model, steps);

    let mut lens: HashMap<u32, Vec<f64>> = HashMap::new();
    for &e in trainer.history.epochs() {
        for p in 0..cfg.workload.n_problems as u32 {
            for r in trainer.history.rollouts(p, e) {
                lens.entry(p).or_default().push(r.len() as f64);
            }
        }
    }
    let mut table = Table::new("fig09_len_dispersion", &["problem", "mean_len", "max_len"]);
    let mut ratios = Vec::new();
    let mut problems: Vec<_> = lens.keys().copied().collect();
    problems.sort_unstable();
    for p in problems {
        let v = &lens[&p];
        let mean = crate::util::stats::mean(v);
        let max = v.iter().cloned().fold(0.0, f64::max);
        ratios.push(max / mean.max(1.0));
        table.row_f(&[p as f64, mean, max]);
    }
    let mean_ratio = crate::util::stats::mean(&ratios);
    let max_ratio = ratios.iter().cloned().fold(0.0, f64::max);
    let summary = format!(
        "Fig.9: max/mean generated-length ratio per problem averages \
         {mean_ratio:.2} (worst {max_ratio:.2}) — lengths are highly \
         dispersed, as in the paper's 90-epoch scatter; point predictions \
         of length are unreliable, motivating the Long/Medium/Short classes."
    );
    FigureOutput {
        tables: vec![table],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_are_dispersed() {
        let out = run(&FigOpts::default());
        let t = &out.tables[0];
        assert!(t.rows.len() >= 20);
        let mut any_dispersed = 0;
        for r in &t.rows {
            let mean: f64 = r[1].parse().unwrap();
            let max: f64 = r[2].parse().unwrap();
            assert!(max >= mean);
            if max > 1.12 * mean {
                any_dispersed += 1;
            }
        }
        assert!(
            any_dispersed * 2 >= t.rows.len(),
            "most problems should show dispersion ({any_dispersed}/{})",
            t.rows.len()
        );
    }
}

//! Shared helpers for figure drivers.

use super::FigOpts;
use crate::config::{preset, DasConfig};
use crate::model::sim::{SimModel, SimModelConfig};
use crate::rl::{StepStats, Trainer};

/// Scale a preset down so figures regenerate in seconds by default;
/// `--full` keeps the preset's paper-scale settings.
pub fn scaled_config(preset_name: &str, opts: &FigOpts) -> DasConfig {
    let mut cfg = preset(preset_name).expect("known preset");
    cfg.seed = opts.seed;
    if !opts.full {
        cfg.workload.n_problems = cfg.workload.n_problems.min(24);
        cfg.train.problems_per_step = cfg.train.problems_per_step.min(8);
        cfg.rollout.samples_per_problem = cfg.rollout.samples_per_problem.min(4);
        cfg.rollout.max_new_tokens = cfg.rollout.max_new_tokens.min(512);
        cfg.rollout.max_batch = cfg.rollout.max_batch.min(16);
        cfg.workload.len_mu = cfg.workload.len_mu.min(5.0);
    }
    cfg
}

pub fn steps_for(opts: &FigOpts, default_steps: usize, full_steps: usize) -> usize {
    if opts.full {
        full_steps
    } else {
        default_steps
    }
}

/// Build a sim model + trainer for a config.
pub fn sim_trainer(cfg: &DasConfig) -> (SimModel, Trainer) {
    let model = SimModel::new(SimModelConfig::from_das(cfg));
    let trainer = Trainer::new(cfg.clone());
    (model, trainer)
}

/// Run a full sim training and return the per-step stats.
pub fn run_variant(cfg: &DasConfig, steps: usize) -> Vec<StepStats> {
    let (mut model, mut trainer) = sim_trainer(cfg);
    trainer.run_sim(&mut model, steps)
}

pub fn total_gen_time(stats: &[StepStats]) -> f64 {
    stats.iter().map(|s| s.metrics.gen_time).sum()
}

pub fn mean_late_reward(stats: &[StepStats]) -> f64 {
    let k = (stats.len() / 4).max(1);
    let tail = &stats[stats.len() - k..];
    crate::util::stats::mean(&tail.iter().map(|s| s.reward).collect::<Vec<_>>())
}

//! Fig. 7 — sliding-window size ablation (4 / 8 / 16 / 32 / all).
//!
//! Paper: larger windows give higher acceptance (more matching
//! continuations) but `window_all` pays the highest per-token speculation
//! latency (querying and maintaining the full history, including stale
//! trajectories); moderate windows (16/32) strike the balance.

use super::common::{scaled_config, sim_trainer, steps_for};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

const WINDOWS: [usize; 5] = [4, 8, 16, 32, 0]; // 0 = all

pub fn run(opts: &FigOpts) -> FigureOutput {
    let steps = steps_for(opts, 14, 40);
    let mut accept = vec![Vec::new(); WINDOWS.len()];
    let mut lat = vec![Vec::new(); WINDOWS.len()];
    for (i, &w) in WINDOWS.iter().enumerate() {
        let mut cfg = scaled_config("math_rl", opts);
        cfg.spec.window = w;
        cfg.spec.budget_policy = "uniform".into();
        // Epochs advance quickly so windows differentiate: few problems.
        cfg.workload.n_problems = 8;
        cfg.train.problems_per_step = 8;
        let (mut model, mut trainer) = sim_trainer(&cfg);
        for s in trainer.run_sim(&mut model, steps) {
            accept[i].push(s.metrics.accepted_per_round());
            lat[i].push(s.metrics.draft_ms_per_token());
        }
    }
    let names = ["w4", "w8", "w16", "w32", "all"];
    let mut t_acc = Table::new(
        "fig07_accept_by_window",
        &["step", "w4", "w8", "w16", "w32", "all"],
    );
    let mut t_lat = Table::new(
        "fig07_latency_by_window",
        &["step", "w4_ms", "w8_ms", "w16_ms", "w32_ms", "all_ms"],
    );
    for s in 0..steps {
        t_acc.row_f(&[
            s as f64, accept[0][s], accept[1][s], accept[2][s], accept[3][s], accept[4][s],
        ]);
        t_lat.row_f(&[s as f64, lat[0][s], lat[1][s], lat[2][s], lat[3][s], lat[4][s]]);
    }
    let late = |xs: &[f64]| {
        let k = (xs.len() / 3).max(1);
        crate::util::stats::mean(&xs[xs.len() - k..])
    };
    let mut parts = Vec::new();
    for (i, n) in names.iter().enumerate() {
        parts.push(format!("{n}: {:.2} acc / {:.4} ms", late(&accept[i]), late(&lat[i])));
    }
    let summary = format!(
        "Fig.7: {} — larger windows raise acceptance; window_all pays the \
         highest query latency (paper: moderate windows 16/32 balance best).",
        parts.join("; ")
    );
    FigureOutput {
        tables: vec![t_acc, t_lat],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_tradeoff_reproduced() {
        let out = run(&FigOpts::default());
        let acc = &out.tables[0];
        let lat = &out.tables[1];
        let late = |t: &crate::telemetry::Table, col: usize| -> f64 {
            let k = (t.rows.len() / 3).max(1);
            t.rows[t.rows.len() - k..]
                .iter()
                .map(|r| r[col].parse::<f64>().unwrap())
                .sum::<f64>()
                / k as f64
        };
        // Acceptance: all/32 >= 4 (more history = more matches).
        assert!(
            late(acc, 5).max(late(acc, 4)) >= late(acc, 1) * 0.95,
            "large windows should not lose acceptance: w4={} w32={} all={}",
            late(acc, 1),
            late(acc, 4),
            late(acc, 5)
        );
        // Latency: window_all must cost at least as much as w4.
        assert!(
            late(lat, 5) >= late(lat, 1) * 0.8,
            "all={} w4={}",
            late(lat, 5),
            late(lat, 1)
        );
    }
}

//! Fig. 1 — effective batch size collapse during rollout, w/ and w/o DAS.
//!
//! Paper: decoding starts at full parallelism; short sequences finish and
//! the effective batch shrinks until a few long stragglers set the step
//! makespan. DAS both shortens total latency and shrinks the tail.

use super::common::{run_variant, scaled_config, sim_trainer, steps_for};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

pub fn run(opts: &FigOpts) -> FigureOutput {
    let warmup = steps_for(opts, 4, 8);
    let mut variants = Vec::new();
    for drafter in ["none", "das"] {
        let mut cfg = scaled_config("math_rl", opts);
        cfg.spec.drafter = drafter.into();
        // Warm the drafter/history, then profile ONE representative step.
        let (mut model, mut trainer) = sim_trainer(&cfg);
        let mut stats = trainer.run_sim(&mut model, warmup + 1);
        let last = stats.pop().unwrap();
        variants.push((drafter, last));
    }

    let mut table = Table::new("fig01_effective_batch", &["round", "none", "das"]);
    let a = &variants[0].1.metrics.eff_batch;
    let b = &variants[1].1.metrics.eff_batch;
    for i in 0..a.len().max(b.len()) {
        table.row(vec![
            i.to_string(),
            a.get(i).map(|v| v.to_string()).unwrap_or_default(),
            b.get(i).map(|v| v.to_string()).unwrap_or_default(),
        ]);
    }
    let makespan_none = variants[0].1.metrics.gen_time;
    let makespan_das = variants[1].1.metrics.gen_time;
    let rounds_none = variants[0].1.metrics.rounds;
    let rounds_das = variants[1].1.metrics.rounds;
    // Tail fraction: rounds spent at effective batch <= 25% of max.
    let tail = |t: &[u32]| -> f64 {
        if t.is_empty() {
            return 0.0;
        }
        let max = *t.iter().max().unwrap() as f64;
        t.iter().filter(|&&v| (v as f64) <= 0.25 * max).count() as f64 / t.len() as f64
    };
    let summary = format!(
        "Fig.1: decode rounds none={rounds_none} das={rounds_das} \
         (makespan {:.2}s -> {:.2}s, {:.0}% less); rounds in the collapsed \
         tail (eff.batch <= 25% of peak): none={:.0}% das={:.0}%. Paper: a few \
         long stragglers dominate after ~100 steps; DAS shrinks the tail.",
        makespan_none,
        makespan_das,
        100.0 * (1.0 - makespan_das / makespan_none),
        100.0 * tail(a),
        100.0 * tail(b),
    );
    let _ = run_variant; // (re-exported helper used by other figures)
    FigureOutput {
        tables: vec![table],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collapse_and_das_improvement() {
        let out = run(&FigOpts::default());
        let t = &out.tables[0];
        assert!(t.rows.len() > 10);
        // Baseline trace starts at max batch and ends at 1.
        let first: u32 = t.rows.first().unwrap()[1].parse().unwrap();
        let last: u32 = t.rows.last().unwrap()[1].parse::<u32>().unwrap_or_else(|_| {
            // das column may be longer; find last non-empty baseline value
            t.rows
                .iter()
                .rev()
                .find_map(|r| r[1].parse().ok())
                .unwrap()
        });
        assert!(first >= 8);
        assert!(last <= 2);
        // DAS uses fewer rounds than baseline.
        let das_rounds = t.rows.iter().filter(|r| !r[2].is_empty()).count();
        let none_rounds = t.rows.iter().filter(|r| !r[1].is_empty()).count();
        assert!(das_rounds < none_rounds, "das={das_rounds} none={none_rounds}");
    }
}

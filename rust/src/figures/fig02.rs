//! Fig. 2 — rollout similarity structure across training.
//!
//! Left: per-iteration n-gram reuse ratio. Right: pairwise epoch similarity
//! matrix — block structure near the diagonal (recency bias from policy
//! drift) is what justifies sliding-window drafters.

use super::common::{scaled_config, sim_trainer, steps_for};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

const NGRAM: usize = 4;

pub fn run(opts: &FigOpts) -> FigureOutput {
    let mut cfg = scaled_config("math_rl", opts);
    // Several epochs of history: few problems per step, more steps.
    cfg.workload.n_problems = 8;
    cfg.train.problems_per_step = 8;
    let steps = steps_for(opts, 10, 30);
    let (mut model, mut trainer) = sim_trainer(&cfg);
    trainer.run_sim(&mut model, steps);

    let reuse = trainer.history.reuse_per_iteration(NGRAM);
    let mut left = Table::new("fig02_reuse_per_iteration", &["epoch", "reuse_ratio"]);
    for (e, r) in &reuse {
        left.row_f(&[*e as f64, *r]);
    }

    let m = trainer.history.epoch_similarity_matrix(NGRAM);
    let epochs = trainer.history.epochs().to_vec();
    let mut cols = vec!["epoch".to_string()];
    cols.extend(epochs.iter().map(|e| format!("e{e}")));
    let mut right = Table::new(
        "fig02_epoch_similarity",
        &cols.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (i, e) in epochs.iter().enumerate() {
        let mut row = vec![e.to_string()];
        row.extend(m[i].iter().map(|v| format!("{v:.4}")));
        right.row(row);
    }

    // Quantify the block-diagonal claim: adjacent-epoch similarity vs
    // most-distant-pair similarity.
    let n = m.len();
    let adjacent: Vec<f64> = (1..n).map(|i| m[i - 1][i]).collect();
    let adj = crate::util::stats::mean(&adjacent);
    let far = if n >= 2 { m[0][n - 1] } else { 0.0 };
    let reuse_last = reuse.last().map(|(_, r)| *r).unwrap_or(0.0);
    let summary = format!(
        "Fig.2: n-gram reuse vs previous iteration reaches {:.2} by the last \
         epoch (paper: elevated reuse across epochs); adjacent-epoch \
         similarity {:.3} vs epoch-0↔epoch-{} similarity {:.3} — the \
         near-diagonal block structure that motivates sliding windows.",
        reuse_last,
        adj,
        n.saturating_sub(1),
        far
    );
    FigureOutput {
        tables: vec![left, right],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recency_structure_reproduced() {
        let out = run(&FigOpts::default());
        // Parse the similarity matrix back out of the table.
        let right = &out.tables[1];
        let n = right.rows.len();
        assert!(n >= 4);
        let val = |i: usize, j: usize| -> f64 { right.rows[i][j + 1].parse().unwrap() };
        // Diagonal dominant.
        assert!(val(1, 1) > val(1, n - 1));
        // Adjacent beats distant on average.
        let adj: f64 = (1..n).map(|i| val(i - 1, i)).sum::<f64>() / (n - 1) as f64;
        assert!(
            adj > val(0, n - 1) + 0.02,
            "adjacent {adj} vs far {}",
            val(0, n - 1)
        );
        // Reuse series exists and rises overall.
        let left = &out.tables[0];
        let first: f64 = left.rows.first().unwrap()[1].parse().unwrap();
        let last: f64 = left.rows.last().unwrap()[1].parse().unwrap();
        assert!(last > first, "reuse should rise as policy sharpens");
    }
}

//! Fig. 12 — budget ablation: VeRL baseline vs DAS-unlimited-budget vs DAS
//! (distribution-aware).
//!
//! Paper: an unbounded speculative budget lets the drafter propose
//! arbitrarily long continuations, inflating verification cost and giving
//! up ~15% of the end-to-end gain vs the distribution-aware budget.

use super::common::{scaled_config, sim_trainer, steps_for, total_gen_time};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

pub fn run(opts: &FigOpts) -> FigureOutput {
    let steps = steps_for(opts, 14, 30);
    let variants: [(&str, &str, &str); 3] = [
        ("baseline", "none", "length_aware"),
        ("das_unlimited", "das", "unlimited"),
        ("das", "das", "length_aware"),
    ];
    let mut stats = Vec::new();
    for (_, drafter, policy) in &variants {
        let mut cfg = scaled_config("code_rl", opts);
        cfg.spec.drafter = drafter.to_string();
        cfg.spec.budget_policy = policy.to_string();
        let (mut model, mut trainer) = sim_trainer(&cfg);
        stats.push(trainer.run_sim(&mut model, steps));
    }
    let mut t = Table::new(
        "fig12_budget_ablation",
        &["step", "baseline_s", "das_unlimited_s", "das_s"],
    );
    for s in 0..steps {
        t.row_f(&[
            s as f64,
            stats[0][s].metrics.gen_time,
            stats[1][s].metrics.gen_time,
            stats[2][s].metrics.gen_time,
        ]);
    }
    let base = total_gen_time(&stats[0][1..]);
    let unlim = total_gen_time(&stats[1][1..]);
    let das = total_gen_time(&stats[2][1..]);
    let gain_unlim = base - unlim;
    let gain_das = base - das;
    let lost = 100.0 * (1.0 - gain_unlim / gain_das.max(1e-9));
    let summary = format!(
        "Fig.12: gen time baseline {base:.2}s, DAS-unlimited {unlim:.2}s, \
         DAS {das:.2}s — the unlimited budget gives up {lost:.0}% of DAS's \
         end-to-end gain to verification overhead (paper: ~15%).",
    );
    FigureOutput {
        tables: vec![t],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_awareness_beats_unlimited() {
        let out = run(&FigOpts::default());
        let t = &out.tables[0];
        let sum = |col: usize| -> f64 {
            t.rows[1..].iter().map(|r| r[col].parse::<f64>().unwrap()).sum()
        };
        let base = sum(1);
        let unlim = sum(2);
        let das = sum(3);
        assert!(das < base, "DAS must beat baseline");
        assert!(
            das < unlim,
            "distribution-aware must beat unlimited: das={das:.2} unlim={unlim:.2}"
        );
    }
}

//! Figs. 10 & 11 — end-to-end training curves: generation time and reward
//! per step, VeRL-baseline (no speculation) vs DAS.
//!
//! Fig. 10 (math, DSR-analog): DAS cuts rollout time >50% with identical
//! reward. Fig. 11 (code, DeepCoder-analog): ~25% reduction, comparable
//! reward. Same driver, different preset.

use super::common::{mean_late_reward, scaled_config, sim_trainer, steps_for, total_gen_time};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

pub fn run(opts: &FigOpts, preset_name: &str, table_name: &str) -> FigureOutput {
    let steps = steps_for(opts, 14, 30);
    let mut all = Vec::new();
    for drafter in ["none", "das"] {
        let mut cfg = scaled_config(preset_name, opts);
        cfg.spec.drafter = drafter.into();
        let (mut model, mut trainer) = sim_trainer(&cfg);
        all.push(trainer.run_sim(&mut model, steps));
    }
    let (base, das) = (&all[0], &all[1]);
    let mut t = Table::new(
        &format!("{table_name}_training_curves"),
        &[
            "step",
            "gen_time_base_s",
            "gen_time_das_s",
            "reward_base",
            "reward_das",
            "accept_rate_das",
        ],
    );
    for s in 0..steps {
        t.row_f(&[
            s as f64,
            base[s].metrics.gen_time,
            das[s].metrics.gen_time,
            base[s].reward,
            das[s].reward,
            das[s].metrics.accept_rate(),
        ]);
    }
    // Skip step 0 (drafter cold start) when reporting the headline ratio,
    // like the paper's steady-state reading of the curves.
    let tb = total_gen_time(&base[1..]);
    let td = total_gen_time(&das[1..]);
    let reduction = 100.0 * (1.0 - td / tb);
    let rb = mean_late_reward(base);
    let rd = mean_late_reward(das);
    let paper_claim = if table_name == "fig10" {
        "paper: >50% reduction, identical reward (Fig. 10)"
    } else {
        "paper: ~25% reduction, comparable reward (Fig. 11)"
    };
    let summary = format!(
        "{}: DAS cuts rollout generation time {:.0}% ({:.2}s → {:.2}s over \
         steps 1..{}); late-training reward {:.3} (baseline) vs {:.3} (DAS). \
         {}",
        table_name.to_uppercase(),
        reduction,
        tb,
        td,
        steps,
        rb,
        rd,
        paper_claim
    );
    FigureOutput {
        tables: vec![t],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn late_cols(t: &crate::telemetry::Table, col: usize, k: usize) -> f64 {
        t.rows[t.rows.len() - k..]
            .iter()
            .map(|r| r[col].parse::<f64>().unwrap())
            .sum::<f64>()
            / k as f64
    }

    #[test]
    fn fig10_math_speedup_and_reward_parity() {
        let out = run(&FigOpts::default(), "math_rl", "fig10");
        let t = &out.tables[0];
        // Steady-state gen time: DAS well below baseline.
        let base = late_cols(t, 1, 4);
        let das = late_cols(t, 2, 4);
        assert!(
            das < 0.7 * base,
            "expect >30% cut at small scale (paper 50%): base={base:.2} das={das:.2}"
        );
        // Reward parity: same expected reward trajectory (both rising, ends
        // within noise).
        let rb = late_cols(t, 3, 4);
        let rd = late_cols(t, 4, 4);
        assert!((rb - rd).abs() < 0.25, "rewards diverged: {rb} vs {rd}");
    }

    #[test]
    fn fig11_code_speedup() {
        let out = run(&FigOpts::default(), "code_rl", "fig11");
        let t = &out.tables[0];
        let base = late_cols(t, 1, 4);
        let das = late_cols(t, 2, 4);
        assert!(
            das < 0.9 * base,
            "expect a visible cut (paper ~25%): base={base:.2} das={das:.2}"
        );
    }
}

//! Fig. 5 — suffix tree vs suffix array as the online drafter index.
//!
//! Left: speculation (query) time across corpus sizes. Right: update time
//! for inserting one 100-token rollout (log scale in the paper). The tree's
//! incremental updates stay ~constant; the array pays an O(n log n) rebuild
//! every insert — the "three orders of magnitude" gap.

use std::time::Instant;

use super::{FigOpts, FigureOutput};
use crate::suffix::{SuffixArrayIndex, SuffixTree};
use crate::telemetry::Table;
use crate::util::rng::Rng;

fn measure<F: FnMut()>(mut f: F, min_iters: usize) -> f64 {
    // Median-of-iters wall time in microseconds.
    let mut times = Vec::with_capacity(min_iters);
    for _ in 0..min_iters {
        // audit: allow(wall-clock-determinism) -- figure-only microbenchmark; never feeds decode
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    crate::util::stats::median(&times)
}

pub fn run(opts: &FigOpts) -> FigureOutput {
    let sizes: Vec<usize> = if opts.full {
        vec![10_000, 30_000, 100_000, 300_000, 1_000_000]
    } else {
        vec![10_000, 30_000, 100_000]
    };
    let mut rng = Rng::seed_from_u64(opts.seed);
    let rollout_len = 100usize;
    let alphabet = 512usize;

    let mut query_t = Table::new(
        "fig05_query_time",
        &["corpus_tokens", "tree_us", "array_us"],
    );
    let mut update_t = Table::new(
        "fig05_update_time",
        &["corpus_tokens", "tree_us", "array_rebuild_us"],
    );
    let mut last_ratio = 0.0;
    for &n in &sizes {
        // Build both indexes over the same corpus of 100-token rollouts.
        let rollouts: Vec<Vec<u32>> = (0..n / rollout_len)
            .map(|_| (0..rollout_len).map(|_| rng.below(alphabet) as u32).collect())
            .collect();
        let mut tree = SuffixTree::new();
        for r in &rollouts {
            tree.insert(r);
        }
        // SuffixArrayIndex rebuilds on every insert by design; for the QUERY
        // comparison we charge it fairly with one bulk insert (one rebuild).
        let mut array = SuffixArrayIndex::new();
        let corpus: Vec<u32> = rollouts.iter().flatten().copied().collect();
        array.insert(&corpus);

        // Queries: longest-suffix-match + draft for random contexts.
        let contexts: Vec<Vec<u32>> = (0..64)
            .map(|_| {
                let r = &rollouts[rng.below(rollouts.len())];
                let start = rng.below(r.len() - 8);
                r[start..start + 8].to_vec()
            })
            .collect();
        let mut ci = 0usize;
        let tree_q = measure(
            || {
                let c = &contexts[ci % contexts.len()];
                ci += 1;
                std::hint::black_box(tree.draft(c, 8, 16));
            },
            200,
        );
        let mut cj = 0usize;
        let arr_q = measure(
            || {
                let c = &contexts[cj % contexts.len()];
                cj += 1;
                std::hint::black_box(array.draft(c, 8, 16));
            },
            200,
        );
        query_t.row_f(&[n as f64, tree_q, arr_q]);

        // Updates: insert one fresh 100-token rollout. The tree is an
        // online structure — insert into the live index (amortized O(1));
        // the array must rebuild from a clone each time (that IS its cost).
        let fresh: Vec<u32> = (0..rollout_len).map(|_| rng.below(alphabet) as u32).collect();
        let tree_u = {
            let mut live = tree.clone();
            measure(|| live.insert(&fresh), 20)
        };
        let arr_u = {
            let mut a2 = array.clone();
            measure(|| a2.insert(&fresh), 3)
        };
        update_t.row_f(&[n as f64, tree_u, arr_u]);
        last_ratio = arr_u / tree_u.max(1e-9);
    }
    let summary = format!(
        "Fig.5: at the largest corpus, one 100-token insert costs the suffix \
         array {last_ratio:.0}x the suffix tree (paper: >3 orders of \
         magnitude at 1M tokens — run with --full for the 1M point); tree \
         updates stay ~constant while array rebuilds grow with corpus size."
    );
    FigureOutput {
        tables: vec![query_t, update_t],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_updates_beat_array_rebuilds() {
        let mut opts = FigOpts::default();
        opts.seed = 3;
        let out = run(&opts);
        let upd = &out.tables[1];
        for row in &upd.rows {
            let tree: f64 = row[1].parse().unwrap();
            let arr: f64 = row[2].parse().unwrap();
            assert!(
                arr > 10.0 * tree,
                "array rebuild should dwarf tree insert: {row:?}"
            );
        }
        // Array rebuild cost grows with corpus size; tree stays flat-ish.
        let first_arr: f64 = upd.rows.first().unwrap()[2].parse().unwrap();
        let last_arr: f64 = upd.rows.last().unwrap()[2].parse().unwrap();
        assert!(last_arr > 2.0 * first_arr);
    }
}

//! Fig. 6 — history scoping ablation: problem vs problem+request vs
//! global+request.
//!
//! Paper: problem-scoped histories beat the global index on acceptance AND
//! on speculation latency (one large global index is slower to query and
//! maintain).

use super::common::{scaled_config, sim_trainer, steps_for};
use super::{FigOpts, FigureOutput};
use crate::telemetry::Table;

const SCOPES: [&str; 3] = ["problem", "problem+request", "global+request"];

pub fn run(opts: &FigOpts) -> FigureOutput {
    let steps = steps_for(opts, 12, 30);
    let mut accept = vec![Vec::new(); SCOPES.len()];
    let mut lat = vec![Vec::new(); SCOPES.len()];
    for (i, scope) in SCOPES.iter().enumerate() {
        let mut cfg = scaled_config("math_rl", opts);
        cfg.spec.scope = scope.to_string();
        cfg.spec.budget_policy = "uniform".into();
        // Make the workload big enough that a global tree is meaningfully
        // larger than per-problem shards.
        cfg.workload.n_problems = 24;
        let (mut model, mut trainer) = sim_trainer(&cfg);
        for s in trainer.run_sim(&mut model, steps) {
            accept[i].push(s.metrics.accepted_per_round());
            lat[i].push(s.metrics.draft_ms_per_token());
        }
    }
    let mut t_acc = Table::new(
        "fig06_accept_by_scope",
        &["step", "problem", "problem_request", "global_request"],
    );
    let mut t_lat = Table::new(
        "fig06_latency_by_scope",
        &["step", "problem_ms", "problem_request_ms", "global_request_ms"],
    );
    for s in 0..steps {
        t_acc.row_f(&[s as f64, accept[0][s], accept[1][s], accept[2][s]]);
        t_lat.row_f(&[s as f64, lat[0][s], lat[1][s], lat[2][s]]);
    }
    let late = |xs: &[f64]| {
        let k = (xs.len() / 3).max(1);
        crate::util::stats::mean(&xs[xs.len() - k..])
    };
    let summary = format!(
        "Fig.6: accepted/round — problem {:.2}, problem+request {:.2}, \
         global+request {:.2}; speculation ms/token — {:.4} / {:.4} / {:.4}. \
         Paper: problem-scoped ≥ global on acceptance and cheaper to query.",
        late(&accept[0]),
        late(&accept[1]),
        late(&accept[2]),
        late(&lat[0]),
        late(&lat[1]),
        late(&lat[2]),
    );
    FigureOutput {
        tables: vec![t_acc, t_lat],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_scope_at_least_matches_global_acceptance() {
        let out = run(&FigOpts::default());
        let t = &out.tables[0];
        let late = |col: usize| -> f64 {
            let k = t.rows.len() / 3;
            t.rows[t.rows.len() - k..]
                .iter()
                .map(|r| r[col].parse::<f64>().unwrap())
                .sum::<f64>()
                / k as f64
        };
        // Problem scope should not lose to global scope on acceptance.
        assert!(
            late(1) >= 0.9 * late(3),
            "problem {} vs global {}",
            late(1),
            late(3)
        );
        // Latency: global index must not be cheaper than problem shards.
        let l = &out.tables[1];
        let lat = |col: usize| -> f64 {
            let k = l.rows.len() / 3;
            l.rows[l.rows.len() - k..]
                .iter()
                .map(|r| r[col].parse::<f64>().unwrap())
                .sum::<f64>()
                / k as f64
        };
        assert!(lat(3) >= 0.7 * lat(1), "global {} vs problem {}", lat(3), lat(1));
    }
}

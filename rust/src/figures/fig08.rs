//! Fig. 8 — decode latency vs token count is linear (Eq. 1).
//!
//! Runs the REAL PJRT decode executables compiled at several context
//! lengths, measures wall-clock per forward, and fits
//! `t_fwd = c_base + c_tok·n`. The paper reports a clean linear
//! relationship with mean relative error ≈ 12%.

use super::{FigOpts, FigureOutput};
use crate::runtime::PjrtModel;
use crate::telemetry::Table;

pub fn run(opts: &FigOpts) -> anyhow::Result<FigureOutput> {
    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(
        dir.join("meta.json").exists(),
        "Fig.8 needs the real model: run `make artifacts` first"
    );
    let mut model = PjrtModel::load(dir)?;
    let reps = if opts.full { 25 } else { 8 };
    let report = model.calibrate(reps)?;
    let mut table = Table::new(
        "fig08_latency_vs_tokens",
        &["tokens", "measured_s", "fitted_s"],
    );
    for (n, secs) in &report.samples {
        table.row_f(&[*n as f64, *secs, report.model.t_fwd(*n)]);
    }
    let summary = format!(
        "Fig.8: fitted t_fwd = {:.4}s + {:.2}µs/token over {} samples, \
         R²={:.3}, MRE={:.1}% (paper: clear linear relationship, MRE ≈ 12%).",
        report.model.c_base,
        report.model.c_tok * 1e6,
        report.n_points,
        report.r_squared,
        report.mre * 100.0
    );
    Ok(FigureOutput {
        tables: vec![table],
        summary,
    })
}

//! Distributed draft service: the drafter behind a socket.
//!
//! SpecRL-style deployments centralize the nonparametric drafter so
//! history aggregates across a fleet of rollout workers instead of
//! fragmenting per process. This module is that split for `das`:
//!
//! - [`wire`] — `das-draft-rpc-v1`, a length-prefixed, checksummed
//!   binary protocol built from the `store/wire.rs` codec idioms
//!   (`u32 len | u64 fnv1a | body`, every count checked pre-allocation).
//! - [`server`] — the `das serve-drafts` daemon: one [`SuffixDrafter`]
//!   + optional [`HistoryStore`] (WAL-first mutations, periodic
//!   snapshot commits), drafts answered from published
//!   [`DrafterSnapshot`]s so readers never block the single writer.
//! - [`session`] — the client connection: timeouts, bounded retry with
//!   deterministic backoff, reconnects, a fast-degrade breaker, and the
//!   `remote_draft_*` telemetry the engine surfaces per step.
//! - [`client`] — [`RemoteDraftSource`], the `DraftSource` whose shards
//!   live server-side; selected via `spec.substrate = "remote"` +
//!   `spec.draft_addr`. Engine and rollout layers are unchanged.
//!
//! Failure semantics: every remote fault — refused connect, timeout,
//! mid-RPC server death, fingerprint drift — degrades the affected
//! draft to empty, which the engine already treats as "decode plainly".
//! At temperature 0 losslessness makes that a pure slowdown; outputs
//! are bit-identical with a healthy server, a dead one, or no server
//! at all (the `kill-draftsvc` chaos directive gates exactly this).
//!
//! [`SuffixDrafter`]: crate::drafter::SuffixDrafter
//! [`HistoryStore`]: crate::store::HistoryStore
//! [`DrafterSnapshot`]: crate::drafter::DrafterSnapshot

pub mod client;
pub mod server;
pub mod session;
pub mod wire;

pub use client::{RemoteDraftSource, RemoteShardSnapshot};
pub use server::DraftServer;
pub use session::{RemoteDraftStats, RemoteSession};
pub use wire::{DraftReq, Fingerprint, Msg, ShardKey, MAX_FRAME, PROTOCOL};

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::config::DasConfig;
    use crate::drafter::{Drafter, SuffixDrafter};
    use crate::model::sim::{SimModel, SimModelConfig};
    use crate::rollout::{GenJob, RolloutEngine, StepReport};
    use crate::tokens::Rollout;

    fn cfg(substrate: &str) -> DasConfig {
        let mut c = DasConfig::default();
        c.model.vocab_size = 64;
        c.workload.n_problems = 6;
        c.workload.len_mu = 3.2;
        c.workload.len_sigma = 0.4;
        c.rollout.max_new_tokens = 128;
        c.rollout.max_batch = 4;
        c.rollout.temperature = 0.0; // greedy: the bit-identity regime
        c.spec.drafter = "das".into();
        c.spec.substrate = substrate.into();
        c
    }

    fn jobs(n: usize, samples: usize) -> Vec<GenJob> {
        (0..n)
            .map(|p| GenJob {
                problem: p as u32,
                prompt: vec![p as u32 + 1, 7, 9],
                samples,
            })
            .collect()
    }

    fn sorted_rollouts(rep: &StepReport) -> Vec<(u32, Vec<u32>)> {
        let mut k: Vec<_> = rep
            .rollouts
            .iter()
            .map(|r| (r.problem, r.tokens.clone()))
            .collect();
        k.sort();
        k
    }

    /// Spawn a serve-drafts daemon for `client_cfg` on an OS-chosen
    /// loopback port: same drafter geometry, local substrate, optional
    /// store dir. Returns (server, join handle, addr).
    fn spawn_server(
        client_cfg: &DasConfig,
        dir: Option<&std::path::Path>,
    ) -> (Arc<DraftServer>, std::thread::JoinHandle<()>, String) {
        let mut spec = client_cfg.spec.clone();
        spec.substrate = "window".into();
        spec.draft_addr = String::new();
        let server = Arc::new(DraftServer::bind(&spec, dir, "127.0.0.1:0").expect("bind"));
        let addr = server.local_addr();
        let handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || server.run())
        };
        (server, handle, addr)
    }

    #[test]
    fn remote_engine_outputs_bit_identical_to_window_over_loopback() {
        // THE tentpole acceptance test: substrate="remote" over loopback
        // produces greedy rollouts byte-identical to in-process "window",
        // step for step, across epoch rolls — while actually speculating
        // through the socket.
        let c_ref = cfg("window");
        let mut m1 = SimModel::new(SimModelConfig::from_das(&c_ref));
        let mut e1 = RolloutEngine::new(&c_ref, crate::drafter::from_config(&c_ref));

        let mut c_rem = cfg("remote");
        let (server, handle, addr) = spawn_server(&c_rem, None);
        c_rem.spec.draft_addr = addr;
        let mut m2 = SimModel::new(SimModelConfig::from_das(&c_rem));
        let mut e2 = RolloutEngine::new(&c_rem, crate::drafter::from_config(&c_rem));

        let mut saw_traffic = false;
        for step in 0..3u32 {
            let r1 = e1.generate_step(&mut m1, &jobs(4, 2), step);
            let r2 = e2.generate_step(&mut m2, &jobs(4, 2), step);
            assert_eq!(
                sorted_rollouts(&r1),
                sorted_rollouts(&r2),
                "remote substrate broke losslessness at step {step}"
            );
            if r2.metrics.remote_round_trips > 0 {
                saw_traffic = true;
            }
            assert_eq!(r2.metrics.remote_degraded, 0, "healthy server never degrades");
            e1.roll_epoch(step + 1);
            e2.roll_epoch(step + 1);
        }
        assert!(saw_traffic, "remote run must actually speculate over the wire");
        server.stop();
        handle.join().expect("server thread");
    }

    #[test]
    fn mid_run_server_death_degrades_to_plain_decoding() {
        // The chaos contract: kill-draftsvc mid-run must leave greedy
        // outputs untouched (empty drafts = plain decoding) and surface
        // the death in the remote_draft_* gauges.
        let c_ref = cfg("window");
        let mut m1 = SimModel::new(SimModelConfig::from_das(&c_ref));
        let mut e1 = RolloutEngine::new(&c_ref, crate::drafter::from_config(&c_ref));

        let mut c_rem = cfg("remote");
        let (_server, handle, addr) = spawn_server(&c_rem, None);
        c_rem.spec.draft_addr = addr;
        c_rem.spec.draft_timeout_ms = 50;
        c_rem.spec.draft_retries = 1;
        c_rem.rollout.fault_plan = "kill-draftsvc step=1".into();
        let mut m2 = SimModel::new(SimModelConfig::from_das(&c_rem));
        let mut e2 = RolloutEngine::new(&c_rem, crate::drafter::from_config(&c_rem));

        let mut degraded_total = 0u64;
        for step in 0..3u32 {
            let r1 = e1.generate_step(&mut m1, &jobs(3, 2), step);
            let r2 = e2.generate_step(&mut m2, &jobs(3, 2), step);
            assert_eq!(
                sorted_rollouts(&r1),
                sorted_rollouts(&r2),
                "server death changed greedy outputs at step {step}"
            );
            degraded_total += r2.metrics.remote_degraded;
        }
        assert!(
            degraded_total > 0,
            "a killed server must show up as degraded remote drafts"
        );
        handle.join().expect("server thread exits after Die");
    }

    #[test]
    fn remote_drafter_matches_local_substrate_draft_for_draft() {
        // Drafter-level bit-identity: identical absorb/roll streams, then
        // identical draft calls — the remote drafter (through a real
        // socket) and the local window drafter must answer the same
        // tokens, both on the serial path and through published
        // snapshots.
        let c = cfg("remote");
        let (server, handle, addr) = spawn_server(&c, None);
        let mut c_rem = c.clone();
        c_rem.spec.draft_addr = addr;
        let mut remote = SuffixDrafter::from_config(&c_rem.spec);
        let mut c_loc = c.clone();
        c_loc.spec.substrate = "window".into();
        let mut local = SuffixDrafter::from_config(&c_loc.spec);

        let runs: Vec<(u32, Vec<u32>)> = vec![
            (1, vec![5, 6, 7, 8, 9, 6, 7, 8, 9, 10]),
            (1, vec![5, 6, 7, 8, 9, 11]),
            (2, vec![20, 21, 22, 23, 21, 22, 23, 24]),
        ];
        for (problem, tokens) in &runs {
            let r = Rollout {
                problem: *problem,
                epoch: 0,
                step: 0,
                tokens: tokens.clone(),
                reward: 0.0,
            };
            remote.observe_rollout(&r);
            local.observe_rollout(&r);
        }
        remote.roll_epoch(1);
        local.roll_epoch(1);

        let contexts: Vec<(u32, Vec<u32>)> = vec![
            (1, vec![5, 6, 7, 8]),
            (1, vec![9, 6, 7]),
            (2, vec![22, 23]),
            (2, vec![1, 2, 3]), // miss on both sides
            (3, vec![5, 6]),    // unknown problem on both sides
        ];
        for (i, (problem, ctx)) in contexts.iter().enumerate() {
            let dr = remote.draft(100 + i as u64, *problem, ctx, 8);
            let dl = local.draft(100 + i as u64, *problem, ctx, 8);
            assert_eq!(dr.tokens, dl.tokens, "serial draft {i} diverged");
            assert_eq!(dr.match_len, dl.match_len, "serial match_len {i} diverged");
            let sr = remote.snapshot().expect("remote snapshot");
            let sl = local.snapshot().expect("local snapshot");
            let (dr2, _) = sr.draft(200 + i as u64, *problem, ctx, 8);
            let (dl2, _) = sl.draft(200 + i as u64, *problem, ctx, 8);
            assert_eq!(dr2.tokens, dl2.tokens, "snapshot draft {i} diverged");
        }
        let stats = remote.remote_stats().expect("remote drafter reports stats");
        assert!(stats.round_trips > 0);
        assert_eq!(stats.degraded, 0);
        server.stop();
        handle.join().expect("server thread");
    }

    #[test]
    fn server_warm_starts_from_its_store() {
        // Durability: absorb through the wire, roll an epoch (snapshot
        // commit cadence 1), shut down gracefully, rebind on the same
        // dir — the reborn server must answer the same drafts.
        let dir = crate::store::test_dir("draftsvc-warm-start");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = cfg("remote");
        c.spec.snapshot_every = 1;

        let (server, handle, addr) = spawn_server(&c, Some(&dir));
        c.spec.draft_addr = addr;
        let mut drafter = SuffixDrafter::from_config(&c.spec);
        drafter.observe_rollout(&Rollout {
            problem: 4,
            epoch: 0,
            step: 0,
            tokens: vec![30, 31, 32, 33, 31, 32, 33, 34],
            reward: 0.0,
        });
        drafter.roll_epoch(1);
        let before = drafter.draft(1, 4, &[30, 31, 32], 8);
        assert!(!before.tokens.is_empty(), "live server drafts from history");
        drafter.kill_remote(); // graceful path exercised below via rebind
        server.stop();
        handle.join().expect("server thread");
        assert_eq!(server.store_failures(), 0);

        let (server2, handle2, addr2) = spawn_server(&c, Some(&dir));
        c.spec.draft_addr = addr2;
        let mut drafter2 = SuffixDrafter::from_config(&c.spec);
        let after = drafter2.draft(2, 4, &[30, 31, 32], 8);
        assert_eq!(after.tokens, before.tokens, "warm-started server must agree");
        server2.stop();
        handle2.join().expect("server thread");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_drift_is_refused_and_marks_the_session_dead() {
        // A client whose shard geometry differs must be refused at
        // handshake — silently different drafts would break the
        // remote ≡ local contract. The session goes permanently dead and
        // every later call degrades immediately.
        let c = cfg("remote");
        let (server, handle, addr) = spawn_server(&c, None);
        let session = Arc::new(RemoteSession::new(
            &addr,
            200,
            0,
            Fingerprint {
                window: c.spec.window + 1, // drifted
                match_len: c.spec.match_len,
                max_depth: c.spec.match_len + c.spec.budget_cap.max(8),
                scope: c.spec.scope.clone(),
            },
        ));
        let d = session.draft_one(0, ShardKey::Problem(1), &[1, 2, 3], 8, 8);
        assert!(d.is_empty());
        assert!(session.is_dead(), "fingerprint drift is permanent");
        let stats = session.drain_stats();
        assert!(stats.degraded > 0);
        server.stop();
        handle.join().expect("server thread");
    }

    #[test]
    fn batched_drafts_match_single_request_drafts() {
        // One frame carrying N contexts must answer exactly what N
        // single-request frames answer (batching is transport-only).
        let c = cfg("remote");
        let (server, handle, addr) = spawn_server(&c, None);
        let session = Arc::new(RemoteSession::new(
            &addr,
            200,
            2,
            Fingerprint {
                window: c.spec.window,
                match_len: c.spec.match_len,
                max_depth: c.spec.match_len + c.spec.budget_cap.max(8),
                scope: c.spec.scope.clone(),
            },
        ));
        session.absorb(ShardKey::Problem(9), 0, &[40, 41, 42, 43, 41, 42, 43, 44]);
        let reqs: Vec<DraftReq> = (0..4)
            .map(|i| DraftReq {
                shard: ShardKey::Problem(9),
                context: vec![40 + i, 41 + i],
                max_match: 8,
                budget: 8,
            })
            .collect();
        let batched = session.draft_batch(0, reqs.clone());
        assert_eq!(batched.len(), reqs.len());
        for (req, want) in reqs.iter().zip(&batched) {
            let one = session.draft_one(0, req.shard, &req.context, req.max_match, req.budget);
            assert_eq!(one.tokens, want.tokens);
            assert_eq!(one.match_len, want.match_len);
        }
        let stats = session.drain_stats();
        assert!(stats.contexts >= 8, "4 batched + 4 single contexts counted");
        server.stop();
        handle.join().expect("server thread");
    }
}

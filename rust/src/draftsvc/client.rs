//! The `substrate = "remote"` client: a [`DraftSource`] whose index lives
//! in a `das serve-drafts` daemon.
//!
//! Each shard of the client drafter (the global shard plus one per
//! problem) is a [`RemoteDraftSource`] addressing the matching server
//! shard over the shared [`RemoteSession`]. Drafting forwards the *raw*
//! shard-level `draft_from` call — minimum-match gating, request-local
//! indexes, and router redirects all stay client-side in
//! `SuffixDrafter` — so for identical absorb/roll streams the remote
//! substrate answers bit-identically to its in-process counterpart.
//!
//! `snapshot()` returns a snapshot-*shaped* handle
//! ([`RemoteShardSnapshot`]): it pins a server-published snapshot id, so
//! `spec.draft_threads` fan-out keeps its publish-time semantics (readers
//! see the pinned server state, never a mid-mutation view), while the
//! bytes stay on the server.

use std::sync::Arc;

use super::session::RemoteSession;
use super::wire::ShardKey;
use crate::drafter::{Draft, DraftSnapshot, DraftSource};
use crate::store::wire::{Reader, StoreError, Writer};
use crate::tokens::{Epoch, TokenId};

/// One server shard seen through the [`DraftSource`] interface.
#[derive(Debug)]
pub struct RemoteDraftSource {
    session: Arc<RemoteSession>,
    shard: ShardKey,
    /// Tokens forwarded to the server shard — the client-side stand-in
    /// for `indexed_tokens` (the true count lives server-side; this
    /// tracks what *this* client contributed, which is what the engine's
    /// per-step index gauges want to see grow).
    sent_tokens: usize,
}

impl RemoteDraftSource {
    pub fn new(session: Arc<RemoteSession>, shard: ShardKey) -> RemoteDraftSource {
        RemoteDraftSource {
            session,
            shard,
            sent_tokens: 0,
        }
    }
}

impl DraftSource for RemoteDraftSource {
    fn source_name(&self) -> &'static str {
        "remote"
    }

    fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        // Snapshot 0 is the server's live view. Failure inside the
        // session surfaces as an empty draft — the engine then decodes
        // plainly for this request, which is the degrade contract.
        self.session
            .draft_one(0, self.shard, context, max_match, budget)
    }

    fn snapshot(&mut self) -> DraftSnapshot {
        // Pin a published server snapshot. If publishing fails the id
        // degrades to 0 (live view) — still correct, merely unpinned.
        let snapshot = self.session.publish();
        DraftSnapshot::Remote(Arc::new(RemoteShardSnapshot {
            session: Arc::clone(&self.session),
            shard: self.shard,
            snapshot,
        }))
    }

    fn absorb(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        self.sent_tokens += tokens.len();
        self.session.absorb(self.shard, epoch, tokens);
    }

    fn on_epoch(&mut self, epoch: Epoch) {
        // The drafter fans on_epoch out per shard; the session dedups so
        // the server rolls once per epoch.
        self.session.roll_epoch(epoch);
    }

    fn indexed_tokens(&self) -> usize {
        self.sent_tokens
    }

    fn save_state(&self, w: &mut Writer) {
        // Remote shards are views, not state: the server owns the index
        // and its durability (store dir, WAL, snapshots). Persist the
        // stateless tag so a blob written with a remote shard loads as
        // "nothing to restore".
        w.str(self.source_name());
        w.u8(0);
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        r.expect_str(self.source_name(), "source blob tag")?;
        if r.u8()? != 0 {
            return Err(StoreError::Corrupt("remote source with a payload".into()));
        }
        Ok(())
    }
}

/// The snapshot-shaped handle a [`RemoteDraftSource`] publishes: a pinned
/// server snapshot id plus the session to reach it. Shareable across the
/// engine's draft threads (`DraftSnapshot` is `Send + Sync`; the session
/// serializes the wire underneath).
#[derive(Debug)]
pub struct RemoteShardSnapshot {
    session: Arc<RemoteSession>,
    shard: ShardKey,
    snapshot: u64,
}

impl RemoteShardSnapshot {
    pub fn draft(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        self.session
            .draft_one(self.snapshot, self.shard, context, max_match, budget)
    }

    /// The pinned server snapshot id (0 = live view fallback).
    pub fn snapshot_id(&self) -> u64 {
        self.snapshot
    }
}

#[cfg(test)]
mod tests {
    use super::super::wire::Fingerprint;
    use super::*;

    fn session() -> Arc<RemoteSession> {
        Arc::new(RemoteSession::new(
            "127.0.0.1:1",
            20,
            0,
            Fingerprint {
                window: 16,
                match_len: 8,
                max_depth: 72,
                scope: "problem".to_string(),
            },
        ))
    }

    #[test]
    fn source_degrades_cleanly_with_no_server() {
        let mut src = RemoteDraftSource::new(session(), ShardKey::Problem(3));
        assert_eq!(src.source_name(), "remote");
        assert!(src.draft_from(&[1, 2, 3], 8, 16).is_empty());
        src.absorb(0, &[1, 2, 3, 4]);
        assert_eq!(src.indexed_tokens(), 4);
        let snap = src.snapshot();
        assert!(snap.draft_from(&[1, 2], 8, 16).is_empty());
    }

    #[test]
    fn state_blob_roundtrips_as_stateless() {
        let src = RemoteDraftSource::new(session(), ShardKey::Global);
        let mut w = Writer::new();
        src.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let mut fresh = RemoteDraftSource::new(session(), ShardKey::Global);
        fresh.load_state(&mut r).expect("stateless blob loads");
        assert!(r.is_empty());
    }
}

//! Client session: one shared TCP connection to a `das serve-drafts`
//! daemon, with the full fault ladder in front of it.
//!
//! The ladder, in order: connect timeout → read timeout → bounded retry
//! with deterministic backoff (`1 + spec.draft_retries` attempts, each on
//! a fresh connection) → on exhaustion the call *degrades* — mutations
//! are dropped, drafts come back empty, and the engine falls back to
//! plain decoding exactly as it does for a poisoned local drafter. Three
//! consecutive exhausted calls trip a fast-degrade breaker so a dead
//! server costs one cheap check per call instead of a full retry ladder;
//! any later success rearms the breaker. A fingerprint rejection at
//! handshake (shard-geometry or protocol drift) is not transient and
//! marks the session permanently dead.
//!
//! All connection state and every counter live behind one mutex: RPC
//! traffic is serialized per session anyway (the engine's draft threads
//! read published snapshots; only round-trips reach here), so there is
//! nothing to win from lock-free counters and a single lock keeps the
//! degrade bookkeeping trivially consistent. The per-call latency samples
//! feed the `remote_draft_rpc_p50/p99` gauges, drained once per step.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::Duration;

use super::wire::{read_frame, write_frame, DraftReq, Fingerprint, Msg, ShardKey, PROTOCOL};
use crate::drafter::Draft;
use crate::store::wire::StoreError;
use crate::tokens::{Epoch, TokenId};

/// Consecutive exhausted RPCs before the fast-degrade breaker opens.
const STRIKE_LIMIT: u32 = 3;
/// Cap on buffered latency samples between drains (one step's worth of
/// round-trips is far below this; the cap only bounds a pathological
/// drain-free run).
const MAX_LAT_SAMPLES: usize = 8192;

/// One step's worth of remote-drafting telemetry, drained by the engine
/// into the `remote_draft_*` gauges of `StepMetrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RemoteDraftStats {
    /// Completed request/reply round-trips.
    pub round_trips: u64,
    /// Draft contexts carried inside those round-trips (batching ratio =
    /// contexts / round-trips).
    pub contexts: u64,
    /// Read/connect timeouts observed (each consumes one retry attempt).
    pub timeouts: u64,
    /// Reconnect attempts after a broken or refused connection.
    pub reconnects: u64,
    /// Calls that exhausted the retry ladder and degraded.
    pub degraded: u64,
    /// Median round-trip latency in seconds (0 when no samples).
    pub rpc_p50_s: f64,
    /// p99 round-trip latency in seconds (0 when no samples).
    pub rpc_p99_s: f64,
}

#[derive(Debug, Default)]
struct Counters {
    round_trips: u64,
    contexts: u64,
    timeouts: u64,
    reconnects: u64,
    degraded: u64,
}

#[derive(Debug)]
struct Inner {
    stream: Option<TcpStream>,
    /// Whether this session ever held a live connection — distinguishes
    /// first dials from reconnects in the gauge family.
    was_connected: bool,
    /// Last epoch forwarded via `RollEpoch`; the drafter calls `on_epoch`
    /// once per shard, the server rolls once per epoch.
    last_epoch: Option<Epoch>,
    /// Cached published snapshot id; invalidated by any mutation.
    publish: Option<u64>,
    /// Consecutive exhausted calls (fast-degrade breaker).
    strikes: u32,
    /// Permanently dead: the server rejected our handshake fingerprint.
    dead: bool,
    stats: Counters,
    lat_us: Vec<u64>,
}

/// A shared client session; cheap to clone behind `Arc` across every
/// shard-shaped [`super::RemoteDraftSource`] of one drafter.
#[derive(Debug)]
pub struct RemoteSession {
    addr: String,
    timeout: Duration,
    retries: u32,
    fp: Fingerprint,
    inner: Mutex<Inner>,
}

impl RemoteSession {
    /// Build a session. No I/O happens here — the first RPC dials.
    pub fn new(addr: &str, timeout_ms: usize, retries: usize, fp: Fingerprint) -> RemoteSession {
        RemoteSession {
            addr: addr.to_string(),
            timeout: Duration::from_millis(timeout_ms.max(1) as u64),
            retries: retries.min(16) as u32,
            fp,
            inner: Mutex::new(Inner {
                stream: None,
                was_connected: false,
                last_epoch: None,
                publish: None,
                strikes: 0,
                dead: false,
                stats: Counters::default(),
                lat_us: Vec::new(),
            }),
        }
    }

    /// The configured daemon address (for logs and error messages).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A panic mid-RPC leaves at worst a stale stream, which the next
        // call tears down and redials; the counters stay monotonic.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn resolve(&self) -> Result<SocketAddr, StoreError> {
        self.addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| StoreError::Io(format!("draft_addr '{}' resolved to nothing", self.addr)))
    }

    /// Dial + handshake. On fingerprint rejection the session is marked
    /// permanently dead by the caller (the error carries the detail).
    fn dial(&self, g: &mut Inner) -> Result<(), StoreError> {
        let sockaddr = self.resolve()?;
        let mut stream = TcpStream::connect_timeout(&sockaddr, self.timeout)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        stream.set_nodelay(true)?;
        write_frame(
            &mut stream,
            &Msg::Hello {
                proto: PROTOCOL.to_string(),
                fp: self.fp.clone(),
            },
        )?;
        match read_frame(&mut stream)? {
            Msg::HelloOk { .. } => {
                g.stream = Some(stream);
                g.was_connected = true;
                Ok(())
            }
            Msg::Err(detail) => {
                g.dead = true;
                Err(StoreError::Mismatch(format!(
                    "draft server at {} refused the handshake: {detail}",
                    self.addr
                )))
            }
            other => Err(StoreError::Corrupt(format!(
                "unexpected handshake reply {other:?}"
            ))),
        }
    }

    fn is_timeout(err: &StoreError) -> bool {
        // StoreError::Io carries the stringified io::Error; std's Display
        // for WouldBlock/TimedOut is stable English. Only a gauge keys
        // off this, never control flow.
        match err {
            StoreError::Io(s) => {
                let s = s.to_ascii_lowercase();
                s.contains("timed out") || s.contains("would block") || s.contains("temporarily unavailable")
            }
            _ => false,
        }
    }

    /// One request/reply exchange with retry, reconnect, and degrade
    /// accounting. Server-side `Err` replies surface as `Err` without
    /// retry (the server understood and refused; retrying cannot help).
    fn rpc(&self, g: &mut Inner, msg: &Msg) -> Result<Msg, StoreError> {
        if g.dead {
            g.stats.degraded += 1;
            return Err(StoreError::Unsupported(
                "remote draft session is permanently dead (handshake rejected)",
            ));
        }
        if g.strikes >= STRIKE_LIMIT && g.stream.is_none() {
            // Fast degrade: probe with a single dial so a revived server
            // is eventually rediscovered, but a dead one costs one
            // connect timeout per call instead of a full retry ladder.
            g.stats.reconnects += 1;
            if let Err(err) = self.dial(g) {
                if Self::is_timeout(&err) {
                    g.stats.timeouts += 1;
                }
                g.stats.degraded += 1;
                return Err(err);
            }
        }
        let attempts = 1 + self.retries;
        let mut last = StoreError::Io("remote draft rpc never attempted".to_string());
        for attempt in 0..attempts {
            if g.stream.is_none() {
                if g.was_connected || attempt > 0 {
                    g.stats.reconnects += 1;
                }
                if let Err(err) = self.dial(g) {
                    if g.dead {
                        g.stats.degraded += 1;
                        return Err(err);
                    }
                    if Self::is_timeout(&err) {
                        g.stats.timeouts += 1;
                    }
                    last = err;
                    self.backoff(attempt);
                    continue;
                }
            }
            let Some(stream) = g.stream.as_mut() else {
                last = StoreError::Io("connection lost before send".to_string());
                continue;
            };
            // audit: allow(wall-clock-determinism) -- RPC latency gauge only; never replayed or compared
            let t0 = std::time::Instant::now();
            let res = write_frame(stream, msg).and_then(|()| read_frame(stream));
            match res {
                Ok(Msg::Err(detail)) => {
                    g.stats.round_trips += 1;
                    g.strikes = 0;
                    g.stats.degraded += 1;
                    return Err(StoreError::Corrupt(format!(
                        "draft server refused request: {detail}"
                    )));
                }
                Ok(reply) => {
                    g.stats.round_trips += 1;
                    g.strikes = 0;
                    if g.lat_us.len() < MAX_LAT_SAMPLES {
                        let us = t0.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
                        g.lat_us.push(us);
                    }
                    return Ok(reply);
                }
                Err(err) => {
                    if Self::is_timeout(&err) {
                        g.stats.timeouts += 1;
                    }
                    g.stream = None;
                    last = err;
                    self.backoff(attempt);
                }
            }
        }
        g.strikes += 1;
        g.stats.degraded += 1;
        Err(last)
    }

    fn backoff(&self, attempt: u32) {
        // Deterministic linear backoff, capped by the configured timeout
        // so the worst-case ladder stays bounded by
        // attempts * (timeout + backoff).
        let step = Duration::from_millis(10 * u64::from(attempt) + 5);
        std::thread::sleep(step.min(self.timeout));
    }

    /// Forward one absorbed rollout to the server shard. Failures degrade
    /// silently: the server misses one history run, drafts stay correct
    /// (losslessness never depends on drafter content).
    pub fn absorb(&self, shard: ShardKey, epoch: Epoch, tokens: &[TokenId]) {
        let mut g = self.lock();
        g.publish = None;
        let msg = Msg::Absorb {
            shard,
            epoch,
            tokens: tokens.to_vec(),
        };
        let _ = self.rpc(&mut g, &msg);
    }

    /// Roll the server's epoch window. Deduplicated: the drafter fans
    /// `on_epoch` out per shard, the server rolls once.
    pub fn roll_epoch(&self, epoch: Epoch) {
        let mut g = self.lock();
        if g.last_epoch == Some(epoch) {
            return;
        }
        g.publish = None;
        if self.rpc(&mut g, &Msg::RollEpoch { epoch }).is_ok() {
            g.last_epoch = Some(epoch);
        }
    }

    /// Register a routed prefix → shard mapping on the server.
    pub fn register(&self, shard: u32, tokens: &[TokenId]) {
        let mut g = self.lock();
        g.publish = None;
        let msg = Msg::Register {
            shard,
            tokens: tokens.to_vec(),
        };
        let _ = self.rpc(&mut g, &msg);
    }

    /// Pin a published server snapshot and return its id. Cached until
    /// the next mutation; 0 (the live view) on failure, which keeps
    /// drafting correct and merely loosens the publish-time pinning.
    pub fn publish(&self) -> u64 {
        let mut g = self.lock();
        if let Some(id) = g.publish {
            return id;
        }
        match self.rpc(&mut g, &Msg::Publish) {
            Ok(Msg::Published { snapshot, .. }) => {
                g.publish = Some(snapshot);
                snapshot
            }
            _ => 0,
        }
    }

    /// Draft a batch of contexts in one round-trip. On any failure every
    /// slot comes back [`Draft::empty`] — the degrade contract.
    pub fn draft_batch(&self, snapshot: u64, reqs: Vec<DraftReq>) -> Vec<Draft> {
        let n = reqs.len();
        if n == 0 {
            return Vec::new();
        }
        let mut g = self.lock();
        match self.rpc(&mut g, &Msg::DraftBatch { snapshot, reqs }) {
            Ok(Msg::Drafts { drafts }) if drafts.len() == n => {
                g.stats.contexts += n as u64;
                drafts
            }
            _ => vec![Draft::empty(); n],
        }
    }

    /// Draft a single context (the per-source path).
    pub fn draft_one(
        &self,
        snapshot: u64,
        shard: ShardKey,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> Draft {
        let reqs = vec![DraftReq {
            shard,
            context: context.to_vec(),
            max_match,
            budget,
        }];
        self.draft_batch(snapshot, reqs)
            .into_iter()
            .next()
            .unwrap_or_default()
    }

    /// Best-effort abrupt kill (chaos directive): tell the server to die
    /// without replying, then drop our connection so the next call walks
    /// the reconnect/degrade ladder for real.
    pub fn send_die(&self) {
        let mut g = self.lock();
        if let Some(stream) = g.stream.as_mut() {
            let _ = write_frame(stream, &Msg::Die);
        }
        g.stream = None;
        g.publish = None;
    }

    /// Graceful server stop (waits for the `Ok` ack).
    pub fn send_shutdown(&self) -> Result<(), StoreError> {
        let mut g = self.lock();
        match self.rpc(&mut g, &Msg::Shutdown) {
            Ok(Msg::Ok) => Ok(()),
            Ok(other) => Err(StoreError::Corrupt(format!(
                "unexpected shutdown reply {other:?}"
            ))),
            Err(e) => Err(e),
        }
    }

    /// True once the handshake has been permanently rejected.
    pub fn is_dead(&self) -> bool {
        self.lock().dead
    }

    /// Drain the step's telemetry: counters reset to zero, latency
    /// samples consumed into p50/p99.
    pub fn drain_stats(&self) -> RemoteDraftStats {
        let mut g = self.lock();
        let c = std::mem::take(&mut g.stats);
        let mut lat = std::mem::take(&mut g.lat_us);
        lat.sort_unstable();
        let quant = |q_num: usize, q_den: usize| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            let idx = (lat.len() * q_num / q_den).min(lat.len() - 1);
            lat[idx] as f64 / 1e6
        };
        RemoteDraftStats {
            round_trips: c.round_trips,
            contexts: c.contexts,
            timeouts: c.timeouts,
            reconnects: c.reconnects,
            degraded: c.degraded,
            rpc_p50_s: quant(1, 2),
            rpc_p99_s: quant(99, 100),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp() -> Fingerprint {
        Fingerprint {
            window: 16,
            match_len: 8,
            max_depth: 72,
            scope: "problem".to_string(),
        }
    }

    #[test]
    fn unreachable_server_degrades_to_empty_drafts_without_panicking() {
        // Port 1 on loopback is essentially never listening; connect is
        // refused immediately, so the ladder runs fast and deterministic.
        let s = RemoteSession::new("127.0.0.1:1", 20, 1, fp());
        let d = s.draft_one(0, ShardKey::Global, &[1, 2, 3], 8, 16);
        assert!(d.is_empty());
        s.absorb(ShardKey::Global, 0, &[1, 2, 3]);
        s.roll_epoch(1);
        assert_eq!(s.publish(), 0, "failed publish falls back to the live view");
        let stats = s.drain_stats();
        assert!(stats.degraded >= 4, "each failed call degrades: {stats:?}");
        assert_eq!(stats.round_trips, 0);
        assert_eq!(stats.contexts, 0);
    }

    #[test]
    fn fast_degrade_breaker_opens_after_consecutive_failures() {
        let s = RemoteSession::new("127.0.0.1:1", 20, 0, fp());
        for _ in 0..(STRIKE_LIMIT + 2) {
            let _ = s.draft_one(0, ShardKey::Global, &[1], 4, 8);
        }
        let g = s.lock();
        assert!(g.strikes >= STRIKE_LIMIT, "breaker armed: {}", g.strikes);
    }

    #[test]
    fn drain_stats_resets_counters() {
        let s = RemoteSession::new("127.0.0.1:1", 20, 0, fp());
        let _ = s.draft_one(0, ShardKey::Global, &[1], 4, 8);
        let first = s.drain_stats();
        assert!(first.degraded > 0);
        let second = s.drain_stats();
        assert_eq!(second, RemoteDraftStats::default());
    }

    #[test]
    fn latency_quantiles_come_from_the_sorted_samples() {
        let s = RemoteSession::new("127.0.0.1:1", 20, 0, fp());
        {
            let mut g = s.lock();
            g.lat_us.extend([100u64, 200, 300, 400, 1000]);
        }
        let stats = s.drain_stats();
        assert!((stats.rpc_p50_s - 300e-6).abs() < 1e-12, "{stats:?}");
        assert!((stats.rpc_p99_s - 1000e-6).abs() < 1e-12, "{stats:?}");
    }
}

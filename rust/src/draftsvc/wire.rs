//! `das-draft-rpc-v1` — the length-prefixed binary message codec of the
//! distributed draft service.
//!
//! Frame layout on the wire (everything little-endian, mirroring the
//! `das-store-v1` WAL frame):
//!
//! ```text
//! [u32 body_len][u64 fnv1a(body)][body]
//! ```
//!
//! The body is one message: a `u8` tag followed by tag-specific fields,
//! encoded with the store codec ([`Writer`]/[`Reader`]), so every length
//! is a checked prefix and every hostile count is rejected *before* any
//! allocation sized by it. `body_len` itself is capped at [`MAX_FRAME`]
//! for the same reason: a flipped high bit in the length prefix must come
//! back as [`StoreError::Corrupt`], not as a 4 GiB allocation attempt.
//!
//! Message table (tag → payload → expected reply):
//!
//! | tag | message      | payload                                   | reply       |
//! |-----|--------------|-------------------------------------------|-------------|
//! | 1   | `Hello`      | proto string + drafter fingerprint        | `HelloOk`/`Err` |
//! | 2   | `HelloOk`    | server epoch                              | —           |
//! | 3   | `Absorb`     | shard key, epoch, token run               | `Ok`        |
//! | 4   | `RollEpoch`  | epoch                                     | `Ok`        |
//! | 5   | `Register`   | router shard id, token run                | `Ok`        |
//! | 6   | `Publish`    | —                                         | `Published` |
//! | 7   | `Published`  | snapshot id, epoch                        | —           |
//! | 8   | `DraftBatch` | snapshot id (0 = live), N draft requests  | `Drafts`    |
//! | 9   | `Drafts`     | N drafts (tokens, confidence, match_len)  | —           |
//! | 10  | `Ok`         | —                                         | —           |
//! | 11  | `Err`        | detail string                             | —           |
//! | 12  | `Shutdown`   | — (graceful stop; server acks `Ok`)       | `Ok`        |
//! | 13  | `Die`        | — (abrupt stop, no reply; chaos directive)| none        |
//!
//! A `DraftBatch` frame carries N contexts and its `Drafts` reply carries
//! N drafts — one round-trip amortizes the framing and syscall cost across
//! the whole batch (`benches/remote_draft.rs` measures the win).

use crate::drafter::Draft;
use crate::store::wire::{checksum, len_u32, Reader, StoreError, Writer};
use crate::tokens::{Epoch, ProblemId, TokenId};

/// Protocol identifier carried by `Hello`; a server speaking a different
/// revision answers `Err` and the client degrades instead of misparsing.
pub const PROTOCOL: &str = "das-draft-rpc-v1";

/// Hard cap on one frame body. Anything larger is corrupt by definition
/// (the largest legitimate frame is a draft batch of full-context
/// requests, well under a mebibyte) and is rejected before allocation.
pub const MAX_FRAME: usize = 1 << 24;

/// Which server-side history shard a message addresses. The client's
/// routing layer (scope rules, request-local indexes, the prefix router)
/// stays client-side; the wire only ever names the storage shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardKey {
    /// The single global shard (`global+request` scope).
    Global,
    /// The per-problem shard of `problem` / `problem+request` scopes.
    Problem(ProblemId),
}

impl ShardKey {
    fn encode(self, w: &mut Writer) {
        match self {
            ShardKey::Global => {
                w.u8(0);
                w.u32(0);
            }
            ShardKey::Problem(p) => {
                w.u8(1);
                w.u32(p);
            }
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<ShardKey, StoreError> {
        let tag = r.u8()?;
        let p = r.u32()?;
        match tag {
            0 => Ok(ShardKey::Global),
            1 => Ok(ShardKey::Problem(p)),
            t => Err(StoreError::Corrupt(format!("bad shard key tag {t}"))),
        }
    }
}

/// One draft request inside a `DraftBatch` frame.
#[derive(Debug, Clone, PartialEq)]
pub struct DraftReq {
    pub shard: ShardKey,
    pub context: Vec<TokenId>,
    pub max_match: usize,
    pub budget: usize,
}

/// The drafter-shape fingerprint a client presents at handshake. The
/// server refuses a client whose shard geometry differs from its own —
/// a shard indexed under a different window or depth cap answers
/// different drafts, and silent drift would break the remote ≡ local
/// bit-identity contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint {
    pub window: usize,
    pub match_len: usize,
    pub max_depth: usize,
    pub scope: String,
}

/// One `das-draft-rpc-v1` message. See the module docs for the table.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello { proto: String, fp: Fingerprint },
    HelloOk { epoch: Epoch },
    Absorb { shard: ShardKey, epoch: Epoch, tokens: Vec<TokenId> },
    RollEpoch { epoch: Epoch },
    Register { shard: u32, tokens: Vec<TokenId> },
    Publish,
    Published { snapshot: u64, epoch: Epoch },
    DraftBatch { snapshot: u64, reqs: Vec<DraftReq> },
    Drafts { drafts: Vec<Draft> },
    Ok,
    Err(String),
    Shutdown,
    Die,
}

const TAG_HELLO: u8 = 1;
const TAG_HELLO_OK: u8 = 2;
const TAG_ABSORB: u8 = 3;
const TAG_ROLL_EPOCH: u8 = 4;
const TAG_REGISTER: u8 = 5;
const TAG_PUBLISH: u8 = 6;
const TAG_PUBLISHED: u8 = 7;
const TAG_DRAFT_BATCH: u8 = 8;
const TAG_DRAFTS: u8 = 9;
const TAG_OK: u8 = 10;
const TAG_ERR: u8 = 11;
const TAG_SHUTDOWN: u8 = 12;
const TAG_DIE: u8 = 13;

/// Minimum encoded bytes of one `DraftReq` (shard 5 + empty token run 4 +
/// two u64 fields) — the pre-allocation bound for the batch count.
const MIN_REQ_BYTES: usize = 5 + 4 + 8 + 8;
/// Minimum encoded bytes of one `Draft` (empty token run 4 + confidence
/// count 8 + match_len 8).
const MIN_DRAFT_BYTES: usize = 4 + 8 + 8;

fn encode_draft(w: &mut Writer, d: &Draft) {
    w.tokens(&d.tokens);
    w.usize(d.confidence.len());
    for &c in &d.confidence {
        w.f64(f64::from(c));
    }
    w.usize(d.match_len);
}

fn decode_draft(r: &mut Reader<'_>) -> Result<Draft, StoreError> {
    let tokens = r.tokens()?;
    let n_conf = r.count(8)?;
    let mut confidence = Vec::with_capacity(n_conf);
    for _ in 0..n_conf {
        confidence.push(r.f64()? as f32);
    }
    let match_len = r.usize()?;
    Ok(Draft {
        tokens,
        confidence,
        match_len,
    })
}

impl Msg {
    /// Serialize one message body (tag + fields, no frame header).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            Msg::Hello { proto, fp } => {
                w.u8(TAG_HELLO);
                w.str(proto);
                w.usize(fp.window);
                w.usize(fp.match_len);
                w.usize(fp.max_depth);
                w.str(&fp.scope);
            }
            Msg::HelloOk { epoch } => {
                w.u8(TAG_HELLO_OK);
                w.u32(*epoch);
            }
            Msg::Absorb { shard, epoch, tokens } => {
                w.u8(TAG_ABSORB);
                shard.encode(&mut w);
                w.u32(*epoch);
                w.tokens(tokens);
            }
            Msg::RollEpoch { epoch } => {
                w.u8(TAG_ROLL_EPOCH);
                w.u32(*epoch);
            }
            Msg::Register { shard, tokens } => {
                w.u8(TAG_REGISTER);
                w.u32(*shard);
                w.tokens(tokens);
            }
            Msg::Publish => w.u8(TAG_PUBLISH),
            Msg::Published { snapshot, epoch } => {
                w.u8(TAG_PUBLISHED);
                w.u64(*snapshot);
                w.u32(*epoch);
            }
            Msg::DraftBatch { snapshot, reqs } => {
                w.u8(TAG_DRAFT_BATCH);
                w.u64(*snapshot);
                w.usize(reqs.len());
                for req in reqs {
                    req.shard.encode(&mut w);
                    w.tokens(&req.context);
                    w.usize(req.max_match);
                    w.usize(req.budget);
                }
            }
            Msg::Drafts { drafts } => {
                w.u8(TAG_DRAFTS);
                w.usize(drafts.len());
                for d in drafts {
                    encode_draft(&mut w, d);
                }
            }
            Msg::Ok => w.u8(TAG_OK),
            Msg::Err(detail) => {
                w.u8(TAG_ERR);
                w.str(detail);
            }
            Msg::Shutdown => w.u8(TAG_SHUTDOWN),
            Msg::Die => w.u8(TAG_DIE),
        }
        w.into_bytes()
    }

    /// Parse one message body. Every malformation — truncation at any
    /// byte, hostile counts, unknown tags, trailing bytes — is a typed
    /// [`StoreError`], never a panic.
    pub fn decode(body: &[u8]) -> Result<Msg, StoreError> {
        let mut r = Reader::new(body);
        let msg = match r.u8()? {
            TAG_HELLO => Msg::Hello {
                proto: r.str()?,
                fp: Fingerprint {
                    window: r.usize()?,
                    match_len: r.usize()?,
                    max_depth: r.usize()?,
                    scope: r.str()?,
                },
            },
            TAG_HELLO_OK => Msg::HelloOk { epoch: r.u32()? },
            TAG_ABSORB => Msg::Absorb {
                shard: ShardKey::decode(&mut r)?,
                epoch: r.u32()?,
                tokens: r.tokens()?,
            },
            TAG_ROLL_EPOCH => Msg::RollEpoch { epoch: r.u32()? },
            TAG_REGISTER => Msg::Register {
                shard: r.u32()?,
                tokens: r.tokens()?,
            },
            TAG_PUBLISH => Msg::Publish,
            TAG_PUBLISHED => Msg::Published {
                snapshot: r.u64()?,
                epoch: r.u32()?,
            },
            TAG_DRAFT_BATCH => {
                let snapshot = r.u64()?;
                let n = r.count(MIN_REQ_BYTES)?;
                let mut reqs = Vec::with_capacity(n);
                for _ in 0..n {
                    reqs.push(DraftReq {
                        shard: ShardKey::decode(&mut r)?,
                        context: r.tokens()?,
                        max_match: r.usize()?,
                        budget: r.usize()?,
                    });
                }
                Msg::DraftBatch { snapshot, reqs }
            }
            TAG_DRAFTS => {
                let n = r.count(MIN_DRAFT_BYTES)?;
                let mut drafts = Vec::with_capacity(n);
                for _ in 0..n {
                    drafts.push(decode_draft(&mut r)?);
                }
                Msg::Drafts { drafts }
            }
            TAG_OK => Msg::Ok,
            TAG_ERR => Msg::Err(r.str()?),
            TAG_SHUTDOWN => Msg::Shutdown,
            TAG_DIE => Msg::Die,
            t => return Err(StoreError::Corrupt(format!("unknown message tag {t}"))),
        };
        if !r.is_empty() {
            return Err(StoreError::Corrupt(format!(
                "trailing bytes after message ({} left)",
                r.remaining()
            )));
        }
        Ok(msg)
    }
}

/// Write one framed message: length prefix, body checksum, body.
pub fn write_frame(w: &mut impl std::io::Write, msg: &Msg) -> Result<(), StoreError> {
    let body = msg.encode();
    let mut frame = Vec::with_capacity(12 + body.len());
    frame.extend_from_slice(&len_u32(body.len()).to_le_bytes());
    frame.extend_from_slice(&checksum(&body).to_le_bytes());
    frame.extend_from_slice(&body);
    w.write_all(&frame)?;
    w.flush()?;
    Ok(())
}

/// Read one framed message. The length cap is enforced before the body
/// buffer is allocated, and the checksum before the body is parsed.
pub fn read_frame(r: &mut impl std::io::Read) -> Result<Msg, StoreError> {
    let mut head = [0u8; 12];
    r.read_exact(&mut head)?;
    let len = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
    let want = u64::from_le_bytes([
        head[4], head[5], head[6], head[7], head[8], head[9], head[10], head[11],
    ]);
    let len = usize::try_from(len)
        .map_err(|_| StoreError::Corrupt(format!("frame length overflow: {len}")))?;
    if len > MAX_FRAME {
        return Err(StoreError::Corrupt(format!(
            "frame length {len} exceeds the {MAX_FRAME}-byte cap"
        )));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    if checksum(&body) != want {
        return Err(StoreError::Corrupt("frame checksum mismatch".into()));
    }
    Msg::decode(&body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_messages() -> Vec<Msg> {
        vec![
            Msg::Hello {
                proto: PROTOCOL.to_string(),
                fp: Fingerprint {
                    window: 16,
                    match_len: 8,
                    max_depth: 72,
                    scope: "problem".to_string(),
                },
            },
            Msg::HelloOk { epoch: 3 },
            Msg::Absorb {
                shard: ShardKey::Problem(7),
                epoch: 2,
                tokens: vec![1, 2, 3, 4, 5],
            },
            Msg::Absorb {
                shard: ShardKey::Global,
                epoch: 0,
                tokens: vec![],
            },
            Msg::RollEpoch { epoch: 9 },
            Msg::Register {
                shard: 42,
                tokens: vec![5, 6, 7],
            },
            Msg::Publish,
            Msg::Published { snapshot: 11, epoch: 4 },
            Msg::DraftBatch {
                snapshot: 11,
                reqs: vec![
                    DraftReq {
                        shard: ShardKey::Problem(1),
                        context: vec![10, 11, 12],
                        max_match: 8,
                        budget: 16,
                    },
                    DraftReq {
                        shard: ShardKey::Global,
                        context: vec![],
                        max_match: 0,
                        budget: 0,
                    },
                ],
            },
            Msg::Drafts {
                drafts: vec![
                    Draft {
                        tokens: vec![13, 14],
                        confidence: vec![0.5, 0.25],
                        match_len: 3,
                    },
                    Draft::empty(),
                ],
            },
            Msg::Ok,
            Msg::Err("unknown snapshot".to_string()),
            Msg::Shutdown,
            Msg::Die,
        ]
    }

    fn frame_bytes(msg: &Msg) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, msg).expect("vec write cannot fail");
        out
    }

    #[test]
    fn every_message_roundtrips() {
        for msg in sample_messages() {
            let body = msg.encode();
            assert_eq!(Msg::decode(&body).expect("decode"), msg);
            let frame = frame_bytes(&msg);
            let got = read_frame(&mut &frame[..]).expect("framed roundtrip");
            assert_eq!(got, msg, "framed roundtrip");
        }
    }

    #[test]
    fn truncation_at_every_byte_is_a_typed_error_never_a_panic() {
        for msg in sample_messages() {
            let body = msg.encode();
            for cut in 0..body.len() {
                assert!(
                    Msg::decode(&body[..cut]).is_err(),
                    "{msg:?}: body cut at {cut} must error"
                );
            }
            let frame = frame_bytes(&msg);
            for cut in 0..frame.len() {
                assert!(
                    read_frame(&mut &frame[..cut]).is_err(),
                    "{msg:?}: frame cut at {cut} must error"
                );
            }
        }
    }

    #[test]
    fn single_bit_flips_never_decode_to_the_original() {
        // Every bit of every sample frame: a flip must surface as a typed
        // error (length/checksum/decode), never as the original message
        // and never as a panic. The checksum covers the whole body, so
        // body flips are always caught; header flips corrupt the length
        // or the checksum itself.
        for msg in sample_messages() {
            let frame = frame_bytes(&msg);
            for byte in 0..frame.len() {
                for bit in 0..8 {
                    let mut bad = frame.clone();
                    bad[byte] ^= 1 << bit;
                    match read_frame(&mut &bad[..]) {
                        Err(_) => {}
                        Ok(got) => {
                            assert_ne!(got, msg, "flip {byte}.{bit} went unnoticed");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        for msg in sample_messages() {
            let mut body = msg.encode();
            body.push(0);
            match Msg::decode(&body) {
                Err(StoreError::Corrupt(d)) => {
                    assert!(d.contains("trailing"), "{d}");
                }
                other => panic!("{msg:?}: expected Corrupt(trailing), got {other:?}"),
            }
        }
    }

    #[test]
    fn hostile_length_prefix_is_rejected_before_allocation() {
        // A frame header claiming a 4 GiB body must be refused from the
        // 12 header bytes alone.
        let mut head = Vec::new();
        head.extend_from_slice(&u32::MAX.to_le_bytes());
        head.extend_from_slice(&0u64.to_le_bytes());
        match read_frame(&mut &head[..]) {
            Err(StoreError::Corrupt(d)) => assert!(d.contains("cap"), "{d}"),
            other => panic!("expected Corrupt(cap), got {other:?}"),
        }
    }

    #[test]
    fn hostile_interior_counts_are_rejected_before_allocation() {
        // A DraftBatch body claiming u64::MAX requests in 8 spare bytes.
        let mut w = Writer::new();
        w.u8(8); // TAG_DRAFT_BATCH
        w.u64(0);
        w.u64(u64::MAX);
        assert!(matches!(
            Msg::decode(w.as_bytes()),
            Err(StoreError::Truncated) | Err(StoreError::Corrupt(_))
        ));
        // Same for a Drafts body.
        let mut w = Writer::new();
        w.u8(9); // TAG_DRAFTS
        w.u64(u64::MAX);
        assert!(matches!(
            Msg::decode(w.as_bytes()),
            Err(StoreError::Truncated) | Err(StoreError::Corrupt(_))
        ));
    }

    #[test]
    fn unknown_tags_and_bad_shard_keys_are_corrupt() {
        assert!(matches!(Msg::decode(&[200]), Err(StoreError::Corrupt(_))));
        let mut w = Writer::new();
        w.u8(3); // TAG_ABSORB
        w.u8(9); // bad shard key tag
        w.u32(0);
        w.u32(0);
        w.tokens(&[]);
        assert!(matches!(Msg::decode(w.as_bytes()), Err(StoreError::Corrupt(_))));
    }

    #[test]
    fn empty_body_is_truncated_not_a_panic() {
        assert!(Msg::decode(&[]).is_err());
    }
}

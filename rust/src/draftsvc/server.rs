//! The `das serve-drafts` daemon: one [`SuffixDrafter`] + optional
//! [`HistoryStore`] behind a TCP accept loop speaking `das-draft-rpc-v1`.
//!
//! Lifecycle: [`DraftServer::bind`] builds the drafter from the spec,
//! warm-starts it from the store directory (snapshot restore + WAL tail
//! replay, exactly the engine's recipe), opens the store for writing,
//! and binds the listener. [`DraftServer::run`] accepts connections and
//! spawns one handler thread per client — rollout workers hold their
//! connection for the whole run, so a sequential accept loop would
//! deadlock the fleet behind its first member.
//!
//! Single-writer rule: all mutations (`Absorb`/`RollEpoch`/`Register`)
//! are WAL-appended first and then applied under the one state lock.
//! Draft reads resolve a pinned published [`DrafterSnapshot`] `Arc`
//! under the lock, then draft *outside* it — readers never block the
//! writer beyond the pointer fetch, which is the PR 7 snapshot contract
//! carried over the wire. Store failures are counted and logged but
//! never stop serving: durability degrades, availability doesn't.

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::wire::{read_frame, write_frame, DraftReq, Fingerprint, Msg, ShardKey, PROTOCOL};
use crate::config::SpecConfig;
use crate::drafter::{Draft, Drafter, DrafterSnapshot, SuffixDrafter};
use crate::store::wire::StoreError;
use crate::store::{replay_wal, HistoryStore, WalRecord};
use crate::tokens::{Epoch, Rollout};

/// Published snapshots kept addressable by id. Clients repin every
/// mutation, so a short ring is plenty; an evicted id answers `Err` and
/// the client falls back to the live view.
const SNAPSHOT_RING: usize = 8;

struct ServerState {
    drafter: SuffixDrafter,
    store: Option<HistoryStore>,
    /// Published snapshots: (id, pinned view), newest at the back.
    snapshots: VecDeque<(u64, Arc<DrafterSnapshot>)>,
    next_snapshot: u64,
    /// Commit a full store snapshot every this many epoch rolls.
    snapshot_every: Epoch,
    epochs_since_snapshot: Epoch,
    store_failures: u64,
}

impl ServerState {
    fn wal_append(&mut self, record: &WalRecord) {
        if let Some(store) = self.store.as_mut() {
            if let Err(e) = store.append(record) {
                self.store_failures += 1;
                eprintln!("das-draftsvc: WAL append failed ({e}); serving without that record");
            }
        }
    }

    /// Pin the drafter's current snapshot under a fresh id (or the
    /// existing id when nothing mutated since the last publish — the
    /// drafter-level cache hands back the same `Arc`).
    fn publish(&mut self) -> Result<(u64, Epoch), StoreError> {
        let epoch = self.drafter.epoch();
        let Some(snap) = self.drafter.snapshot() else {
            return Err(StoreError::Unsupported("server drafter cannot snapshot"));
        };
        if let Some((id, last)) = self.snapshots.back() {
            if Arc::ptr_eq(last, &snap) {
                return Ok((*id, epoch));
            }
        }
        let id = self.next_snapshot;
        self.next_snapshot += 1;
        self.snapshots.push_back((id, snap));
        while self.snapshots.len() > SNAPSHOT_RING {
            self.snapshots.pop_front();
        }
        Ok((id, epoch))
    }

    /// Resolve a batch's snapshot id: 0 pins the live view now, anything
    /// else must still be in the ring.
    fn resolve(&mut self, id: u64) -> Result<Arc<DrafterSnapshot>, StoreError> {
        if id == 0 {
            return match self.drafter.snapshot() {
                Some(s) => Ok(s),
                None => Err(StoreError::Unsupported("server drafter cannot snapshot")),
            };
        }
        self.snapshots
            .iter()
            .find(|(sid, _)| *sid == id)
            .map(|(_, s)| Arc::clone(s))
            .ok_or_else(|| StoreError::Corrupt(format!("unknown snapshot id {id}")))
    }
}

/// The daemon: listener + shared state + stop latch.
pub struct DraftServer {
    listener: TcpListener,
    state: Arc<Mutex<ServerState>>,
    stop: Arc<AtomicBool>,
}

fn lock_state(state: &Arc<Mutex<ServerState>>) -> std::sync::MutexGuard<'_, ServerState> {
    // A handler that panicked mid-mutation leaves applied-or-not state no
    // worse than a client that died mid-stream; keep serving.
    state.lock().unwrap_or_else(|e| e.into_inner())
}

impl DraftServer {
    /// Build the drafter from `spec`, warm-start it from `dir` (when
    /// given), and bind `addr`. The spec must name a *local* substrate —
    /// a server whose own shards were remote would just be a loop.
    pub fn bind(spec: &SpecConfig, dir: Option<&Path>, addr: &str) -> Result<DraftServer, StoreError> {
        if spec.substrate == "remote" {
            return Err(StoreError::Unsupported(
                "serve-drafts needs a local substrate (window|tree|array), not 'remote'",
            ));
        }
        let mut drafter = SuffixDrafter::from_config(spec);
        // Warm start mirrors the engine: restore + replay from a read-only
        // view first, open for writing only once the state was accepted,
        // and degrade to serving without persistence on any store error.
        let store = match dir {
            None => None,
            Some(dir) => match HistoryStore::peek(dir) {
                Ok(view) => {
                    let restored = match &view.snapshot {
                        Some(snap) => match drafter.load_state(snap) {
                            Ok(()) => true,
                            Err(e) => {
                                eprintln!(
                                    "das-draftsvc: warm start from '{}' skipped ({e}); \
                                     serving cold without persistence",
                                    dir.display()
                                );
                                false
                            }
                        },
                        None => true,
                    };
                    if restored {
                        replay_wal(&mut drafter, &view.wal);
                        match HistoryStore::open(dir) {
                            Ok(store) => Some(store),
                            Err(e) => {
                                eprintln!(
                                    "das-draftsvc: cannot open '{}' for writing ({e}); \
                                     serving without persistence",
                                    dir.display()
                                );
                                None
                            }
                        }
                    } else {
                        None
                    }
                }
                Err(e) => {
                    eprintln!(
                        "das-draftsvc: cannot read '{}' ({e}); serving without persistence",
                        dir.display()
                    );
                    None
                }
            },
        };
        let listener = TcpListener::bind(addr)?;
        let snapshot_every = (spec.snapshot_every.min(Epoch::MAX as usize) as Epoch).max(1);
        Ok(DraftServer {
            listener,
            state: Arc::new(Mutex::new(ServerState {
                drafter,
                store,
                snapshots: VecDeque::new(),
                next_snapshot: 1,
                snapshot_every,
                epochs_since_snapshot: 0,
                store_failures: 0,
            })),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address ("127.0.0.1:PORT" after binding port 0).
    pub fn local_addr(&self) -> String {
        match self.listener.local_addr() {
            Ok(a) => a.to_string(),
            Err(_) => String::new(),
        }
    }

    /// The fingerprint this server accepts (for logs / tests).
    pub fn fingerprint(&self) -> Fingerprint {
        let g = lock_state(&self.state);
        Fingerprint {
            window: g.drafter.window(),
            match_len: g.drafter.match_len(),
            max_depth: g.drafter.max_depth(),
            scope: g.drafter.scope().as_str().to_string(),
        }
    }

    /// WAL/snapshot commits that failed so far (durability degradations).
    pub fn store_failures(&self) -> u64 {
        lock_state(&self.state).store_failures
    }

    /// Accept loop: one handler thread per connection, until stopped by
    /// a `Shutdown`/`Die` frame or [`DraftServer::stop`].
    pub fn run(&self) {
        for conn in self.listener.incoming() {
            // SeqCst: the stop latch is a rare, cold flag — the simplest
            // ordering keeps the accept loop trivially correct.
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let state = Arc::clone(&self.state);
            let stop = Arc::clone(&self.stop);
            let addr = self.local_addr();
            std::thread::spawn(move || handle_conn(stream, &state, &stop, &addr));
        }
    }

    /// Stop the accept loop from outside (tests, signal handlers).
    pub fn stop(&self) {
        // SeqCst: see run() — correctness over micro-cost on a cold path.
        self.stop.store(true, Ordering::SeqCst);
        wake_accept(&self.local_addr());
    }
}

/// The accept loop only re-checks the stop latch when a connection
/// lands; poke it with a throwaway dial so a stop takes effect now.
fn wake_accept(addr: &str) {
    if let Ok(stream) = TcpStream::connect(addr) {
        drop(stream);
    }
}

fn check_hello(state: &Arc<Mutex<ServerState>>, proto: &str, fp: &Fingerprint) -> Msg {
    if proto != PROTOCOL {
        return Msg::Err(format!("protocol '{proto}' not supported (server speaks {PROTOCOL})"));
    }
    let mut g = lock_state(state);
    let want = Fingerprint {
        window: g.drafter.window(),
        match_len: g.drafter.match_len(),
        max_depth: g.drafter.max_depth(),
        scope: g.drafter.scope().as_str().to_string(),
    };
    if *fp != want {
        return Msg::Err(format!(
            "drafter fingerprint mismatch: client {fp:?} vs server {want:?} — \
             remote drafts would not be bit-identical to local ones"
        ));
    }
    Msg::HelloOk { epoch: g.drafter.epoch() }
}

fn apply_absorb(g: &mut ServerState, shard: ShardKey, epoch: Epoch, tokens: Vec<u32>) {
    let problem = match shard {
        ShardKey::Global => 0,
        ShardKey::Problem(p) => p,
    };
    g.wal_append(&WalRecord::Absorb {
        problem,
        epoch,
        tokens: tokens.clone(),
    });
    g.drafter.observe_rollout(&Rollout {
        problem,
        epoch,
        step: 0,
        tokens,
        reward: 0.0,
    });
}

fn apply_roll_epoch(g: &mut ServerState, epoch: Epoch) {
    g.wal_append(&WalRecord::RollEpoch(epoch));
    g.drafter.roll_epoch(epoch);
    g.epochs_since_snapshot += 1;
    if g.epochs_since_snapshot >= g.snapshot_every {
        g.epochs_since_snapshot = 0;
        let payload = g.drafter.save_state();
        if let Some(store) = g.store.as_mut() {
            if let Err(e) = store.commit_snapshot(&payload) {
                g.store_failures += 1;
                eprintln!("das-draftsvc: snapshot commit failed ({e}); WAL keeps accumulating");
            }
        }
    }
}

fn run_batch(snap: &DrafterSnapshot, reqs: &[DraftReq]) -> Vec<Draft> {
    reqs.iter()
        .map(|req| {
            let shard = match req.shard {
                ShardKey::Global => None,
                ShardKey::Problem(p) => Some(p),
            };
            snap.shard_draft(shard, &req.context, req.max_match, req.budget)
        })
        .collect()
}

fn handle_conn(
    mut stream: TcpStream,
    state: &Arc<Mutex<ServerState>>,
    stop: &Arc<AtomicBool>,
    listen_addr: &str,
) {
    let _ = stream.set_nodelay(true);
    let mut greeted = false;
    loop {
        // SeqCst: cold stop latch, simplest ordering (see run()).
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let msg = match read_frame(&mut stream) {
            Ok(msg) => msg,
            Err(StoreError::Io(_)) => return, // client hung up / died
            Err(e) => {
                // Corrupt frame: answer typed, then drop the connection —
                // framing is lost, resync is the client's reconnect.
                let _ = write_frame(&mut stream, &Msg::Err(format!("bad frame: {e}")));
                return;
            }
        };
        let reply = match msg {
            Msg::Hello { proto, fp } => {
                let reply = check_hello(state, &proto, &fp);
                greeted = matches!(reply, Msg::HelloOk { .. });
                reply
            }
            _ if !greeted => Msg::Err("handshake required before any other message".to_string()),
            Msg::Absorb { shard, epoch, tokens } => {
                let mut g = lock_state(state);
                apply_absorb(&mut g, shard, epoch, tokens);
                Msg::Ok
            }
            Msg::RollEpoch { epoch } => {
                let mut g = lock_state(state);
                apply_roll_epoch(&mut g, epoch);
                Msg::Ok
            }
            Msg::Register { shard, tokens } => {
                let mut g = lock_state(state);
                g.wal_append(&WalRecord::Register {
                    shard,
                    tokens: tokens.clone(),
                });
                g.drafter.register_route(shard, &tokens);
                Msg::Ok
            }
            Msg::Publish => {
                let mut g = lock_state(state);
                match g.publish() {
                    Ok((snapshot, epoch)) => Msg::Published { snapshot, epoch },
                    Err(e) => Msg::Err(e.to_string()),
                }
            }
            Msg::DraftBatch { snapshot, reqs } => {
                // Pin the Arc under the lock, draft outside it: concurrent
                // batches read in parallel and never block a writer.
                let pinned = {
                    let mut g = lock_state(state);
                    g.resolve(snapshot)
                };
                match pinned {
                    Ok(snap) => Msg::Drafts {
                        drafts: run_batch(&snap, &reqs),
                    },
                    Err(e) => Msg::Err(e.to_string()),
                }
            }
            Msg::Shutdown => {
                // SeqCst: cold stop latch (see run()).
                stop.store(true, Ordering::SeqCst);
                let _ = write_frame(&mut stream, &Msg::Ok);
                wake_accept(listen_addr);
                return;
            }
            Msg::Die => {
                // Abrupt death for the chaos gate: no reply, no flush —
                // the client sees a dead socket mid-RPC, exactly like a
                // crashed daemon.
                // SeqCst: cold stop latch (see run()).
                stop.store(true, Ordering::SeqCst);
                wake_accept(listen_addr);
                return;
            }
            // Server-to-client shapes arriving here mean a confused peer.
            Msg::HelloOk { .. } | Msg::Published { .. } | Msg::Drafts { .. } | Msg::Ok | Msg::Err(_) => {
                Msg::Err("unexpected client frame".to_string())
            }
        };
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

//! Built-in configuration presets mirroring the paper's evaluated setups
//! (§5.1 math RL on DSR-sub, §5.2 code RL on DeepCoder), scaled to this
//! testbed per DESIGN.md §3, plus a tiny preset for the PJRT e2e examples.

use super::*;

pub fn preset_names() -> &'static [&'static str] {
    &["math_rl", "code_rl", "tiny_pjrt", "trace"]
}

pub fn preset(name: &str) -> Option<DasConfig> {
    match name {
        // §5.1: DeepSeek-R1-Distill-Qwen-7B on DSR-sub math. Long-tail heavy
        // (16k max tokens in the paper → scaled to 2048 virtual tokens with
        // the same lognormal tail shape).
        "math_rl" => Some(DasConfig {
            model: ModelConfig {
                vocab_size: 512,
                d_model: 128,
                n_layers: 2,
                n_heads: 4,
                max_seq_len: 2048,
                backend: "sim".into(),
                artifacts_dir: "artifacts".into(),
            },
            rollout: RolloutConfig {
                max_batch: 64,
                samples_per_problem: 8,
                max_new_tokens: 2048,
                temperature: 0.6,
                n_workers: 4,
                fault_plan: String::new(),
            },
            spec: SpecConfig {
                drafter: "das".into(),
                scope: "problem".into(),
                substrate: "window".into(),
                draft_addr: String::new(),
                draft_timeout_ms: 200,
                draft_retries: 2,
                window: 16,
                budget_policy: "length_aware".into(),
                budget_short: 0,
                budget_medium: 6,
                budget_long: 16,
                budget_cap: 64,
                prefix_router: false,
                router_capacity: 4096,
                match_len: 8,
                store_dir: String::new(),
                snapshot_every: 4,
                draft_threads: 0,
                resume_budget_boost: 2.0,
            },
            train: TrainConfig {
                steps: 30,
                problems_per_step: 16,
                lr: 1e-2,
                clip_eps: 0.2,
                kl_coef: 0.0,
            },
            workload: WorkloadConfig {
                kind: "math".into(),
                n_problems: 64,
                // lognormal(mu, sigma) over generated length: median ~400,
                // p99 ~ 2000 — the paper's "few long stragglers" shape.
                len_mu: 6.0,
                len_sigma: 0.75,
                drift: 0.03,
            },
            seed: 17,
        }),
        // §5.2: Qwen3-8B DeepCoder-style code RL. Shorter tail, smaller
        // effective batch, unit-test rewards from the stack-VM.
        "code_rl" => Some(DasConfig {
            model: ModelConfig {
                vocab_size: 512,
                d_model: 128,
                n_layers: 2,
                n_heads: 4,
                max_seq_len: 2048,
                backend: "sim".into(),
                artifacts_dir: "artifacts".into(),
            },
            rollout: RolloutConfig {
                max_batch: 16,
                samples_per_problem: 8,
                max_new_tokens: 2048,
                temperature: 0.6,
                n_workers: 4,
                fault_plan: String::new(),
            },
            spec: SpecConfig {
                drafter: "das".into(),
                scope: "problem".into(),
                substrate: "window".into(),
                draft_addr: String::new(),
                draft_timeout_ms: 200,
                draft_retries: 2,
                window: 16,
                budget_policy: "length_aware".into(),
                budget_short: 0,
                budget_medium: 4,
                budget_long: 12,
                budget_cap: 64,
                prefix_router: false,
                router_capacity: 4096,
                match_len: 6,
                store_dir: String::new(),
                snapshot_every: 4,
                draft_threads: 0,
                resume_budget_boost: 2.0,
            },
            train: TrainConfig {
                steps: 30,
                problems_per_step: 8,
                lr: 1e-2,
                clip_eps: 0.2,
                kl_coef: 0.0,
            },
            workload: WorkloadConfig {
                kind: "code".into(),
                n_problems: 32,
                len_mu: 5.6,
                len_sigma: 0.55,
                drift: 0.04,
            },
            seed: 23,
        }),
        // Real PJRT model for the end-to-end examples: geometry matches the
        // default export of python/compile/aot.py.
        "tiny_pjrt" => Some(DasConfig {
            model: ModelConfig {
                vocab_size: 64,
                d_model: 64,
                n_layers: 2,
                n_heads: 4,
                max_seq_len: 128,
                backend: "pjrt".into(),
                artifacts_dir: "artifacts".into(),
            },
            rollout: RolloutConfig {
                max_batch: 8,
                samples_per_problem: 4,
                max_new_tokens: 48,
                temperature: 0.8,
                n_workers: 1,
                fault_plan: String::new(),
            },
            spec: SpecConfig {
                drafter: "das".into(),
                scope: "problem".into(),
                substrate: "window".into(),
                draft_addr: String::new(),
                draft_timeout_ms: 200,
                draft_retries: 2,
                window: 8,
                budget_policy: "length_aware".into(),
                budget_short: 0,
                budget_medium: 4,
                budget_long: 7,
                budget_cap: 7,
                prefix_router: false,
                router_capacity: 512,
                match_len: 4,
                store_dir: String::new(),
                snapshot_every: 2,
                draft_threads: 0,
                resume_budget_boost: 2.0,
            },
            train: TrainConfig {
                steps: 40,
                problems_per_step: 8,
                lr: 1.2e-1,
                clip_eps: 0.2,
                kl_coef: 0.0,
            },
            workload: WorkloadConfig {
                kind: "math".into(),
                n_problems: 16,
                len_mu: 3.0,
                len_sigma: 0.4,
                drift: 0.05,
            },
            seed: 7,
        }),
        // Rollout-only serving over a recorded trace (no training).
        "trace" => Some(DasConfig {
            workload: WorkloadConfig {
                kind: "trace".into(),
                n_problems: 128,
                len_mu: 6.2,
                len_sigma: 0.8,
                drift: 0.05,
            },
            ..preset("math_rl").unwrap()
        }),
        _ => None,
    }
}

//! Typed configuration system.
//!
//! Everything the launcher can run — rollout-only serving, full RL training,
//! figure reproduction — is described by a [`DasConfig`], loadable from a
//! JSON file (`--config path`) with `--set key=value` dotted-path overrides,
//! in the spirit of MaxText/vLLM config systems. Presets mirror the paper's
//! two workloads (`math_rl`, `code_rl`).

use crate::util::json::Json;
use std::fmt;
use std::path::Path;

mod presets;
pub use presets::{preset, preset_names};

#[derive(Debug, Clone, PartialEq)]
pub struct DasConfig {
    pub model: ModelConfig,
    pub rollout: RolloutConfig,
    pub spec: SpecConfig,
    pub train: TrainConfig,
    pub workload: WorkloadConfig,
    pub seed: u64,
}

/// Policy model geometry — must match what `python/compile/aot.py` exported
/// (checked against `artifacts/meta.json` when the PJRT backend is used).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq_len: usize,
    /// "sim" (synthetic policy; virtual time) or "pjrt" (real AOT artifacts).
    pub backend: String,
    /// Directory with `*.hlo.txt` + `meta.json` for the pjrt backend.
    pub artifacts_dir: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct RolloutConfig {
    /// Max concurrent sequences in one decode batch (vLLM-style continuous
    /// batching slot count; also the compiled batch dim for pjrt).
    pub max_batch: usize,
    /// Samples drawn per problem per step (GRPO group size).
    pub samples_per_problem: usize,
    /// Hard cap on generated tokens per rollout.
    pub max_new_tokens: usize,
    pub temperature: f64,
    /// Data-parallel rollout workers (the supervised pool in
    /// `rollout/parallel.rs`). 1 = a single worker thread.
    pub n_workers: usize,
    /// Deterministic fault-injection plan (see `rollout/faults.rs` for the
    /// directive syntax). Empty = no injection; non-empty plans drive the
    /// chaos harness (`das train --fault-plan`) and chaos tests.
    pub fault_plan: String,
}

/// Speculation settings — the paper's §4 knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecConfig {
    /// Drafter: "das" (windowed per-problem suffix tree), "static" (frozen
    /// n-gram, the EAGLE analog), "none" (VeRL baseline).
    pub drafter: String,
    /// History scope for the suffix drafter: "problem", "problem+request",
    /// "global+request" (Fig 6).
    pub scope: String,
    /// Retrieval substrate behind the suffix drafter's history shards:
    /// "window" (fused epoch-tagged arena trie — the production path),
    /// "tree" (online Ukkonen tree, unbounded history), "array"
    /// (rebuild-per-insert suffix array — the Fig. 5 strawman), "remote"
    /// (shards served by a `das serve-drafts` daemon over the
    /// das-draft-rpc-v1 protocol; requires `draft_addr`). Every substrate
    /// is driven through the `DraftSource` trait.
    pub substrate: String,
    /// `host:port` of the draft daemon for the "remote" substrate
    /// (e.g. "127.0.0.1:7831"). Ignored by local substrates.
    pub draft_addr: String,
    /// Per-RPC connect/read/write timeout for the remote substrate, in
    /// milliseconds. Expiry counts a timeout and triggers the retry
    /// ladder; ladder exhaustion degrades that call to plain decoding.
    pub draft_timeout_ms: usize,
    /// Retries per remote RPC after the first attempt (bounded backoff
    /// between attempts). 0 = single attempt.
    pub draft_retries: usize,
    /// Sliding window size in epochs; 0 = unbounded ("window_all", Fig 7).
    pub window: usize,
    /// Budget policy: "length_aware" (the paper §4.2.3), "optimal" (Eq. 9
    /// solver), "uniform", "unlimited"
    /// (Fig 12 ablation).
    pub budget_policy: String,
    /// Draft tokens per round for the uniform policy / class budgets for the
    /// length-aware policy (short, medium, long).
    pub budget_short: usize,
    pub budget_medium: usize,
    pub budget_long: usize,
    /// Cap for "unlimited" (still bounded by the tree's match depth).
    pub budget_cap: usize,
    /// Enable the per-request prefix-trie router (§4.1.2: off for small
    /// models where routing overhead outweighs the gain).
    pub prefix_router: bool,
    /// Max generations the prefix router keeps registered per shard (FIFO
    /// eviction beyond it); 0 = unbounded. Bounds router memory on long
    /// serving runs.
    pub router_capacity: usize,
    /// Minimum context suffix length used as the tree query.
    pub match_len: usize,
    /// Directory for the persistent history store (snapshot + WAL of
    /// drafter state — see `rust/src/store/`). Empty = no persistence
    /// (the historical cold-start behavior). Data-parallel runs place one
    /// store per worker under `<store_dir>/worker<i>`.
    pub store_dir: String,
    /// Epochs between snapshot commits when the store is enabled (the WAL
    /// covers mutations in between, so recovery replays at most this many
    /// epochs of records). Must be >= 1.
    pub snapshot_every: usize,
    /// Reader threads for the snapshot draft path inside one engine step.
    /// 0 = auto (available parallelism, capped at 8), 1 = serial drafting
    /// against the live structures (the historical behavior), N > 1 = that
    /// many workers drafting against a published snapshot while the writer
    /// absorbs finished rollouts concurrently.
    pub draft_threads: usize,
    /// Speculative-budget multiplier for requests resumed after a
    /// preemption (checkpointed off a straggler, migrated to an idle
    /// worker). A migrated request is a known straggler, so drafting
    /// deeper is nearly free on the otherwise-idle destination. 1.0 = no
    /// escalation; clamped to [1, 8] and always bounded by
    /// `spec.budget_cap` at apply time.
    pub resume_budget_boost: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub problems_per_step: usize,
    pub lr: f64,
    /// GRPO clip epsilon.
    pub clip_eps: f64,
    /// KL penalty weight (0 disables).
    pub kl_coef: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// "math" | "code" | "trace".
    pub kind: String,
    pub n_problems: usize,
    /// Log-normal length distribution parameters for the simulated policy
    /// (chosen so a small fraction of rollouts dominates makespan).
    pub len_mu: f64,
    pub len_sigma: f64,
    /// Policy drift per step for the simulator (fraction of the canonical
    /// trajectory that mutates after each learner update).
    pub drift: f64,
}

#[derive(Debug, Clone)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl Default for DasConfig {
    fn default() -> Self {
        preset("math_rl").expect("math_rl preset exists")
    }
}

macro_rules! read_field {
    ($obj:expr, $root:expr, $section:literal, $key:literal, usize, $target:expr) => {
        if let Some(v) = $obj.get_path(concat!($section, ".", $key)) {
            $target = v
                .as_usize()
                .ok_or_else(|| ConfigError(format!("{}.{} must be a non-negative integer", $section, $key)))?;
        }
    };
    ($obj:expr, $root:expr, $section:literal, $key:literal, f64, $target:expr) => {
        if let Some(v) = $obj.get_path(concat!($section, ".", $key)) {
            $target = v
                .as_f64()
                .ok_or_else(|| ConfigError(format!("{}.{} must be a number", $section, $key)))?;
        }
    };
    ($obj:expr, $root:expr, $section:literal, $key:literal, bool, $target:expr) => {
        if let Some(v) = $obj.get_path(concat!($section, ".", $key)) {
            $target = v
                .as_bool()
                .ok_or_else(|| ConfigError(format!("{}.{} must be a bool", $section, $key)))?;
        }
    };
    ($obj:expr, $root:expr, $section:literal, $key:literal, string, $target:expr) => {
        if let Some(v) = $obj.get_path(concat!($section, ".", $key)) {
            $target = v
                .as_str()
                .ok_or_else(|| ConfigError(format!("{}.{} must be a string", $section, $key)))?
                .to_string();
        }
    };
}

impl DasConfig {
    /// Load from a JSON file, starting from the preset named by the file's
    /// `"preset"` field (default `math_rl`) and applying overrides on top.
    pub fn load(path: &Path) -> Result<DasConfig, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("cannot read {}: {e}", path.display())))?;
        Self::from_json_text(&text)
    }

    pub fn from_json_text(text: &str) -> Result<DasConfig, ConfigError> {
        let j = Json::parse(text).map_err(|e| ConfigError(e.to_string()))?;
        let base = j
            .get("preset")
            .and_then(|p| p.as_str())
            .unwrap_or("math_rl");
        let mut cfg = preset(base)
            .ok_or_else(|| ConfigError(format!("unknown preset '{base}'")))?;
        cfg.apply_json(&j)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply a parsed JSON object's fields over the current config.
    pub fn apply_json(&mut self, j: &Json) -> Result<(), ConfigError> {
        if let Some(v) = j.get("seed") {
            self.seed = v
                .as_i64()
                .ok_or_else(|| ConfigError("seed must be an integer".into()))? as u64;
        }
        read_field!(j, self, "model", "vocab_size", usize, self.model.vocab_size);
        read_field!(j, self, "model", "d_model", usize, self.model.d_model);
        read_field!(j, self, "model", "n_layers", usize, self.model.n_layers);
        read_field!(j, self, "model", "n_heads", usize, self.model.n_heads);
        read_field!(j, self, "model", "max_seq_len", usize, self.model.max_seq_len);
        read_field!(j, self, "model", "backend", string, self.model.backend);
        read_field!(j, self, "model", "artifacts_dir", string, self.model.artifacts_dir);

        read_field!(j, self, "rollout", "max_batch", usize, self.rollout.max_batch);
        read_field!(
            j,
            self,
            "rollout",
            "samples_per_problem",
            usize,
            self.rollout.samples_per_problem
        );
        read_field!(j, self, "rollout", "max_new_tokens", usize, self.rollout.max_new_tokens);
        read_field!(j, self, "rollout", "temperature", f64, self.rollout.temperature);
        read_field!(j, self, "rollout", "n_workers", usize, self.rollout.n_workers);
        read_field!(j, self, "rollout", "fault_plan", string, self.rollout.fault_plan);

        read_field!(j, self, "spec", "drafter", string, self.spec.drafter);
        read_field!(j, self, "spec", "scope", string, self.spec.scope);
        read_field!(j, self, "spec", "substrate", string, self.spec.substrate);
        read_field!(j, self, "spec", "draft_addr", string, self.spec.draft_addr);
        read_field!(j, self, "spec", "draft_timeout_ms", usize, self.spec.draft_timeout_ms);
        read_field!(j, self, "spec", "draft_retries", usize, self.spec.draft_retries);
        read_field!(j, self, "spec", "window", usize, self.spec.window);
        read_field!(j, self, "spec", "budget_policy", string, self.spec.budget_policy);
        read_field!(j, self, "spec", "budget_short", usize, self.spec.budget_short);
        read_field!(j, self, "spec", "budget_medium", usize, self.spec.budget_medium);
        read_field!(j, self, "spec", "budget_long", usize, self.spec.budget_long);
        read_field!(j, self, "spec", "budget_cap", usize, self.spec.budget_cap);
        read_field!(j, self, "spec", "prefix_router", bool, self.spec.prefix_router);
        read_field!(j, self, "spec", "router_capacity", usize, self.spec.router_capacity);
        read_field!(j, self, "spec", "match_len", usize, self.spec.match_len);
        read_field!(j, self, "spec", "store_dir", string, self.spec.store_dir);
        read_field!(j, self, "spec", "snapshot_every", usize, self.spec.snapshot_every);
        read_field!(j, self, "spec", "draft_threads", usize, self.spec.draft_threads);
        read_field!(
            j,
            self,
            "spec",
            "resume_budget_boost",
            f64,
            self.spec.resume_budget_boost
        );

        read_field!(j, self, "train", "steps", usize, self.train.steps);
        read_field!(j, self, "train", "problems_per_step", usize, self.train.problems_per_step);
        read_field!(j, self, "train", "lr", f64, self.train.lr);
        read_field!(j, self, "train", "clip_eps", f64, self.train.clip_eps);
        read_field!(j, self, "train", "kl_coef", f64, self.train.kl_coef);

        read_field!(j, self, "workload", "kind", string, self.workload.kind);
        read_field!(j, self, "workload", "n_problems", usize, self.workload.n_problems);
        read_field!(j, self, "workload", "len_mu", f64, self.workload.len_mu);
        read_field!(j, self, "workload", "len_sigma", f64, self.workload.len_sigma);
        read_field!(j, self, "workload", "drift", f64, self.workload.drift);
        Ok(())
    }

    /// Apply a `--set section.key=value` style override.
    pub fn set(&mut self, assignment: &str) -> Result<(), ConfigError> {
        let (path, value) = assignment
            .split_once('=')
            .ok_or_else(|| ConfigError(format!("--set expects key=value, got '{assignment}'")))?;
        // Build a nested JSON object for the single key and reuse apply_json.
        let parts: Vec<&str> = path.split('.').collect();
        let leaf: Json = if value == "true" || value == "false" {
            Json::Bool(value == "true")
        } else if let Ok(n) = value.parse::<f64>() {
            Json::Num(n)
        } else {
            Json::Str(value.to_string())
        };
        let mut node = leaf;
        for part in parts.iter().rev() {
            node = Json::obj(vec![(part, node)]);
        }
        self.apply_json(&node)?;
        self.validate()
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let e = |m: String| Err(ConfigError(m));
        if self.model.vocab_size < 8 {
            return e("model.vocab_size must be >= 8".into());
        }
        if self.model.d_model % self.model.n_heads != 0 {
            return e(format!(
                "model.d_model ({}) must be divisible by n_heads ({})",
                self.model.d_model, self.model.n_heads
            ));
        }
        if !matches!(self.model.backend.as_str(), "sim" | "pjrt") {
            return e(format!("model.backend must be sim|pjrt, got '{}'", self.model.backend));
        }
        if self.rollout.max_batch == 0 || self.rollout.max_new_tokens == 0 {
            return e("rollout.max_batch and max_new_tokens must be > 0".into());
        }
        if self.rollout.temperature < 0.0 {
            return e("rollout.temperature must be >= 0".into());
        }
        if self.rollout.n_workers == 0 {
            return e("rollout.n_workers must be >= 1".into());
        }
        match crate::rollout::faults::FaultPlan::parse(&self.rollout.fault_plan) {
            Err(m) => return e(format!("rollout.fault_plan invalid: {m}")),
            // Syntax check only — this plan is never installed, so its
            // drop-time unfired audit must stay quiet.
            Ok(p) => p.disarm_drop_audit(),
        }
        if !matches!(self.spec.drafter.as_str(), "das" | "static" | "none") {
            return e(format!("spec.drafter must be das|static|none, got '{}'", self.spec.drafter));
        }
        if !matches!(
            self.spec.scope.as_str(),
            "problem" | "problem+request" | "global+request"
        ) {
            return e(format!("spec.scope invalid: '{}'", self.spec.scope));
        }
        if !matches!(
            self.spec.substrate.as_str(),
            "window" | "tree" | "array" | "remote"
        ) {
            return e(format!(
                "spec.substrate must be window|tree|array|remote, got '{}'",
                self.spec.substrate
            ));
        }
        if self.spec.substrate == "remote" {
            if self.spec.draft_addr.is_empty() {
                return e("spec.substrate=remote requires spec.draft_addr (host:port)".into());
            }
            if !self.spec.store_dir.is_empty() {
                return e(
                    "spec.substrate=remote is incompatible with spec.store_dir: \
                     the serve-drafts daemon owns the store"
                        .into(),
                );
            }
        }
        if self.spec.draft_timeout_ms == 0 {
            return e("spec.draft_timeout_ms must be >= 1".into());
        }
        if !matches!(
            self.spec.budget_policy.as_str(),
            "length_aware" | "optimal" | "uniform" | "unlimited"
        ) {
            return e(format!("spec.budget_policy invalid: '{}'", self.spec.budget_policy));
        }
        if self.spec.budget_long < self.spec.budget_medium {
            return e("spec.budget_long must be >= budget_medium".into());
        }
        // A tiny bounded router thrashes (every new generation evicts the
        // previous one before it can ever be routed to); require a sane
        // floor when a bound is set at all.
        if self.spec.router_capacity != 0 && self.spec.router_capacity < 4 {
            return e(format!(
                "spec.router_capacity must be 0 (unbounded) or >= 4, got {}",
                self.spec.router_capacity
            ));
        }
        if self.spec.snapshot_every == 0 {
            return e("spec.snapshot_every must be >= 1".into());
        }
        if !self.spec.resume_budget_boost.is_finite()
            || !(1.0..=8.0).contains(&self.spec.resume_budget_boost)
        {
            return e(format!(
                "spec.resume_budget_boost must be a finite number in [1, 8], got {}",
                self.spec.resume_budget_boost
            ));
        }
        if !matches!(self.workload.kind.as_str(), "math" | "code" | "trace") {
            return e(format!(
                "workload.kind must be math|code|trace, got '{}'",
                self.workload.kind
            ));
        }
        if self.workload.n_problems == 0 {
            return e("workload.n_problems must be > 0".into());
        }
        Ok(())
    }

    /// Serialize the resolved config (for logging / EXPERIMENTS.md records).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            (
                "model",
                Json::obj(vec![
                    ("vocab_size", Json::num(self.model.vocab_size as f64)),
                    ("d_model", Json::num(self.model.d_model as f64)),
                    ("n_layers", Json::num(self.model.n_layers as f64)),
                    ("n_heads", Json::num(self.model.n_heads as f64)),
                    ("max_seq_len", Json::num(self.model.max_seq_len as f64)),
                    ("backend", Json::str(&self.model.backend)),
                    ("artifacts_dir", Json::str(&self.model.artifacts_dir)),
                ]),
            ),
            (
                "rollout",
                Json::obj(vec![
                    ("max_batch", Json::num(self.rollout.max_batch as f64)),
                    (
                        "samples_per_problem",
                        Json::num(self.rollout.samples_per_problem as f64),
                    ),
                    ("max_new_tokens", Json::num(self.rollout.max_new_tokens as f64)),
                    ("temperature", Json::num(self.rollout.temperature)),
                    ("n_workers", Json::num(self.rollout.n_workers as f64)),
                    ("fault_plan", Json::str(&self.rollout.fault_plan)),
                ]),
            ),
            (
                "spec",
                Json::obj(vec![
                    ("drafter", Json::str(&self.spec.drafter)),
                    ("scope", Json::str(&self.spec.scope)),
                    ("substrate", Json::str(&self.spec.substrate)),
                    ("draft_addr", Json::str(&self.spec.draft_addr)),
                    ("draft_timeout_ms", Json::num(self.spec.draft_timeout_ms as f64)),
                    ("draft_retries", Json::num(self.spec.draft_retries as f64)),
                    ("window", Json::num(self.spec.window as f64)),
                    ("budget_policy", Json::str(&self.spec.budget_policy)),
                    ("budget_short", Json::num(self.spec.budget_short as f64)),
                    ("budget_medium", Json::num(self.spec.budget_medium as f64)),
                    ("budget_long", Json::num(self.spec.budget_long as f64)),
                    ("budget_cap", Json::num(self.spec.budget_cap as f64)),
                    ("prefix_router", Json::Bool(self.spec.prefix_router)),
                    ("router_capacity", Json::num(self.spec.router_capacity as f64)),
                    ("match_len", Json::num(self.spec.match_len as f64)),
                    ("store_dir", Json::str(&self.spec.store_dir)),
                    ("snapshot_every", Json::num(self.spec.snapshot_every as f64)),
                    ("draft_threads", Json::num(self.spec.draft_threads as f64)),
                    (
                        "resume_budget_boost",
                        Json::num(self.spec.resume_budget_boost),
                    ),
                ]),
            ),
            (
                "train",
                Json::obj(vec![
                    ("steps", Json::num(self.train.steps as f64)),
                    ("problems_per_step", Json::num(self.train.problems_per_step as f64)),
                    ("lr", Json::num(self.train.lr)),
                    ("clip_eps", Json::num(self.train.clip_eps)),
                    ("kl_coef", Json::num(self.train.kl_coef)),
                ]),
            ),
            (
                "workload",
                Json::obj(vec![
                    ("kind", Json::str(&self.workload.kind)),
                    ("n_problems", Json::num(self.workload.n_problems as f64)),
                    ("len_mu", Json::num(self.workload.len_mu)),
                    ("len_sigma", Json::num(self.workload.len_sigma)),
                    ("drift", Json::num(self.workload.drift)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        DasConfig::default().validate().unwrap();
    }

    #[test]
    fn all_presets_valid() {
        for name in preset_names() {
            preset(name).unwrap().validate().unwrap();
        }
    }

    #[test]
    fn json_overrides_apply() {
        let cfg = DasConfig::from_json_text(
            r#"{"preset": "code_rl", "spec": {"window": 8, "drafter": "static"},
                "rollout": {"temperature": 0.9}}"#,
        )
        .unwrap();
        assert_eq!(cfg.spec.window, 8);
        assert_eq!(cfg.spec.drafter, "static");
        assert!((cfg.rollout.temperature - 0.9).abs() < 1e-12);
        assert_eq!(cfg.workload.kind, "code");
    }

    #[test]
    fn set_override() {
        let mut cfg = DasConfig::default();
        cfg.set("spec.budget_long=24").unwrap();
        assert_eq!(cfg.spec.budget_long, 24);
        cfg.set("model.backend=pjrt").unwrap();
        assert_eq!(cfg.model.backend, "pjrt");
        assert!(cfg.set("spec.drafter=bogus").is_err());
        assert!(cfg.set("no_equals_sign").is_err());
    }

    #[test]
    fn router_capacity_parsed_and_validated() {
        let cfg =
            DasConfig::from_json_text(r#"{"spec": {"router_capacity": 64}}"#).unwrap();
        assert_eq!(cfg.spec.router_capacity, 64);
        let mut cfg = DasConfig::default();
        cfg.set("spec.router_capacity=128").unwrap();
        assert_eq!(cfg.spec.router_capacity, 128);
        cfg.set("spec.router_capacity=0").unwrap(); // unbounded is fine
        assert!(cfg.set("spec.router_capacity=2").is_err(), "thrashing bound rejected");
    }

    #[test]
    fn store_settings_parsed_and_validated() {
        let cfg = DasConfig::from_json_text(
            r#"{"spec": {"store_dir": "/tmp/das-store", "snapshot_every": 8}}"#,
        )
        .unwrap();
        assert_eq!(cfg.spec.store_dir, "/tmp/das-store");
        assert_eq!(cfg.spec.snapshot_every, 8);
        let mut cfg = DasConfig::default();
        assert!(cfg.spec.store_dir.is_empty(), "persistence is opt-in");
        cfg.set("spec.store_dir=run1/store").unwrap();
        assert_eq!(cfg.spec.store_dir, "run1/store");
        assert!(cfg.set("spec.snapshot_every=0").is_err(), "zero cadence rejected");
        cfg.set("spec.snapshot_every=2").unwrap();
        assert_eq!(cfg.spec.snapshot_every, 2);
    }

    #[test]
    fn substrate_parsed_and_validated() {
        let cfg = DasConfig::from_json_text(r#"{"spec": {"substrate": "tree"}}"#).unwrap();
        assert_eq!(cfg.spec.substrate, "tree");
        let mut cfg = DasConfig::default();
        assert_eq!(cfg.spec.substrate, "window");
        cfg.set("spec.substrate=array").unwrap();
        assert_eq!(cfg.spec.substrate, "array");
        assert!(cfg.set("spec.substrate=bogus").is_err());
    }

    #[test]
    fn remote_substrate_parsed_and_validated() {
        let cfg = DasConfig::from_json_text(
            r#"{"spec": {"substrate": "remote", "draft_addr": "127.0.0.1:7831",
                "draft_timeout_ms": 50, "draft_retries": 1}}"#,
        )
        .unwrap();
        assert_eq!(cfg.spec.substrate, "remote");
        assert_eq!(cfg.spec.draft_addr, "127.0.0.1:7831");
        assert_eq!(cfg.spec.draft_timeout_ms, 50);
        assert_eq!(cfg.spec.draft_retries, 1);

        let mut cfg = DasConfig::default();
        assert!(cfg.spec.draft_addr.is_empty(), "remote drafting is opt-in");
        // Remote without an address is unusable.
        cfg.spec.substrate = "remote".into();
        assert!(cfg.validate().is_err(), "remote requires draft_addr");
        cfg.spec.draft_addr = "127.0.0.1:7831".into();
        cfg.validate().unwrap();
        // The daemon owns the store; a client-side store dir is a
        // configuration contradiction, not a merge.
        cfg.spec.store_dir = "run1/store".into();
        assert!(cfg.validate().is_err(), "remote client must not own a store");
        cfg.spec.store_dir.clear();
        assert!(cfg.set("spec.draft_timeout_ms=0").is_err(), "zero timeout rejected");
        cfg.set("spec.draft_retries=0").unwrap(); // single attempt is legal
    }

    #[test]
    fn draft_threads_parsed_with_auto_default() {
        let mut cfg = DasConfig::default();
        assert_eq!(cfg.spec.draft_threads, 0, "auto is the default");
        cfg.set("spec.draft_threads=4").unwrap();
        assert_eq!(cfg.spec.draft_threads, 4);
        let cfg = DasConfig::from_json_text(r#"{"spec": {"draft_threads": 1}}"#).unwrap();
        assert_eq!(cfg.spec.draft_threads, 1);
    }

    #[test]
    fn supervision_settings_parsed_and_validated() {
        let cfg = DasConfig::from_json_text(
            r#"{"rollout": {"n_workers": 8, "fault_plan": "panic worker=1 step=3"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.rollout.n_workers, 8);
        assert_eq!(cfg.rollout.fault_plan, "panic worker=1 step=3");
        let mut cfg = DasConfig::default();
        assert!(cfg.rollout.fault_plan.is_empty(), "injection is opt-in");
        cfg.set("rollout.fault_plan=store-fail epoch=2").unwrap();
        assert_eq!(cfg.rollout.fault_plan, "store-fail epoch=2");
        assert!(cfg.set("rollout.fault_plan=reboot now").is_err(), "plans are validated");
        assert!(cfg.set("rollout.n_workers=0").is_err(), "zero workers rejected");
    }

    #[test]
    fn resume_budget_boost_parsed_and_clamped() {
        let mut cfg = DasConfig::default();
        assert!(
            cfg.spec.resume_budget_boost >= 1.0,
            "presets escalate resumed stragglers"
        );
        cfg.set("spec.resume_budget_boost=1.5").unwrap();
        assert!((cfg.spec.resume_budget_boost - 1.5).abs() < 1e-12);
        cfg.set("spec.resume_budget_boost=1").unwrap(); // no escalation is legal
        assert!(cfg.set("spec.resume_budget_boost=0.5").is_err(), "shrinking rejected");
        assert!(cfg.set("spec.resume_budget_boost=9").is_err(), "runaway rejected");
        assert!(cfg.set("spec.resume_budget_boost=nan").is_err(), "non-finite rejected");
        let cfg = DasConfig::from_json_text(r#"{"spec": {"resume_budget_boost": 3.0}}"#)
            .unwrap();
        assert!((cfg.spec.resume_budget_boost - 3.0).abs() < 1e-12);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = DasConfig::default();
        cfg.model.d_model = 100;
        cfg.model.n_heads = 3;
        assert!(cfg.validate().is_err());

        let mut cfg = DasConfig::default();
        cfg.spec.scope = "nope".into();
        assert!(cfg.validate().is_err());

        let mut cfg = DasConfig::default();
        cfg.spec.budget_long = 1;
        cfg.spec.budget_medium = 8;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn unknown_preset_rejected() {
        assert!(DasConfig::from_json_text(r#"{"preset": "nonexistent"}"#).is_err());
    }

    #[test]
    fn roundtrip_via_json() {
        let cfg = preset("code_rl").unwrap();
        let text = cfg.to_json().to_string();
        let mut cfg2 = preset("code_rl").unwrap();
        cfg2.apply_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(cfg, cfg2);
    }
}

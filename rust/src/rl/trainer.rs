//! GRPO training loop — the actor/learner cycle DAS plugs into.
//!
//! One step = generation (the DAS-accelerated rollout phase) → reward
//! labeling (verifiable: answer match or VM unit tests) → policy update
//! (real `train_step` HLO for the PJRT backend; calibrated sharpen+drift
//! for the simulator). The speculation layer never touches rewards or the
//! optimizer — exactly the paper's "plugs into this loop without changing
//! the reward model or optimizer".

use crate::config::DasConfig;
use crate::history::RolloutHistory;
use crate::model::sim::SimModel;
use crate::model::TargetModel;
use crate::rollout::{GenJob, RolloutEngine, StepMetrics};
#[cfg(feature = "pjrt")]
use crate::runtime::PjrtModel;
use crate::tokens::{Epoch, Rollout};
use crate::util::rng::Rng;
use crate::workload::{Problem, TaskSpec, Workload};

use super::reward::{group_advantages, score};

/// Per-step training statistics (the series plotted in Figs. 10–13).
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: u32,
    pub epoch: Epoch,
    pub reward: f64,
    pub loss: f64,
    pub metrics: StepMetrics,
}

pub struct Trainer {
    pub cfg: DasConfig,
    pub engine: RolloutEngine,
    pub workload: Workload,
    pub history: RolloutHistory,
    /// Keep full rollout history for similarity analysis (figures); can be
    /// disabled for long runs.
    pub record_history: bool,
    cursor: usize,
    rng: Rng,
}

impl Trainer {
    pub fn new(cfg: DasConfig) -> Self {
        let workload = Workload::from_config(&cfg);
        let engine = RolloutEngine::new(&cfg, crate::drafter::from_config(&cfg));
        let rng = Rng::seed_from_u64(cfg.seed ^ 0x7124_1EAF);
        Trainer {
            cfg,
            engine,
            workload,
            history: RolloutHistory::new(),
            record_history: true,
            cursor: 0,
            rng,
        }
    }

    /// Round-robin problem selection: every problem is revisited every
    /// `n_problems / problems_per_step` steps (the dataset-revisit structure
    /// that makes per-problem suffix trees work).
    fn select_problems(&mut self) -> Vec<usize> {
        let n = self.workload.problems.len();
        let k = self.cfg.train.problems_per_step.min(n);
        let mut idxs = Vec::with_capacity(k);
        for _ in 0..k {
            idxs.push(self.cursor % n);
            self.cursor += 1;
        }
        idxs
    }

    fn epoch_of(&self, cursor_before: usize) -> Epoch {
        (cursor_before / self.workload.problems.len().max(1)) as Epoch
    }

    fn jobs_for(&self, idxs: &[usize]) -> Vec<GenJob> {
        idxs.iter()
            .map(|&i| {
                let p = &self.workload.problems[i];
                GenJob {
                    problem: p.id,
                    prompt: p.prompt.clone(),
                    samples: self.cfg.rollout.samples_per_problem,
                }
            })
            .collect()
    }

    fn label_rewards(
        &mut self,
        rollouts: &mut [Rollout],
        eos: u32,
        sim: Option<&SimModel>,
    ) -> f64 {
        // Resolve sim answers lazily (they live in the sim's canonical state).
        for r in rollouts.iter_mut() {
            let p = &self.workload.problems[r.problem as usize % self.workload.problems.len()];
            let reward = match (&p.task, sim) {
                (TaskSpec::MatchAnswer { .. }, Some(m)) => {
                    let answer = m.answer(r.problem).to_vec();
                    let tmp = Problem {
                        task: TaskSpec::MatchAnswer { answer },
                        ..p.clone()
                    };
                    score(&tmp, r, eos)
                }
                _ => score(p, r, eos),
            };
            r.reward = reward;
        }
        let vals: Vec<f64> = rollouts.iter().map(|r| r.reward).collect();
        crate::util::stats::mean(&vals)
    }

    fn record(&mut self, rollouts: &[Rollout]) {
        if self.record_history {
            for r in rollouts {
                self.history.add(r);
            }
        }
    }

    /// Install workload-provided canonical trajectories (e.g. correct VM
    /// programs) into the sim policy. Idempotent; called lazily by
    /// `step_sim`.
    pub fn prepare_sim(&self, model: &mut SimModel) {
        let vocab = self.cfg.model.vocab_size as u32;
        for p in &self.workload.problems {
            if let Some(canonical) = &p.canonical {
                // Filler tokens drift inside the no-op range; program tokens
                // are frozen by the mutable mask.
                model.set_canonical(
                    p.id,
                    canonical.clone(),
                    1,
                    p.mutable.clone(),
                    (crate::rl::vm::OP_MAX, vocab - 1),
                );
            }
        }
    }

    /// One full RL step on the SIMULATED policy.
    pub fn step_sim(&mut self, model: &mut SimModel, step: u32) -> StepStats {
        if step == 0 {
            self.prepare_sim(model);
        }
        let cursor_before = self.cursor;
        let idxs = self.select_problems();
        let epoch = self.epoch_of(cursor_before);
        self.engine.roll_epoch(epoch);
        let jobs = self.jobs_for(&idxs);
        let mut report = self.engine.generate_step(model, &jobs, step);
        let reward = self.label_rewards(&mut report.rollouts, model.eos(), Some(model));
        self.record(&report.rollouts);
        // Learner update: the sim policy sharpens toward its canonical
        // trajectories and drifts — the Insight-3 dynamics.
        model.policy_update(1.0);
        StepStats {
            step,
            epoch,
            reward,
            loss: -reward, // surrogate for plotting; the sim has no real loss
            metrics: report.metrics,
        }
    }

    /// One full RL step on the REAL PJRT policy (true gradients).
    #[cfg(feature = "pjrt")]
    pub fn step_pjrt(&mut self, model: &mut PjrtModel, step: u32) -> StepStats {
        let cursor_before = self.cursor;
        let idxs = self.select_problems();
        let epoch = self.epoch_of(cursor_before);
        self.engine.roll_epoch(epoch);
        let jobs = self.jobs_for(&idxs);
        let mut report = self.engine.generate_step(model, &jobs, step);
        let reward = self.label_rewards(&mut report.rollouts, model.eos(), None);
        self.record(&report.rollouts);

        // Group-normalized advantages per problem (GRPO).
        let mut advantages = vec![0.0f64; report.rollouts.len()];
        for &i in &idxs {
            let pid = self.workload.problems[i].id;
            let group: Vec<usize> = report
                .rollouts
                .iter()
                .enumerate()
                .filter(|(_, r)| r.problem == pid)
                .map(|(j, _)| j)
                .collect();
            let rewards: Vec<f64> = group.iter().map(|&j| report.rollouts[j].reward).collect();
            for (j, a) in group.iter().zip(group_advantages(&rewards)) {
                advantages[*j] = a;
            }
        }

        // Pack micro-batches of the compiled train batch size.
        let b = model.batch_capacity();
        let s = model.meta.max_seq_len;
        let mut loss_acc = 0.0;
        let mut micro = 0usize;
        let mut order: Vec<usize> = (0..report.rollouts.len()).collect();
        self.rng.shuffle(&mut order);
        for chunk in order.chunks(b) {
            let mut tokens = vec![0i32; b * s];
            let mut mask = vec![0f32; b * s];
            let mut adv = vec![0f32; b];
            for (row, &j) in chunk.iter().enumerate() {
                let r = &report.rollouts[j];
                let p =
                    &self.workload.problems[r.problem as usize % self.workload.problems.len()];
                let mut col = 0usize;
                for &t in p.prompt.iter().chain(r.tokens.iter()) {
                    if col >= s {
                        break;
                    }
                    tokens[row * s + col] = t as i32;
                    if col >= p.prompt.len() {
                        mask[row * s + col] = 1.0;
                    }
                    col += 1;
                }
                adv[row] = advantages[j] as f32;
            }
            let loss = model
                .train_step(&tokens, &mask, &adv, self.cfg.train.lr as f32)
                .expect("train step failed");
            loss_acc += loss as f64;
            micro += 1;
        }
        StepStats {
            step,
            epoch,
            reward,
            loss: if micro > 0 { loss_acc / micro as f64 } else { 0.0 },
            metrics: report.metrics,
        }
    }

    /// Run `steps` sim-backend steps, returning per-step stats.
    pub fn run_sim(&mut self, model: &mut SimModel, steps: usize) -> Vec<StepStats> {
        (0..steps).map(|s| self.step_sim(model, s as u32)).collect()
    }

    #[cfg(feature = "pjrt")]
    pub fn run_pjrt(&mut self, model: &mut PjrtModel, steps: usize) -> Vec<StepStats> {
        (0..steps).map(|s| self.step_pjrt(model, s as u32)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sim::SimModelConfig;

    fn small_cfg(drafter: &str) -> DasConfig {
        let mut c = DasConfig::default();
        c.model.vocab_size = 64;
        c.workload.n_problems = 8;
        c.workload.len_mu = 3.2;
        c.workload.len_sigma = 0.4;
        c.rollout.max_new_tokens = 96;
        c.rollout.max_batch = 8;
        c.rollout.samples_per_problem = 4;
        c.train.problems_per_step = 4;
        c.spec.drafter = drafter.into();
        c
    }

    #[test]
    fn sim_training_improves_reward() {
        let cfg = small_cfg("das");
        let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
        let mut t = Trainer::new(cfg);
        let stats = t.run_sim(&mut model, 24);
        let early: f64 =
            stats[..4].iter().map(|s| s.reward).sum::<f64>() / 4.0;
        let late: f64 =
            stats[stats.len() - 4..].iter().map(|s| s.reward).sum::<f64>() / 4.0;
        assert!(
            late > early + 0.1,
            "reward should rise during training: early={early:.3} late={late:.3}"
        );
    }

    #[test]
    fn sim_code_training_improves_unit_test_rewards() {
        // The full code path: canonical VM programs installed into the sim
        // policy, rewards from REAL program execution, drift confined to
        // no-op filler so late-training rewards approach 1.
        let mut cfg = small_cfg("das");
        cfg.workload.kind = "code".into();
        cfg.workload.len_mu = 3.0;
        cfg.workload.len_sigma = 0.3;
        let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
        let mut t = Trainer::new(cfg);
        let stats = t.run_sim(&mut model, 24);
        let early: f64 = stats[..4].iter().map(|s| s.reward).sum::<f64>() / 4.0;
        let late: f64 =
            stats[stats.len() - 4..].iter().map(|s| s.reward).sum::<f64>() / 4.0;
        assert!(
            late > early + 0.1 && late > 0.5,
            "code reward should rise: early={early:.3} late={late:.3}"
        );
    }

    #[test]
    fn epochs_advance_with_dataset_passes() {
        let cfg = small_cfg("das"); // 8 problems, 4/step -> epoch bumps every 2 steps
        let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
        let mut t = Trainer::new(cfg);
        let stats = t.run_sim(&mut model, 6);
        assert_eq!(stats[0].epoch, 0);
        assert_eq!(stats[1].epoch, 0);
        assert_eq!(stats[2].epoch, 1);
        assert_eq!(stats[5].epoch, 2);
    }

    #[test]
    fn das_and_baseline_rewards_match_greedy() {
        // Lossless check at the training level: same rewards at T=0.
        let mut cfg_a = small_cfg("none");
        cfg_a.rollout.temperature = 0.0;
        let mut cfg_b = small_cfg("das");
        cfg_b.rollout.temperature = 0.0;
        let mut ma = SimModel::new(SimModelConfig::from_das(&cfg_a));
        let mut mb = SimModel::new(SimModelConfig::from_das(&cfg_b));
        let mut ta = Trainer::new(cfg_a);
        let mut tb = Trainer::new(cfg_b);
        for step in 0..6 {
            let sa = ta.step_sim(&mut ma, step);
            let sb = tb.step_sim(&mut mb, step);
            assert!(
                (sa.reward - sb.reward).abs() < 1e-12,
                "step {step}: rewards diverged {} vs {}",
                sa.reward,
                sb.reward
            );
        }
    }

    #[test]
    fn history_recorded_per_epoch() {
        let cfg = small_cfg("das");
        let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
        let mut t = Trainer::new(cfg);
        t.run_sim(&mut model, 4);
        assert!(!t.history.epochs().is_empty());
        let total: usize = t
            .history
            .epochs()
            .iter()
            .map(|&e| {
                (0..8u32)
                    .map(|p| t.history.rollouts(p, e).len())
                    .sum::<usize>()
            })
            .sum();
        assert_eq!(total, 4 * 4 * 4); // steps * problems/step * samples
    }
}

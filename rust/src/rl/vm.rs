//! Token stack-machine — the code-execution substrate for code-RL rewards.
//!
//! The paper's DeepCoder setup assigns reward by unit-test pass/fail of
//! generated programs executed on a Ray CPU cluster (§5.2). Our substitute
//! is a tiny deterministic stack VM whose instructions ARE vocabulary
//! tokens, so a rollout *is* a program: the reward model decodes the token
//! stream, runs it against the problem's test cases, and pays the pass
//! fraction. Fuel-limited and total — generated garbage can never hang the
//! reward loop.

use crate::tokens::TokenId;

/// Instruction encoding: token id → opcode. Ids are chosen small so they sit
/// inside any vocab ≥ 32; ids ≥ OP_MAX are no-ops (comments), which keeps
/// every token sequence a valid program.
pub const OP_PUSH0: TokenId = 1; // PUSH0..PUSH7 push constants 0..7
pub const OP_PUSH_LAST: TokenId = 8;
pub const OP_ADD: TokenId = 9;
pub const OP_SUB: TokenId = 10;
pub const OP_MUL: TokenId = 11;
pub const OP_DUP: TokenId = 12;
pub const OP_SWAP: TokenId = 13;
pub const OP_POP: TokenId = 14;
pub const OP_LOAD_A: TokenId = 15;
pub const OP_LOAD_B: TokenId = 16;
pub const OP_OUT: TokenId = 17;
pub const OP_END: TokenId = 18;
pub const OP_MAX: TokenId = 19;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VmError {
    StackUnderflow { pc: usize },
    OutOfFuel,
    NoOutput,
}

/// One unit test: run the program with inputs (a, b), expect these outputs.
#[derive(Debug, Clone, PartialEq)]
pub struct TestCase {
    pub a: i64,
    pub b: i64,
    pub expected: Vec<i64>,
}

/// Execute a token program. Unknown tokens are no-ops; `OP_END` stops.
pub fn execute(program: &[TokenId], a: i64, b: i64, fuel: usize) -> Result<Vec<i64>, VmError> {
    let mut stack: Vec<i64> = Vec::with_capacity(16);
    let mut out: Vec<i64> = Vec::new();
    let mut spent = 0usize;
    for (pc, &tok) in program.iter().enumerate() {
        spent += 1;
        if spent > fuel {
            return Err(VmError::OutOfFuel);
        }
        match tok {
            t if (OP_PUSH0..OP_PUSH_LAST).contains(&t) => stack.push((t - OP_PUSH0) as i64),
            OP_PUSH_LAST => stack.push(*out.last().unwrap_or(&0)),
            OP_ADD | OP_SUB | OP_MUL => {
                let y = stack.pop().ok_or(VmError::StackUnderflow { pc })?;
                let x = stack.pop().ok_or(VmError::StackUnderflow { pc })?;
                stack.push(match tok {
                    OP_ADD => x.wrapping_add(y),
                    OP_SUB => x.wrapping_sub(y),
                    _ => x.wrapping_mul(y),
                });
            }
            OP_DUP => {
                let x = *stack.last().ok_or(VmError::StackUnderflow { pc })?;
                stack.push(x);
            }
            OP_SWAP => {
                let n = stack.len();
                if n < 2 {
                    return Err(VmError::StackUnderflow { pc });
                }
                stack.swap(n - 1, n - 2);
            }
            OP_POP => {
                stack.pop().ok_or(VmError::StackUnderflow { pc })?;
            }
            OP_LOAD_A => stack.push(a),
            OP_LOAD_B => stack.push(b),
            OP_OUT => {
                let x = *stack.last().ok_or(VmError::StackUnderflow { pc })?;
                out.push(x);
            }
            OP_END => break,
            _ => {} // no-op / comment token
        }
    }
    if out.is_empty() {
        return Err(VmError::NoOutput);
    }
    Ok(out)
}

/// Fraction of test cases the program passes (errors fail the case).
pub fn pass_fraction(program: &[TokenId], tests: &[TestCase], fuel: usize) -> f64 {
    if tests.is_empty() {
        return 0.0;
    }
    let passed = tests
        .iter()
        .filter(|t| matches!(execute(program, t.a, t.b, fuel), Ok(out) if out == t.expected))
        .count();
    passed as f64 / tests.len() as f64
}

/// Generate a random straight-line program that is guaranteed total and
/// produces at least one output, together with its test cases — used by the
/// workload generator so every code problem HAS a correct answer.
pub fn random_program(
    rng: &mut crate::util::rng::Rng,
    len: usize,
    n_tests: usize,
) -> (Vec<TokenId>, Vec<TestCase>) {
    // Build a stack-depth-tracked straight-line body, then force an output
    // and a terminator so the program is total by construction.
    let body_len = len;
    let mut body: Vec<TokenId> = Vec::with_capacity(body_len);
    let mut d = 0usize;
    let mut guard = 0;
    while body.len() < body_len && guard < body_len * 10 {
        guard += 1;
        let tok = if d == 0 {
            *rng.choose(&[OP_PUSH0 + 2, OP_LOAD_A, OP_LOAD_B]).unwrap()
        } else if rng.chance(0.4) && d >= 2 {
            *rng.choose(&[OP_ADD, OP_SUB, OP_MUL]).unwrap()
        } else if rng.chance(0.2) {
            OP_OUT
        } else {
            *rng.choose(&[OP_PUSH0 + 1, OP_PUSH0 + 4, OP_LOAD_A, OP_LOAD_B, OP_DUP])
                .unwrap()
        };
        match tok {
            t if (OP_PUSH0..OP_PUSH_LAST).contains(&t) => d += 1,
            OP_LOAD_A | OP_LOAD_B | OP_DUP => d += 1,
            OP_ADD | OP_SUB | OP_MUL => d -= 1,
            _ => {}
        }
        body.push(tok);
    }
    let mut program = body;
    if d == 0 {
        program.push(OP_LOAD_A);
    }
    program.push(OP_OUT);
    program.push(OP_END);
    // Derive test cases by executing on random inputs.
    let mut tests = Vec::with_capacity(n_tests);
    for _ in 0..n_tests {
        let a = rng.below(20) as i64;
        let b = rng.below(20) as i64;
        let expected = execute(&program, a, b, 10_000).expect("generated program is total");
        tests.push(TestCase { a, b, expected });
    }
    (program, tests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn arithmetic() {
        // a*b + 3
        let prog = [OP_LOAD_A, OP_LOAD_B, OP_MUL, OP_PUSH0 + 3, OP_ADD, OP_OUT, OP_END];
        assert_eq!(execute(&prog, 4, 5, 100).unwrap(), vec![23]);
        assert_eq!(execute(&prog, 0, 9, 100).unwrap(), vec![3]);
    }

    #[test]
    fn multiple_outputs_and_swap() {
        let prog = [
            OP_LOAD_A,
            OP_LOAD_B,
            OP_SWAP,
            OP_OUT,
            OP_POP,
            OP_OUT,
            OP_END,
        ];
        assert_eq!(execute(&prog, 7, 2, 100).unwrap(), vec![7, 2]);
    }

    #[test]
    fn underflow_and_no_output() {
        assert_eq!(
            execute(&[OP_ADD], 1, 1, 100),
            Err(VmError::StackUnderflow { pc: 0 })
        );
        assert_eq!(execute(&[OP_LOAD_A, OP_END], 1, 1, 100), Err(VmError::NoOutput));
    }

    #[test]
    fn fuel_limit() {
        let prog = vec![OP_LOAD_A; 1000];
        assert_eq!(execute(&prog, 1, 1, 10), Err(VmError::OutOfFuel));
    }

    #[test]
    fn unknown_tokens_are_noops() {
        let prog = [40, 41, OP_LOAD_A, 55, OP_OUT, 60, OP_END];
        assert_eq!(execute(&prog, 6, 0, 100).unwrap(), vec![6]);
    }

    #[test]
    fn end_stops_execution() {
        let prog = [OP_LOAD_A, OP_OUT, OP_END, OP_POP, OP_POP, OP_POP];
        assert_eq!(execute(&prog, 3, 0, 100).unwrap(), vec![3]);
    }

    #[test]
    fn pass_fraction_counts() {
        let prog = [OP_LOAD_A, OP_LOAD_B, OP_ADD, OP_OUT, OP_END];
        let tests = vec![
            TestCase { a: 1, b: 2, expected: vec![3] },
            TestCase { a: 5, b: 5, expected: vec![10] },
            TestCase { a: 1, b: 1, expected: vec![99] }, // wrong
        ];
        assert!((pass_fraction(&prog, &tests, 100) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn prop_random_programs_pass_their_own_tests() {
        prop::check(96, |g| {
            let mut rng = Rng::seed_from_u64(g.rng.next_u64());
            let (prog, tests) = random_program(&mut rng, 3 + g.usize_in(0, 20), 4);
            prop::require(!tests.is_empty(), "tests generated")?;
            prop::require(
                (pass_fraction(&prog, &tests, 10_000) - 1.0).abs() < 1e-12,
                "generated program must pass its own tests",
            )
        });
    }

    #[test]
    fn prop_vm_is_total() {
        // Any token soup either returns outputs or a clean error — never
        // panics, never loops (fuel).
        prop::check(128, |g| {
            let prog = g.vec_u32(64, 200);
            let _ = execute(&prog, 3, 4, 1000);
            Ok(())
        });
    }
}

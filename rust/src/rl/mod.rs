//! RL post-training layer: GRPO trainer, verifiable rewards, and the
//! code-execution VM substrate.

pub mod reward;
pub mod trainer;
pub mod vm;

pub use reward::{group_advantages, score};
pub use trainer::{StepStats, Trainer};

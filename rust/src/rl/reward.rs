//! Verifiable rewards (the "preparation" phase of RL post-training).
//!
//! Rewards here are verifiable outcome signals, matching the paper's two
//! workloads: answer-match for math (One-Shot-RLVR style) and unit-test
//! pass fraction for code (DeepCoder style, executed on the stack VM).
//! DAS never touches this logic — speculation is decode-side only.

use crate::tokens::{Rollout, TokenId};
use crate::workload::{Problem, TaskSpec};

use super::vm;

/// Score one rollout against its problem's task.
/// `eos` is stripped before checking.
pub fn score(problem: &Problem, rollout: &Rollout, eos: TokenId) -> f64 {
    let mut toks: &[TokenId] = &rollout.tokens;
    if toks.last() == Some(&eos) {
        toks = &toks[..toks.len() - 1];
    }
    match &problem.task {
        TaskSpec::MatchAnswer { answer } => {
            if answer.is_empty() || toks.len() < answer.len() {
                0.0
            } else if &toks[toks.len() - answer.len()..] == answer.as_slice() {
                1.0
            } else {
                0.0
            }
        }
        TaskSpec::SumMod { modulus } => {
            let want = problem.prompt.iter().sum::<u32>() % modulus;
            if toks.first() == Some(&want) {
                1.0
            } else {
                0.0
            }
        }
        TaskSpec::UnitTests { tests, fuel } => vm::pass_fraction(toks, tests, *fuel),
        TaskSpec::None => 0.0,
    }
}

/// GRPO group normalization: advantage_i = (r_i − mean) / (std + ε), per
/// problem group.
pub fn group_advantages(rewards: &[f64]) -> Vec<f64> {
    if rewards.is_empty() {
        return Vec::new();
    }
    let mean = crate::util::stats::mean(rewards);
    let std = crate::util::stats::stddev(rewards);
    rewards.iter().map(|r| (r - mean) / (std + 1e-6)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokens::Rollout;

    fn rollout(tokens: Vec<TokenId>) -> Rollout {
        Rollout {
            problem: 0,
            epoch: 0,
            step: 0,
            tokens,
            reward: 0.0,
        }
    }

    fn problem(task: TaskSpec) -> Problem {
        Problem {
            id: 0,
            prompt: vec![3, 4, 5],
            task,
            canonical: None,
            mutable: None,
        }
    }

    #[test]
    fn match_answer_checks_suffix() {
        let p = problem(TaskSpec::MatchAnswer { answer: vec![7, 8] });
        assert_eq!(score(&p, &rollout(vec![1, 2, 7, 8, 63]), 63), 1.0);
        assert_eq!(score(&p, &rollout(vec![1, 2, 7, 8]), 63), 1.0);
        assert_eq!(score(&p, &rollout(vec![7, 8, 9]), 63), 0.0);
        assert_eq!(score(&p, &rollout(vec![8]), 63), 0.0);
    }

    #[test]
    fn sum_mod_checks_first_token() {
        let p = problem(TaskSpec::SumMod { modulus: 10 });
        // 3+4+5 = 12 % 10 = 2.
        assert_eq!(score(&p, &rollout(vec![2, 63]), 63), 1.0);
        assert_eq!(score(&p, &rollout(vec![3]), 63), 0.0);
        assert_eq!(score(&p, &rollout(vec![63]), 63), 0.0);
    }

    #[test]
    fn unit_tests_pay_fraction() {
        use super::vm::{TestCase, OP_ADD, OP_END, OP_LOAD_A, OP_LOAD_B, OP_OUT};
        let p = problem(TaskSpec::UnitTests {
            tests: vec![
                TestCase { a: 1, b: 2, expected: vec![3] },
                TestCase { a: 2, b: 2, expected: vec![5] }, // wrong
            ],
            fuel: 100,
        });
        let prog = vec![OP_LOAD_A, OP_LOAD_B, OP_ADD, OP_OUT, OP_END, 63];
        assert!((score(&p, &rollout(prog), 63) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn group_advantages_zero_mean() {
        let adv = group_advantages(&[0.0, 1.0, 1.0, 0.0]);
        let sum: f64 = adv.iter().sum();
        assert!(sum.abs() < 1e-9);
        assert!(adv[1] > 0.0 && adv[0] < 0.0);
        // Degenerate group: all equal -> all zeros.
        let flat = group_advantages(&[0.5, 0.5]);
        assert!(flat.iter().all(|a| a.abs() < 1e-3));
    }
}

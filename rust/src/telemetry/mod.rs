//! Telemetry: CSV emission and aligned-table printing for the figure
//! harness and the training loop.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-oriented table that can print aligned text and write CSV.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(name: &str, columns: &[&str]) -> Self {
        Table {
            name: name.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Convenience for numeric rows.
    pub fn row_f(&mut self, cells: &[f64]) {
        self.row(cells.iter().map(|v| format_num(*v)).collect());
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        writeln!(s, "{}", self.columns.join(",")).unwrap();
        for r in &self.rows {
            writeln!(s, "{}", r.join(",")).unwrap();
        }
        s
    }

    pub fn write_csv(&self, dir: &Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        writeln!(s, "== {} ==", self.name).unwrap();
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect();
        writeln!(s, "{}", header.join("  ")).unwrap();
        for r in &self.rows {
            let line: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            writeln!(s, "{}", line.join("  ")).unwrap();
        }
        s
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

pub fn format_num(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 || (v.fract() == 0.0 && v.abs() < 1e9) {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_render() {
        let mut t = Table::new("demo", &["step", "value"]);
        t.row_f(&[1.0, 0.5]);
        t.row_f(&[2.0, 1500.0]);
        let csv = t.to_csv();
        assert!(csv.starts_with("step,value\n"));
        assert!(csv.contains("1,0.50000"));
        assert!(csv.contains("2,1500"));
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("step"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn write_csv_creates_file() {
        let dir = std::env::temp_dir().join("das_telemetry_test");
        let mut t = Table::new("out", &["a"]);
        t.row(vec!["1".into()]);
        let p = t.write_csv(&dir).unwrap();
        assert!(p.exists());
        assert_eq!(std::fs::read_to_string(p).unwrap(), "a\n1\n");
    }
}

//! Persistent history store — snapshot + write-ahead log for drafter state.
//!
//! Every run of the coordinator used to COLD-START its drafters, discarding
//! exactly the cross-epoch rollout history DAS exploits (the paper's
//! Insight-2: prompt-level patterns are stable across epochs). In
//! production, restarts are routine — a crash, a preemption, a resumed
//! training run — and paying a multi-epoch acceptance-ramp penalty on every
//! one of them is the long tail all over again. This module makes the
//! in-memory suffix index a durable artifact:
//!
//! * a **versioned binary snapshot** (`das-store-v1`) of the complete
//!   drafter state — the shared [`crate::suffix::SharedPool`] (segments +
//!   refcounts; the hash-cons table is rebuilt on load), every
//!   `ArenaTrie<S>` (nodes, compressed edge labels as pool slices,
//!   `CountStore` rows for all three stores, suffix links with their
//!   exact-or-dirty bookkeeping), the Ukkonen tree / suffix-array
//!   substrates (their deterministic build inputs), and the prefix router
//!   (owner trie + per-shard FIFO);
//! * a **write-ahead log** of every history mutation between snapshots
//!   ([`WalRecord`]: `Absorb` / `RollEpoch` / `Register`), so a crash loses
//!   at most the record being written when the process died.
//!
//! # On-disk layout
//!
//! A store is a directory:
//!
//! ```text
//! <dir>/snapshot.das   magic | u64 generation | u64 payload_len | payload
//!                      | u64 fnv1a(payload)
//! <dir>/wal.das        magic | u64 generation | record*
//! record             = u32 payload_len | u64 fnv1a(payload) | payload
//! magic              = "das-store-v1\n" / "das-wal-v1\n"
//! ```
//!
//! The snapshot payload is an opaque drafter blob (see
//! [`crate::drafter::Drafter::save_state`]); this module only frames it.
//!
//! # Crash safety
//!
//! * Snapshots commit by **atomic rename**: the new snapshot is fully
//!   written and fsynced as `snapshot.das.tmp`, then renamed over
//!   `snapshot.das` and the directory entry fsynced. A crash mid-write
//!   leaves the previous snapshot intact.
//! * WAL records are length-and-checksum framed and **fsynced per
//!   append** (`sync_data`), so an acknowledged record survives power
//!   loss, not just process death. On open, the log is scanned record by
//!   record; the first frame that is short or fails its checksum ends the
//!   valid prefix — the file is truncated back to it and replay proceeds
//!   from exactly that prefix. Truncating the WAL at ANY byte boundary
//!   therefore yields a clean prefix state (property-tested below); only a
//!   damaged HEADER — which no torn append can produce — is rejected, with
//!   a versioned [`StoreError`], never a panic.
//! * Snapshot and WAL carry a **generation** counter: the WAL header names
//!   the snapshot generation it extends. A crash in the window between the
//!   snapshot rename and the WAL reset leaves a NEW snapshot next to the
//!   OLD log; the generation mismatch identifies the log as subsumed and
//!   open discards it instead of replaying (and double-counting) records
//!   whose effects the snapshot already contains (regression-tested).
//! * After a successful snapshot commit the WAL is reset (the snapshot
//!   subsumes it), keeping recovery time bounded by `spec.snapshot_every`.
//!
//! # Warm-start lifecycle
//!
//! 1. [`crate::rollout::RolloutEngine::new`] opens the store when
//!    `spec.store_dir` is set and the configured drafter is persistent.
//! 2. If a snapshot exists, the drafter restores from it
//!    ([`crate::drafter::Drafter::load_state`] — parameter mismatches with
//!    the live config are rejected, falling back to a cold start), then the
//!    WAL's records replay through [`replay_wal`].
//! 3. During the run the engine appends an `Absorb` record per finished
//!    rollout and a `RollEpoch` per epoch boundary; every
//!    `spec.snapshot_every` epochs it commits a fresh snapshot and resets
//!    the log.
//! 4. `das store inspect|verify|compact` operate on a store directory
//!    offline: print its shape, prove the snapshot+WAL replay to a
//!    consistent index, or fold the WAL into a fresh snapshot.
//!
//! # Mid-run failure semantics
//!
//! Persistence is an *accelerator*, never a liveness dependency: when an
//! append or snapshot commit fails mid-run (disk full, permissions yanked,
//! an injected `store-fail` fault), the rollout engine logs it, counts it
//! in `StepMetrics::store_failures`, **drops the store and decodes on** —
//! the run continues without persistence rather than crashing or blocking.
//! The on-disk state stays a valid prefix (the failed record was never
//! acknowledged), so the next warm start simply resumes from slightly
//! older history. The DP coordinator keeps its own small sidecar in the
//! same directory (`coordinator.das`, written by atomic rename) holding
//! the LPT predictor's length/acceptance statistics; it follows the same
//! rule — unreadable or stale state means a cold predictor, never a
//! failed run.

// Clippy backstop for the audit's panic-path rule: the store is a
// supervised path — it degrades (StoreError, persistence disabled), it
// does not abort. Keep the deny module-wide so new call sites fail lint.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod wire;

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::tokens::{Epoch, ProblemId, TokenId};
pub use wire::{checksum, len_u32, Reader, StoreError, Writer};

/// Snapshot file magic (the format version lives in the name).
pub const SNAPSHOT_MAGIC: &[u8] = b"das-store-v1\n";
/// WAL file magic.
pub const WAL_MAGIC: &[u8] = b"das-wal-v1\n";

const SNAPSHOT_FILE: &str = "snapshot.das";
const SNAPSHOT_TMP: &str = "snapshot.das.tmp";
const WAL_FILE: &str = "wal.das";

/// One logged history mutation. The engine emits `Absorb` (a finished
/// rollout entered the drafter's history — shard insert AND, when a router
/// is configured, its prefix registration) and `RollEpoch`; `Register` is
/// the standalone router registration used by flows that route without
/// absorbing (and by the crash-safety tests).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    Absorb {
        problem: ProblemId,
        epoch: Epoch,
        tokens: Vec<TokenId>,
    },
    RollEpoch(Epoch),
    Register {
        shard: u32,
        tokens: Vec<TokenId>,
    },
}

impl WalRecord {
    fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            WalRecord::Absorb { problem, epoch, tokens } => {
                w.u8(1);
                w.u32(*problem);
                w.u32(*epoch);
                w.tokens(tokens);
            }
            WalRecord::RollEpoch(epoch) => {
                w.u8(2);
                w.u32(*epoch);
            }
            WalRecord::Register { shard, tokens } => {
                w.u8(3);
                w.u32(*shard);
                w.tokens(tokens);
            }
        }
        w.into_bytes()
    }

    fn decode(payload: &[u8]) -> Result<WalRecord, StoreError> {
        let mut r = Reader::new(payload);
        let rec = match r.u8()? {
            1 => WalRecord::Absorb {
                problem: r.u32()?,
                epoch: r.u32()?,
                tokens: r.tokens()?,
            },
            2 => WalRecord::RollEpoch(r.u32()?),
            3 => WalRecord::Register {
                shard: r.u32()?,
                tokens: r.tokens()?,
            },
            t => return Err(StoreError::Corrupt(format!("unknown WAL record tag {t}"))),
        };
        if !r.is_empty() {
            return Err(StoreError::Corrupt("trailing bytes in WAL record".into()));
        }
        Ok(rec)
    }
}

/// Size/latency gauges of one store, exported into
/// [`crate::rollout::StepMetrics`] each step.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StoreStatus {
    /// Bytes of the last committed (or loaded) snapshot payload.
    pub snapshot_bytes: u64,
    /// Records currently in the WAL (since the last snapshot).
    pub wal_records: u64,
    /// Bytes currently in the WAL, header excluded.
    pub wal_bytes: u64,
    /// Wall seconds the last snapshot commit took (0 until one happens).
    pub last_persist_secs: f64,
    /// Lifetime snapshot commits by this handle.
    pub snapshots_committed: u64,
}

/// What [`HistoryStore::peek`] sees in a store directory, read-only.
#[derive(Debug)]
pub struct StoreView {
    /// Snapshot payload, if one is committed.
    pub snapshot: Option<Vec<u8>>,
    /// Valid-prefix WAL records extending that snapshot.
    pub wal: Vec<WalRecord>,
    /// Size gauges (persist-latency/commit counters are writer-side state
    /// and stay 0 in a view).
    pub status: StoreStatus,
}

/// A drafter's durable history: one snapshot file plus one WAL, owned for
/// the lifetime of an engine (one store per engine/worker — stores are
/// single-writer by construction, like the drafters they persist).
#[derive(Debug)]
pub struct HistoryStore {
    dir: PathBuf,
    wal: File,
    snapshot: Option<Vec<u8>>,
    /// Records recovered from the WAL at OPEN time (the replay tail).
    /// Live appends go to disk only — the drafter already holds their
    /// effects, so mirroring them here would duplicate every rollout's
    /// tokens in memory until the next snapshot.
    replay: Vec<WalRecord>,
    /// Snapshot generation the current WAL extends.
    generation: u64,
    status: StoreStatus,
}

impl HistoryStore {
    /// Open (or create) the store at `dir`: load and checksum-verify the
    /// snapshot if present, scan the WAL's valid prefix (truncating any
    /// torn tail in place, discarding a whole log whose generation shows
    /// it was already subsumed by the snapshot), and leave the log open
    /// for appends.
    pub fn open(dir: &Path) -> Result<HistoryStore, StoreError> {
        std::fs::create_dir_all(dir)?;
        let (generation, snapshot) = match Self::read_snapshot(&dir.join(SNAPSHOT_FILE))? {
            Some((generation, payload)) => (generation, Some(payload)),
            None => (0, None),
        };
        let (wal, replay, wal_bytes) = Self::open_wal(&dir.join(WAL_FILE), generation)?;
        let status = StoreStatus {
            snapshot_bytes: snapshot.as_ref().map(|s| s.len() as u64).unwrap_or(0),
            wal_records: replay.len() as u64,
            wal_bytes,
            last_persist_secs: 0.0,
            snapshots_committed: 0,
        };
        Ok(HistoryStore {
            dir: dir.to_path_buf(),
            wal,
            snapshot,
            replay,
            generation,
            status,
        })
    }

    fn read_snapshot(path: &Path) -> Result<Option<(u64, Vec<u8>)>, StoreError> {
        let mut bytes = Vec::new();
        match File::open(path) {
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        }
        if bytes.len() < SNAPSHOT_MAGIC.len() || !bytes.starts_with(SNAPSHOT_MAGIC) {
            return Err(StoreError::Version(format!(
                "{} is not a das-store-v1 snapshot",
                path.display()
            )));
        }
        let mut r = Reader::new(&bytes[SNAPSHOT_MAGIC.len()..]);
        let generation = r.u64()?;
        let n = r.count(1)?;
        let payload = r.bytes(n)?.to_vec();
        let want = r.u64()?;
        if checksum(&payload) != want {
            return Err(StoreError::Corrupt(format!(
                "snapshot checksum mismatch in {}",
                path.display()
            )));
        }
        Ok(Some((generation, payload)))
    }

    /// Open the WAL, validating its header and scanning the record frames.
    /// The first short or checksum-failing frame ends the valid prefix; the
    /// file is truncated back to it so future appends extend a clean log.
    /// A log whose header generation differs from `snap_gen` is a crash
    /// artifact from the window between a snapshot rename and the WAL
    /// reset: its records' effects are already inside the snapshot, so it
    /// is discarded whole (replaying it would double-count history).
    fn open_wal(path: &Path, snap_gen: u64) -> Result<(File, Vec<WalRecord>, u64), StoreError> {
        let bytes = Self::read_wal_bytes(path)?;
        let (records, valid_len) = Self::scan_wal(path, &bytes, snap_gen)?;
        let mut wal = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)?;
        if valid_len == 0 {
            Self::reset_wal_file(&mut wal, snap_gen)?;
        } else {
            wal.set_len(valid_len as u64)?;
            use std::io::Seek;
            wal.seek(std::io::SeekFrom::End(0))?;
        }
        let wal_bytes = valid_len.saturating_sub(WAL_MAGIC.len() + 8) as u64;
        Ok((wal, records, wal_bytes))
    }

    fn read_wal_bytes(path: &Path) -> Result<Vec<u8>, StoreError> {
        let mut bytes = Vec::new();
        match File::open(path) {
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(e.into()),
        }
        Ok(bytes)
    }

    /// Pure scan of a WAL image: the recovered records and the byte length
    /// of the valid prefix (0 = nothing usable / subsumed log). Mutates
    /// nothing — shared by [`HistoryStore::open`] (which then repairs the
    /// file) and [`HistoryStore::peek`] (which must not).
    fn scan_wal(
        path: &Path,
        bytes: &[u8],
        snap_gen: u64,
    ) -> Result<(Vec<WalRecord>, usize), StoreError> {
        let header_len = WAL_MAGIC.len() + 8;
        if bytes.len() < WAL_MAGIC.len() {
            // Fresh log, or a torn header write: nothing usable.
            return Ok((Vec::new(), 0));
        }
        if !bytes.starts_with(WAL_MAGIC) {
            // A FULL magic that is wrong is another format/version — that
            // is rejection territory, not a torn write.
            return Err(StoreError::Version(format!(
                "{} is not a das-wal-v1 log",
                path.display()
            )));
        }
        if bytes.len() < header_len {
            // Torn mid-header (generation half-written): empty prefix.
            return Ok((Vec::new(), 0));
        }
        if Reader::new(&bytes[WAL_MAGIC.len()..]).u64()? != snap_gen {
            // Subsumed log (see `open_wal`): discard, do not replay.
            return Ok((Vec::new(), 0));
        }
        let mut records = Vec::new();
        let mut pos = header_len;
        loop {
            if pos == bytes.len() {
                break;
            }
            match Self::parse_frame(bytes, pos) {
                Ok((rec, consumed)) => {
                    records.push(rec);
                    pos += consumed;
                }
                // Torn tail: the valid prefix ends at this frame.
                Err(StoreError::Truncated) => break,
                // A checksum-VALID frame that fails to decode is real
                // corruption, not a torn append.
                Err(e) => return Err(e),
            }
        }
        Ok((records, pos))
    }

    /// Read-only view of a store directory: parses the snapshot and the
    /// WAL's valid prefix WITHOUT creating, truncating or repairing
    /// anything — safe on read-only media and for post-crash forensics
    /// (the `das store inspect`/`verify` verbs go through here, so
    /// diagnosing a store never destroys the bytes being diagnosed).
    pub fn peek(dir: &Path) -> Result<StoreView, StoreError> {
        let (generation, snapshot) = match Self::read_snapshot(&dir.join(SNAPSHOT_FILE))? {
            Some((generation, payload)) => (generation, Some(payload)),
            None => (0, None),
        };
        let bytes = Self::read_wal_bytes(&dir.join(WAL_FILE))?;
        let (wal, valid_len) = Self::scan_wal(&dir.join(WAL_FILE), &bytes, generation)?;
        let status = StoreStatus {
            snapshot_bytes: snapshot.as_ref().map(|s| s.len() as u64).unwrap_or(0),
            wal_records: wal.len() as u64,
            wal_bytes: valid_len.saturating_sub(WAL_MAGIC.len() + 8) as u64,
            last_persist_secs: 0.0,
            snapshots_committed: 0,
        };
        Ok(StoreView {
            snapshot,
            wal,
            status,
        })
    }

    /// Rewrite `wal` as an empty log extending snapshot generation `gen`.
    fn reset_wal_file(wal: &mut File, gen: u64) -> Result<(), StoreError> {
        use std::io::Seek;
        wal.set_len(0)?;
        wal.seek(std::io::SeekFrom::Start(0))?;
        wal.write_all(WAL_MAGIC)?;
        wal.write_all(&gen.to_le_bytes())?;
        wal.sync_data()?;
        Ok(())
    }

    /// Parse one WAL frame at `pos`; [`StoreError::Truncated`] marks a torn
    /// tail (the caller truncates the log back to `pos`).
    fn parse_frame(bytes: &[u8], pos: usize) -> Result<(WalRecord, usize), StoreError> {
        let mut r = Reader::new(&bytes[pos..]);
        let len = r.u32_len()?;
        let want = r.u64()?;
        if r.remaining() < len {
            return Err(StoreError::Truncated);
        }
        let payload = &bytes[pos + 12..pos + 12 + len];
        if checksum(payload) != want {
            return Err(StoreError::Truncated); // torn tail
        }
        let rec = WalRecord::decode(payload)?;
        Ok((rec, 12 + len))
    }

    /// The snapshot payload loaded at OPEN time, if any — the warm-start
    /// input. Dropped by the next [`HistoryStore::commit_snapshot`]: the
    /// caller's live state is what the commit serialized, so mirroring the
    /// (potentially large) payload for the handle's lifetime would double
    /// the drafter's memory; reopen reads it back from disk.
    pub fn snapshot(&self) -> Option<&[u8]> {
        self.snapshot.as_deref()
    }

    /// The recovery tail recovered at OPEN time: records to replay on top
    /// of [`HistoryStore::snapshot`]. Records appended by THIS handle are
    /// not mirrored here (their effects already live in the caller's
    /// state); they show up in [`HistoryStore::status`] and on the next
    /// open.
    pub fn wal(&self) -> &[WalRecord] {
        &self.replay
    }

    pub fn status(&self) -> StoreStatus {
        self.status
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append one record, fsynced before returning (the "ahead" in
    /// write-ahead: the record is durable — power-loss durable, not just
    /// process-crash durable — before the in-memory state that depends on
    /// it is allowed to matter).
    pub fn append(&mut self, rec: &WalRecord) -> Result<(), StoreError> {
        let payload = rec.encode();
        let mut frame = Vec::with_capacity(12 + payload.len());
        frame.extend_from_slice(&len_u32(payload.len()).to_le_bytes());
        frame.extend_from_slice(&checksum(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.wal.write_all(&frame)?;
        self.wal.sync_data()?;
        self.status.wal_records += 1;
        self.status.wal_bytes += frame.len() as u64;
        Ok(())
    }

    /// Commit `payload` as the new snapshot (atomic rename, directory
    /// entry fsynced) and reset the WAL it subsumes under the bumped
    /// generation. On success the store's state is exactly
    /// `snapshot = payload, wal = []`; a crash between the rename and the
    /// WAL reset leaves a generation mismatch that the next open resolves
    /// by discarding the subsumed log.
    pub fn commit_snapshot(&mut self, payload: &[u8]) -> Result<(), StoreError> {
        // audit: allow(wall-clock-determinism) -- persist-latency gauge only, never replayed
        let t0 = Instant::now();
        let next_gen = self.generation + 1;
        let tmp = self.dir.join(SNAPSHOT_TMP);
        {
            let mut f = File::create(&tmp)?;
            f.write_all(SNAPSHOT_MAGIC)?;
            f.write_all(&next_gen.to_le_bytes())?;
            f.write_all(&(payload.len() as u64).to_le_bytes())?;
            f.write_all(payload)?;
            f.write_all(&checksum(payload).to_le_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, self.dir.join(SNAPSHOT_FILE))?;
        // Make the rename itself durable before the WAL reset depends on it.
        File::open(&self.dir)?.sync_all()?;
        Self::reset_wal_file(&mut self.wal, next_gen)?;
        self.generation = next_gen;
        // See `snapshot()`: the open-time copy is stale now and the fresh
        // payload lives in the caller; keep only its size.
        self.snapshot = None;
        self.replay.clear();
        self.status.snapshot_bytes = payload.len() as u64;
        self.status.wal_records = 0;
        self.status.wal_bytes = 0;
        self.status.last_persist_secs = t0.elapsed().as_secs_f64();
        self.status.snapshots_committed += 1;
        Ok(())
    }
}

/// Replay a WAL tail into a drafter (after its snapshot restore): `Absorb`
/// re-enters the rollout into history exactly like the live path did
/// (`observe_rollout` — shard insert plus router registration), `RollEpoch`
/// re-runs window maintenance, `Register` re-registers a router prefix.
pub fn replay_wal(drafter: &mut dyn crate::drafter::Drafter, records: &[WalRecord]) {
    for rec in records {
        match rec {
            WalRecord::Absorb { problem, epoch, tokens } => {
                drafter.observe_rollout(&crate::tokens::Rollout {
                    problem: *problem,
                    epoch: *epoch,
                    step: 0,
                    tokens: tokens.clone(),
                    reward: 0.0,
                });
            }
            WalRecord::RollEpoch(epoch) => drafter.roll_epoch(*epoch),
            WalRecord::Register { shard, tokens } => drafter.register_route(*shard, tokens),
        }
    }
}

#[cfg(test)]
pub(crate) fn test_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("das-store-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn absorb(problem: u32, epoch: u32, tokens: Vec<u32>) -> WalRecord {
        WalRecord::Absorb { problem, epoch, tokens }
    }

    #[test]
    fn fresh_store_is_empty_and_reopenable() {
        let dir = test_dir("fresh");
        let s = HistoryStore::open(&dir).unwrap();
        assert!(s.snapshot().is_none());
        assert!(s.wal().is_empty());
        drop(s);
        let s = HistoryStore::open(&dir).unwrap();
        assert!(s.snapshot().is_none());
        assert!(s.wal().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wal_appends_survive_reopen_in_order() {
        let dir = test_dir("wal-order");
        let recs = vec![
            absorb(1, 0, vec![1, 2, 3]),
            WalRecord::RollEpoch(1),
            WalRecord::Register { shard: 7, tokens: vec![4, 5] },
            absorb(2, 1, vec![9]),
        ];
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            for r in &recs {
                s.append(r).unwrap();
            }
            assert_eq!(s.status().wal_records, 4);
        }
        let s = HistoryStore::open(&dir).unwrap();
        assert_eq!(s.wal(), recs.as_slice());
        assert_eq!(s.status().wal_records, 4);
        assert!(s.status().wal_bytes > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn snapshot_commit_resets_wal_and_survives_reopen() {
        let dir = test_dir("snap");
        let blob = b"drafter-blob-bytes".to_vec();
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            s.append(&absorb(1, 0, vec![1])).unwrap();
            s.commit_snapshot(&blob).unwrap();
            assert_eq!(s.wal().len(), 0, "snapshot subsumes the log");
            assert_eq!(s.status().snapshot_bytes, blob.len() as u64);
            assert_eq!(s.status().snapshots_committed, 1);
            s.append(&absorb(2, 1, vec![2])).unwrap();
        }
        let s = HistoryStore::open(&dir).unwrap();
        assert_eq!(s.snapshot(), Some(blob.as_slice()));
        assert_eq!(s.wal(), &[absorb(2, 1, vec![2])]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_snapshot_is_a_versioned_error() {
        let dir = test_dir("snap-corrupt");
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            s.commit_snapshot(b"payload").unwrap();
        }
        // Flip one payload byte: checksum must catch it.
        let path = dir.join(SNAPSHOT_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        let k = SNAPSHOT_MAGIC.len() + 16 + 2; // inside the payload (magic | gen | len | payload)
        bytes[k] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        match HistoryStore::open(&dir) {
            Err(StoreError::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // A wrong magic is a Version error.
        std::fs::write(&path, b"some-other-format-entirely........").unwrap();
        match HistoryStore::open(&dir) {
            Err(StoreError::Version(_)) => {}
            other => panic!("expected Version, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn foreign_wal_header_rejected() {
        let dir = test_dir("wal-foreign");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(WAL_FILE), b"not-a-das-wal-at-all\n").unwrap();
        match HistoryStore::open(&dir) {
            Err(StoreError::Version(_)) => {}
            other => panic!("expected Version, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_wal_truncation_replays_a_clean_prefix() {
        // THE crash-safety property: truncate the WAL file at EVERY byte
        // boundary; open must either recover a strict prefix of the logged
        // records (and leave the file extendable) or fail with a versioned
        // error — never panic, never invent records. Also: appending after
        // recovery works on the truncated log.
        let dir = test_dir("wal-trunc");
        let recs = vec![
            absorb(1, 0, vec![1, 2, 3, 4, 5]),
            WalRecord::RollEpoch(1),
            absorb(2, 1, vec![6]),
            WalRecord::Register { shard: 3, tokens: vec![7, 8] },
            WalRecord::RollEpoch(2),
        ];
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            for r in &recs {
                s.append(r).unwrap();
            }
        }
        let full = std::fs::read(dir.join(WAL_FILE)).unwrap();
        for cut in 0..=full.len() {
            std::fs::write(dir.join(WAL_FILE), &full[..cut]).unwrap();
            match HistoryStore::open(&dir) {
                Ok(mut s) => {
                    let got = s.wal().to_vec();
                    assert!(got.len() <= recs.len(), "cut {cut}: no invented records");
                    assert_eq!(
                        got.as_slice(),
                        &recs[..got.len()],
                        "cut {cut}: recovered records must be a strict prefix"
                    );
                    // The recovered log must accept appends cleanly.
                    s.append(&WalRecord::RollEpoch(99)).unwrap();
                    drop(s);
                    let s2 = HistoryStore::open(&dir).unwrap();
                    assert_eq!(s2.wal().last(), Some(&WalRecord::RollEpoch(99)), "cut {cut}");
                }
                Err(StoreError::Version(_)) | Err(StoreError::Corrupt(_)) => {
                    // Acceptable only for a damaged header region, which a
                    // pure truncation never produces.
                    panic!("cut {cut}: truncation must never be rejected");
                }
                Err(e) => panic!("cut {cut}: unexpected error {e}"),
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prop_wal_random_bitflip_never_panics() {
        // Beyond truncation: flip a random byte anywhere in the log. Open
        // must return Ok (prefix recovery) or a versioned error — the
        // checksum frames make mid-log damage indistinguishable from a torn
        // tail, which is the safe interpretation.
        prop::check(48, |g| {
            let dir = test_dir(&format!("wal-flip-{}", g.rng.below(1_000_000)));
            {
                let mut s = HistoryStore::open(&dir).unwrap();
                for i in 0..4u32 {
                    s.append(&WalRecord::Absorb {
                        problem: i,
                        epoch: i,
                        tokens: vec![i; 1 + g.usize_in(0, 6)],
                    })
                    .unwrap();
                }
            }
            let path = dir.join(WAL_FILE);
            let mut bytes = std::fs::read(&path).unwrap();
            let k = g.rng.below(bytes.len());
            bytes[k] ^= 1 << g.rng.below(8);
            std::fs::write(&path, &bytes).unwrap();
            let ok = match HistoryStore::open(&dir) {
                Ok(s) => s.wal().len() <= 4,
                Err(StoreError::Version(_)) | Err(StoreError::Corrupt(_)) => true,
                Err(_) => false,
            };
            std::fs::remove_dir_all(&dir).ok();
            prop::require(ok, "bitflip handled without panic or invention")
        });
    }

    #[test]
    fn peek_is_read_only_even_on_damaged_stores() {
        let dir = test_dir("peek");
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            s.commit_snapshot(b"blob").unwrap();
            s.append(&absorb(1, 0, vec![1, 2])).unwrap();
            s.append(&absorb(2, 0, vec![3])).unwrap();
        }
        // Tear the tail: peek must report the valid prefix WITHOUT
        // repairing the file (open would truncate it in place).
        let path = dir.join(WAL_FILE);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let damaged = std::fs::read(&path).unwrap();
        let view = HistoryStore::peek(&dir).unwrap();
        assert_eq!(view.snapshot.as_deref(), Some(b"blob".as_slice()));
        assert_eq!(view.wal, vec![absorb(1, 0, vec![1, 2])]);
        assert_eq!(view.status.wal_records, 1);
        assert_eq!(view.status.snapshot_bytes, 4);
        assert_eq!(std::fs::read(&path).unwrap(), damaged, "peek never repairs");
        // Peeking a directory that does not exist inspects emptiness
        // without creating anything.
        let ghost = dir.join("nope");
        let v = HistoryStore::peek(&ghost).unwrap();
        assert!(v.snapshot.is_none() && v.wal.is_empty());
        assert!(!ghost.exists(), "peek never creates");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stale_wal_from_pre_reset_crash_is_discarded() {
        // THE double-replay regression: a crash in the window between the
        // snapshot rename and the WAL reset leaves the NEW snapshot next
        // to the OLD log, whose records' effects the snapshot already
        // contains. The generation mismatch must discard that log instead
        // of replaying it on top of the snapshot.
        let dir = test_dir("wal-stale");
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            s.append(&absorb(1, 0, vec![1, 2, 3])).unwrap();
        }
        let pre_commit_log = std::fs::read(dir.join(WAL_FILE)).unwrap();
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            s.commit_snapshot(b"state-including-the-absorb").unwrap();
        }
        // Simulate the crash: restore the pre-commit log bytes verbatim.
        std::fs::write(dir.join(WAL_FILE), &pre_commit_log).unwrap();
        let mut s = HistoryStore::open(&dir).unwrap();
        assert_eq!(s.snapshot(), Some(b"state-including-the-absorb".as_slice()));
        assert!(s.wal().is_empty(), "subsumed log must not replay (double count)");
        assert_eq!(s.status().wal_records, 0);
        // The store keeps working: appends land under the new generation
        // and survive a clean reopen.
        s.append(&absorb(2, 1, vec![9])).unwrap();
        drop(s);
        let s = HistoryStore::open(&dir).unwrap();
        assert_eq!(s.wal(), &[absorb(2, 1, vec![9])]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_snapshot_tmp_is_ignored() {
        // A crash between tmp write and rename leaves snapshot.das.tmp
        // behind; open must use the committed snapshot and ignore the tmp.
        let dir = test_dir("snap-torn");
        {
            let mut s = HistoryStore::open(&dir).unwrap();
            s.commit_snapshot(b"committed").unwrap();
        }
        std::fs::write(dir.join(SNAPSHOT_TMP), b"half-writ").unwrap();
        let s = HistoryStore::open(&dir).unwrap();
        assert_eq!(s.snapshot(), Some(b"committed".as_slice()));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Binary wire codec for the `das-store-v1` persistence format.
//!
//! Deliberately tiny: fixed-width little-endian scalars, length-prefixed
//! strings/token runs, and an FNV-1a content checksum. No self-describing
//! schema — every section of the format is written and read by the same
//! release of this crate, and cross-version compatibility is handled at the
//! FILE level by the magic/version header ([`super::HistoryStore`] rejects
//! unknown versions with [`StoreError::Version`] instead of guessing).
//!
//! Every read returns `Result`: a short buffer is [`StoreError::Truncated`],
//! never a panic — the WAL crash-safety property (§ module docs of
//! [`super`]) rests on that.

use std::fmt;

/// Everything that can go wrong opening, replaying or writing a store.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    /// Filesystem-level failure (message carries the `std::io::Error`).
    Io(String),
    /// The file's magic/version header names a format this build does not
    /// speak (or is not a das-store file at all).
    Version(String),
    /// Structurally invalid content behind a VALID header/checksum — a
    /// writer bug or deliberate tampering, never a torn write.
    Corrupt(String),
    /// Ran out of bytes mid-structure (torn tail write; callers treat the
    /// valid prefix as the state).
    Truncated,
    /// Snapshot parameters disagree with the live configuration (e.g. a
    /// snapshot taken under a different substrate/scope/window).
    Mismatch(String),
    /// The drafter/source has no persistent state to save or load.
    Unsupported(&'static str),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(m) => write!(f, "store io error: {m}"),
            StoreError::Version(m) => write!(f, "store version error: {m}"),
            StoreError::Corrupt(m) => write!(f, "store corrupt: {m}"),
            StoreError::Truncated => write!(f, "store data truncated"),
            StoreError::Mismatch(m) => write!(f, "store/config mismatch: {m}"),
            StoreError::Unsupported(m) => write!(f, "store unsupported: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e.to_string())
    }
}

/// FNV-1a over `bytes` — cheap, dependency-free, and plenty to detect the
/// torn writes and bit rot the store guards against (not an integrity MAC).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Checked usize→u32 narrowing for wire length prefixes. Lengths beyond
/// u32 cannot be encoded in the format at all, so exceeding the limit is a
/// caller bug worth stopping at the write site — a wrapped prefix would
/// instead surface later as checksum-valid-but-corrupt payload.
pub fn len_u32(n: usize) -> u32 {
    assert!(u32::try_from(n).is_ok(), "length {n} exceeds the u32 wire-format limit");
    // audit: allow(unchecked-narrowing) -- this IS the checked helper; asserted directly above
    n as u32
}

/// Append-only byte sink for one format section.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// UTF-8 string, u32 length prefix.
    pub fn str(&mut self, s: &str) {
        self.u32(len_u32(s.len()));
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Token run, u32 length prefix.
    pub fn tokens(&mut self, toks: &[u32]) {
        self.u32(len_u32(toks.len()));
        for &t in toks {
            self.u32(t);
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Cursor over one format section. Every accessor is bounds-checked and
/// returns [`StoreError::Truncated`] instead of panicking.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        if self.remaining() < n {
            return Err(StoreError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }

    /// Raw byte run of a known length.
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        self.take(n)
    }

    pub fn u32(&mut self) -> Result<u32, StoreError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, StoreError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn usize(&mut self) -> Result<usize, StoreError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| StoreError::Corrupt(format!("usize overflow: {v}")))
    }

    /// A u32 length prefix widened to usize, checked rather than cast —
    /// 16-bit targets cannot hold every u32, and hostile input must come
    /// back as [`StoreError::Corrupt`], never as a silent truncation.
    pub fn u32_len(&mut self) -> Result<usize, StoreError> {
        let v = self.u32()?;
        usize::try_from(v).map_err(|_| StoreError::Corrupt(format!("length overflow: {v}")))
    }

    /// A u64-encoded count that bounds a following repetition. Rejects
    /// counts that could not possibly fit in the remaining bytes (each
    /// element needs at least `min_elem_bytes`), so corrupt lengths fail
    /// fast instead of driving huge allocations.
    pub fn count(&mut self, min_elem_bytes: usize) -> Result<usize, StoreError> {
        let n = self.usize()?;
        if n.saturating_mul(min_elem_bytes.max(1)) > self.remaining() {
            return Err(StoreError::Truncated);
        }
        Ok(n)
    }

    pub fn f64(&mut self) -> Result<f64, StoreError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn str(&mut self) -> Result<String, StoreError> {
        let n = self.u32_len()?;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| StoreError::Corrupt("non-UTF-8 string".into()))
    }

    pub fn tokens(&mut self) -> Result<Vec<u32>, StoreError> {
        let n = self.u32_len()?;
        if n.saturating_mul(4) > self.remaining() {
            return Err(StoreError::Truncated);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    /// Assert a section tag written by [`Writer::str`].
    pub fn expect_str(&mut self, want: &str, what: &str) -> Result<(), StoreError> {
        let got = self.str()?;
        if got != want {
            return Err(StoreError::Corrupt(format!(
                "{what}: expected '{want}', found '{got}'"
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.usize(123_456);
        w.f64(-0.25);
        w.str("das-store");
        w.tokens(&[1, 2, 3]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.usize().unwrap(), 123_456);
        assert_eq!(r.f64().unwrap(), -0.25);
        assert_eq!(r.str().unwrap(), "das-store");
        assert_eq!(r.tokens().unwrap(), vec![1, 2, 3]);
        assert!(r.is_empty());
    }

    #[test]
    fn truncation_is_an_error_never_a_panic() {
        let mut w = Writer::new();
        w.u64(42);
        w.str("hello");
        w.tokens(&[9, 9, 9]);
        let bytes = w.into_bytes();
        // Every proper prefix must fail with Truncated on SOME read, and
        // never panic. (The full buffer parses cleanly.)
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            let res = (|| -> Result<(), StoreError> {
                r.u64()?;
                r.str()?;
                r.tokens()?;
                Ok(())
            })();
            assert_eq!(res, Err(StoreError::Truncated), "cut at {cut}");
        }
    }

    #[test]
    fn hostile_counts_rejected() {
        // A corrupt length prefix larger than the remaining bytes must be
        // rejected before any allocation is attempted.
        let mut w = Writer::new();
        w.u32(u32::MAX); // token-count prefix with no payload behind it
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.tokens(), Err(StoreError::Truncated));
        let mut w = Writer::new();
        w.u64(u64::MAX);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.count(8).is_err());
    }

    #[test]
    fn checksum_is_stable_and_sensitive() {
        let a = checksum(b"das-store-v1");
        assert_eq!(a, checksum(b"das-store-v1"), "deterministic");
        assert_ne!(a, checksum(b"das-store-v2"), "content-sensitive");
        assert_ne!(checksum(b""), 0);
    }
}

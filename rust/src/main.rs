//! `das` — launcher for the DAS reproduction.
//!
//! Subcommands:
//!   figures    regenerate the paper's figures (CSV + printed tables)
//!   train      run RL training (sim or pjrt backend) with a config
//!   serve      rollout-only generation over a trace workload
//!   serve-drafts  draft daemon: serve DraftSource RPCs (das-draft-rpc-v1)
//!   calibrate  fit the latency model on the real PJRT artifacts (Fig. 8)
//!   config     print the resolved configuration for a preset/file
//!   store      inspect/verify/compact a persistent history store
//!   audit      static-analysis gate over rust/src (das-audit-v1 report)

#![deny(unsafe_code)]

use std::path::{Path, PathBuf};

use anyhow::Result;

use das::config::{preset, preset_names, DasConfig};
use das::drafter::{Drafter, SuffixDrafter};
use das::figures::{emit, known_figures, run as run_figure, FigOpts};
use das::model::sim::{SimModel, SimModelConfig};
use das::rl::Trainer;
#[cfg(feature = "pjrt")]
use das::runtime::PjrtModel;
use das::store::{replay_wal, HistoryStore, WalRecord};
use das::telemetry::Table;
use das::util::argparse::Command;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let result = match argv.first().map(|s| s.as_str()) {
        Some("figures") => cmd_figures(&argv[1..]),
        Some("train") => cmd_train(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("serve-drafts") => cmd_serve_drafts(&argv[1..]),
        Some("calibrate") => cmd_calibrate(&argv[1..]),
        Some("config") => cmd_config(&argv[1..]),
        Some("store") => cmd_store(&argv[1..]),
        Some("audit") => cmd_audit(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "das — Distribution-Aware Speculative Decoding for RL Training\n\n\
         usage: das <subcommand> [options]\n\n\
         subcommands:\n\
           figures    --fig <N>|--all [--full] [--out results] [--seed N]\n\
           train      [--config file.json] [--preset name] [--set k=v] [--steps N] [--out results]\n\
                      [--fault-plan \"panic worker=1 step=2; ...\"] [--workers N]  (chaos harness)\n\
           serve      [--preset name] [--steps N] (rollout-only, trace workload)\n\
           serve-drafts  [--dir store] [--addr host:port] [--preset name] [--set k=v]\n\
                      (draft daemon for spec.substrate=remote clients)\n\
           calibrate  [--reps N] (requires `make artifacts`)\n\
           config     [--preset name | --config file.json]\n\
           store      <inspect|verify|compact> --dir <store-dir>\n\
           audit      [--json report.json] [--paths rust/src] (static-analysis gate)\n\n\
         presets: {}",
        preset_names().join(", ")
    );
}

fn load_config(args: &das::util::argparse::Args) -> Result<DasConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => DasConfig::load(Path::new(path))?,
        None => {
            let name = args.get_or("preset", "math_rl");
            preset(name).ok_or_else(|| {
                anyhow::anyhow!("unknown preset '{name}' (known: {:?})", preset_names())
            })?
        }
    };
    if let Some(seed) = args.get_u64("seed") {
        cfg.seed = seed;
    }
    if let Some(assignment) = args.get("set") {
        cfg.set(assignment)?;
    }
    Ok(cfg)
}

fn cmd_figures(argv: &[String]) -> Result<()> {
    let cmd = Command::new("das figures", "regenerate the paper's figures")
        .opt("fig", "figure number to run", None)
        .flag_opt("all", "run every figure")
        .flag_opt("full", "paper-scale settings (slower)")
        .opt("out", "output directory for CSVs", Some("results"))
        .opt("seed", "random seed", Some("17"));
    let args = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    let opts = FigOpts {
        seed: args.get_u64("seed").unwrap_or(17),
        full: args.flag("full"),
        out_dir: PathBuf::from(args.get_or("out", "results")),
    };
    let figs: Vec<u32> = if args.flag("all") {
        known_figures().to_vec()
    } else {
        let n = args
            .get_usize("fig")
            .ok_or_else(|| anyhow::anyhow!("--fig <N> or --all required\n\n{}", cmd.usage()))?;
        vec![n as u32]
    };
    for f in figs {
        println!("\n───────────────────────────── figure {f} ─────────────────────────────");
        match run_figure(f, &opts) {
            Ok(out) => emit(&out, &opts)?,
            Err(e) => eprintln!("figure {f} skipped: {e:#}"),
        }
    }
    Ok(())
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = Command::new("das train", "run GRPO training with DAS rollouts")
        .opt("config", "JSON config file", None)
        .opt("preset", "named preset", Some("math_rl"))
        .opt("set", "single key=value override", None)
        .opt("steps", "training steps (overrides config)", None)
        .opt("seed", "random seed", None)
        .opt("out", "CSV output directory", Some("results"))
        .opt(
            "fault-plan",
            "inject deterministic faults and verify chaos equivalence (see rollout/faults.rs)",
            None,
        )
        .opt("workers", "data-parallel rollout workers (chaos harness)", None);
    let args = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    let mut cfg = load_config(&args)?;
    if let Some(steps) = args.get_usize("steps") {
        cfg.train.steps = steps;
    }
    if let Some(plan) = args.get("fault-plan") {
        let workers = args.get_usize("workers").unwrap_or(cfg.rollout.n_workers);
        return run_chaos_harness(cfg, plan, workers);
    }
    println!("resolved config: {}", cfg.to_json().to_string());
    let mut table = Table::new(
        "train_log",
        &["step", "epoch", "reward", "loss", "gen_time_s", "accept_rate", "tokens"],
    );
    let mut trainer = Trainer::new(cfg.clone());
    let mut log_step = |t: &mut Table, s: &das::rl::StepStats| {
        println!(
            "step {:>3}  epoch {:>2}  reward {:.3}  loss {:+.4}  gen {:.3}s  accept {:.2}  toks {}",
            s.step,
            s.epoch,
            s.reward,
            s.loss,
            s.metrics.gen_time,
            s.metrics.accept_rate(),
            s.metrics.generated
        );
        t.row_f(&[
            s.step as f64,
            s.epoch as f64,
            s.reward,
            s.loss,
            s.metrics.gen_time,
            s.metrics.accept_rate(),
            s.metrics.generated as f64,
        ]);
    };
    match cfg.model.backend.as_str() {
        "sim" => {
            let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
            for step in 0..cfg.train.steps {
                let s = trainer.step_sim(&mut model, step as u32);
                log_step(&mut table, &s);
            }
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let mut model = PjrtModel::load(Path::new(&cfg.model.artifacts_dir))?;
            for step in 0..cfg.train.steps {
                let s = trainer.step_pjrt(&mut model, step as u32);
                log_step(&mut table, &s);
            }
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            anyhow::bail!("das was built without the pjrt feature; rebuild with --features pjrt")
        }
        other => anyhow::bail!("unknown backend {other}"),
    }
    let out = PathBuf::from(args.get_or("out", "results"));
    let path = table.write_csv(&out)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Chaos harness (`das train --fault-plan "..."`): run the SAME workload
/// through an undisturbed control pool and a fault-injected chaos pool, and
/// verify the supervision contract end to end — greedy outputs identical,
/// no job lost or duplicated, every injected fault fired, every recovery
/// visible in the gauges. Exits non-zero on any violation, so CI can gate
/// on it.
fn run_chaos_harness(mut cfg: DasConfig, plan: &str, workers: usize) -> Result<()> {
    use das::rollout::{DataParallelRollout, FaultPlan, GenJob};
    use das::workload::Workload;

    // Validate the plan up front: an unparseable plan must fail the run,
    // not silently degrade to "no faults injected".
    let parsed = FaultPlan::parse(plan).map_err(|e| anyhow::anyhow!("--fault-plan: {e}"))?;
    anyhow::ensure!(!parsed.is_empty(), "--fault-plan parsed to zero directives");
    // Equivalence is a greedy (temperature 0) property: speculation, shard
    // placement and recovery are all output-invariant only when decoding is
    // deterministic.
    if cfg.rollout.temperature != 0.0 {
        println!(
            "chaos: forcing temperature {} -> 0 (equivalence needs greedy decoding)",
            cfg.rollout.temperature
        );
        cfg.rollout.temperature = 0.0;
    }
    let workers = workers.max(1);
    let steps = cfg.train.steps.max(1);

    let mut chaos_cfg = cfg.clone();
    chaos_cfg.rollout.fault_plan = plan.to_string();
    // store-fail directives need a live store to fail; give the chaos arm a
    // scratch one when the config has none.
    let scratch = if plan.contains("store-fail") && chaos_cfg.spec.store_dir.is_empty() {
        let dir = std::env::temp_dir().join(format!("das-chaos-store-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        chaos_cfg.spec.store_dir = dir.to_string_lossy().into_owned();
        println!("chaos: store-fail injected; scratch store at {}", dir.display());
        Some(dir)
    } else {
        None
    };
    // The control arm never sees faults or the store: it is the pure
    // in-memory reference the chaos arm must reproduce byte for byte.
    let mut control_cfg = cfg.clone();
    control_cfg.rollout.fault_plan = String::new();
    control_cfg.spec.store_dir = String::new();

    let workload = Workload::from_config(&cfg);
    anyhow::ensure!(!workload.problems.is_empty(), "empty workload");
    let per_step = cfg.train.problems_per_step.max(1).min(workload.problems.len());
    let jobs_for = |step: usize| -> Vec<GenJob> {
        (0..per_step)
            .map(|i| {
                let p = &workload.problems[(step * per_step + i) % workload.problems.len()];
                GenJob {
                    problem: p.id,
                    prompt: p.prompt.clone(),
                    samples: cfg.rollout.samples_per_problem.max(1),
                }
            })
            .collect()
    };
    let sorted_keys = |rollouts: &[das::tokens::Rollout]| {
        let mut k: Vec<_> = rollouts
            .iter()
            .map(|r| (r.problem, r.tokens.clone()))
            .collect();
        k.sort();
        k
    };

    println!(
        "chaos harness: {workers} workers, {steps} steps, plan \"{plan}\" \
         ({} directives)",
        parsed.len()
    );
    let control: Vec<_> = {
        let mut dp = DataParallelRollout::new(&control_cfg, workers);
        (0..steps)
            .map(|step| {
                dp.roll_epoch(step as u32);
                let rep = dp.generate_step(&jobs_for(step), step as u32);
                dp.policy_update(1.0);
                sorted_keys(&rep.rollouts)
            })
            .collect()
    };

    let mut dp = DataParallelRollout::new(&chaos_cfg, workers);
    let mut totals = das::rollout::StepMetrics::default();
    let mut violations = 0usize;
    for step in 0..steps {
        dp.roll_epoch(step as u32);
        let rep = dp.generate_step(&jobs_for(step), step as u32);
        dp.policy_update(1.0);
        let keys = sorted_keys(&rep.rollouts);
        let expected: usize = jobs_for(step).iter().map(|j| j.samples).sum();
        let ok = keys == control[step] && keys.len() == expected;
        if !ok {
            violations += 1;
        }
        totals.merge(&rep.supervision);
        for m in &rep.per_worker {
            totals.degraded_requests += m.degraded_requests;
            totals.store_failures += m.store_failures;
            totals.preemptions += m.preemptions;
            totals.resume_budget_boost = totals.resume_budget_boost.max(m.resume_budget_boost);
            totals.remote_round_trips += m.remote_round_trips;
            totals.remote_timeouts += m.remote_timeouts;
            totals.remote_degraded += m.remote_degraded;
        }
        println!(
            "step {:>3}  {}  rollouts {:>4}  restarts {}  redispatched {}  steals {}  \
             preempted {}  migrated {}  degraded {}  store-failures {}  makespan/oracle {:.2}",
            step,
            if ok { "match" } else { "MISMATCH" },
            keys.len(),
            rep.supervision.worker_restarts,
            rep.supervision.jobs_redispatched,
            rep.supervision.deadline_steals,
            rep.per_worker.iter().map(|m| m.preemptions).sum::<u64>(),
            rep.supervision.migrated_requests,
            rep.per_worker.iter().map(|m| m.degraded_requests).sum::<u64>(),
            rep.per_worker.iter().map(|m| m.store_failures).sum::<u64>(),
            rep.supervision.makespan_vs_oracle,
        );
    }
    let unfired = dp.fault_plan().unfired();
    drop(dp);
    if let Some(dir) = scratch {
        std::fs::remove_dir_all(&dir).ok();
    }
    println!(
        "chaos totals: restarts {}  redispatched {}  steals {}  preempted {}  migrated {}  \
         degraded {}  store-failures {}  worst makespan/oracle {:.2}",
        totals.worker_restarts,
        totals.jobs_redispatched,
        totals.deadline_steals,
        totals.preemptions,
        totals.migrated_requests,
        totals.degraded_requests,
        totals.store_failures,
        totals.makespan_vs_oracle,
    );
    anyhow::ensure!(
        violations == 0,
        "{violations} step(s) diverged from the fault-free control run"
    );
    anyhow::ensure!(
        unfired.is_empty(),
        "fault directives never fired (out-of-range worker/step/epoch?): {}",
        unfired.join("; ")
    );
    if parsed.kill_draftsvc_count() > 0 {
        // A fired kill-draftsvc directive must leave its footprint: remote
        // calls degrading to plain decoding after the daemon died. (The
        // output-equivalence check above already proved degradation was
        // lossless.) Requires spec.substrate=remote — under a local
        // substrate the directive fires but there is no daemon to lose.
        anyhow::ensure!(
            totals.remote_round_trips > 0 && totals.remote_degraded > 0,
            "kill-draftsvc directive fired but left no remote footprint \
             (round-trips {}, timeouts {}, degraded {} — is \
             spec.substrate=remote with a live daemon at spec.draft_addr? \
             a daemon that was never reachable degrades everything and \
             proves nothing about the kill)",
            totals.remote_round_trips,
            totals.remote_timeouts,
            totals.remote_degraded
        );
        println!(
            "remote footprint: {} round-trips, {} timeouts, {} degraded calls",
            totals.remote_round_trips, totals.remote_timeouts, totals.remote_degraded
        );
    }
    if parsed.preempt_count() > 0 {
        // A fired preempt directive must leave its full footprint: a frozen
        // chunk, migrated checkpoints, and the escalated-budget gauge.
        anyhow::ensure!(
            totals.preemptions > 0 && totals.migrated_requests > 0,
            "preempt directive fired but left no preemption footprint \
             (preemptions {}, migrated {})",
            totals.preemptions,
            totals.migrated_requests
        );
        anyhow::ensure!(
            totals.resume_budget_boost >= 1.0,
            "resumed requests must surface their budget boost (got {})",
            totals.resume_budget_boost
        );
    }
    println!("chaos equivalence OK: outputs identical, all {} faults fired", parsed.len());
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("das serve", "rollout-only serving over a trace")
        .opt("preset", "named preset", Some("trace"))
        .opt("steps", "generation steps", Some("5"))
        .opt("seed", "random seed", None);
    let args = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    let mut cfg = preset(args.get_or("preset", "trace"))
        .ok_or_else(|| anyhow::anyhow!("unknown preset"))?;
    if let Some(seed) = args.get_u64("seed") {
        cfg.seed = seed;
    }
    let steps = args.get_usize("steps").unwrap_or(5);
    let mut trainer = Trainer::new(cfg.clone());
    let mut model = SimModel::new(SimModelConfig::from_das(&cfg));
    let mut total = 0.0;
    let mut toks = 0u64;
    for step in 0..steps {
        let s = trainer.step_sim(&mut model, step as u32);
        total += s.metrics.gen_time;
        toks += s.metrics.generated;
        println!(
            "step {:>3}  gen {:.3}s  eff-batch start {} end {}  accept {:.2}",
            step,
            s.metrics.gen_time,
            s.metrics.eff_batch.first().copied().unwrap_or(0),
            s.metrics.eff_batch.last().copied().unwrap_or(0),
            s.metrics.accept_rate()
        );
    }
    println!(
        "served {toks} tokens in {total:.3}s model-time ({:.0} tok/s)",
        toks as f64 / total.max(1e-9)
    );
    Ok(())
}

/// `das serve-drafts`: run the draft daemon — one `SuffixDrafter` (plus an
/// optional persistent store it warm-starts from and WAL-logs into) behind
/// the das-draft-rpc-v1 wire protocol, serving `spec.substrate = "remote"`
/// training runs. Blocks until a client sends `Shutdown`/`Die`.
fn cmd_serve_drafts(argv: &[String]) -> Result<()> {
    let cmd = Command::new("das serve-drafts", "draft daemon (das-draft-rpc-v1)")
        .opt("config", "JSON config file", None)
        .opt("preset", "named preset", Some("math_rl"))
        .opt("set", "single key=value override", None)
        .opt("dir", "persistent store directory (warm start + WAL; omit for in-memory)", None)
        .opt("addr", "listen address (use port 0 for an ephemeral port)", Some("127.0.0.1:7831"));
    let args = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    let cfg = load_config(&args)?;
    let dir = args.get("dir").map(Path::new);
    let addr = args.get_or("addr", "127.0.0.1:7831");
    let server = das::draftsvc::DraftServer::bind(&cfg.spec, dir, addr)?;
    let fp = server.fingerprint();
    println!(
        "das serve-drafts: listening on {} ({}; window {}, match_len {}, \
         max_depth {}, scope {}, store {})",
        server.local_addr(),
        das::draftsvc::PROTOCOL,
        fp.window,
        fp.match_len,
        fp.max_depth,
        fp.scope,
        dir.map(|d| d.display().to_string()).unwrap_or_else(|| "none".into()),
    );
    server.run();
    let failures = server.store_failures();
    anyhow::ensure!(
        failures == 0,
        "serve-drafts stopped with {failures} store write failure(s) — \
         run `das store verify --dir <dir>` before reusing the store"
    );
    println!("das serve-drafts: stopped cleanly");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_calibrate(_argv: &[String]) -> Result<()> {
    anyhow::bail!("das was built without the pjrt feature; rebuild with --features pjrt")
}

#[cfg(feature = "pjrt")]
fn cmd_calibrate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("das calibrate", "fit the latency model on PJRT")
        .opt("reps", "repetitions per length", Some("10"))
        .opt("artifacts", "artifacts directory", Some("artifacts"));
    let args = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    let mut model = PjrtModel::load(Path::new(args.get_or("artifacts", "artifacts")))?;
    let rep = model.calibrate(args.get_usize("reps").unwrap_or(10))?;
    println!(
        "t_fwd = {:.6}s + {:.3}µs/token   R²={:.4}  MRE={:.1}%  ({} samples)",
        rep.model.c_base,
        rep.model.c_tok * 1e6,
        rep.r_squared,
        rep.mre * 100.0,
        rep.n_points
    );
    Ok(())
}

fn cmd_store(argv: &[String]) -> Result<()> {
    let usage = "usage: das store <inspect|verify|compact> --dir <store-dir>";
    let action = match argv.first().map(|s| s.as_str()) {
        Some(a @ ("inspect" | "verify" | "compact")) => a,
        _ => anyhow::bail!("{usage}"),
    };
    let cmd = Command::new(
        "das store",
        "offline tools for a das-store-v1 history store",
    )
    .opt("dir", "store directory (DP runs persist per worker under <dir>/worker<i>)", None);
    let args = cmd.parse(&argv[1..]).map_err(anyhow::Error::msg)?;
    let dir = args
        .get("dir")
        .ok_or_else(|| anyhow::anyhow!("--dir required\n{usage}"))?;
    // Coordinator sidecar (DP runs write <dir>/coordinator.das next to the
    // per-worker stores): checksum it on the same read-only path. Drift is
    // fatal for `verify`, reported-but-tolerated for inspect/compact.
    match das::rollout::verify_coordinator_sidecar(Path::new(dir)) {
        Ok(None) => {}
        Ok(Some(bytes)) => println!("coordinator sidecar: {bytes} bytes, checksum OK"),
        Err(e) if action == "verify" => {
            anyhow::bail!("coordinator sidecar corrupt or unreadable: {e}")
        }
        Err(e) => println!("coordinator sidecar: CORRUPT ({e})"),
    }
    // inspect/verify are diagnostics: go through the read-only view so
    // they never repair (truncate/reset) the store being examined and work
    // on read-only media; only compact opens for writing.
    let view = HistoryStore::peek(Path::new(dir))?;
    let wal = view.wal;
    let (absorbs, rolls, registers) = wal.iter().fold((0u64, 0u64, 0u64), |(a, r, g), rec| {
        match rec {
            WalRecord::Absorb { .. } => (a + 1, r, g),
            WalRecord::RollEpoch(_) => (a, r + 1, g),
            WalRecord::Register { .. } => (a, r, g + 1),
        }
    });
    let st = view.status;
    println!(
        "store {dir}: snapshot {} bytes, WAL {} records / {} bytes \
         (absorb {absorbs}, roll_epoch {rolls}, register {registers})",
        st.snapshot_bytes, st.wal_records, st.wal_bytes
    );
    let Some(snapshot) = view.snapshot else {
        println!("no snapshot committed yet (WAL-only store): nothing to {action}");
        return Ok(());
    };
    // Everything the payload needs is inside it — no config file required.
    let (mut drafter, rc_mismatches) = SuffixDrafter::from_state_verified(&snapshot)?;
    println!(
        "snapshot: scope {}, substrate {}, window {}, epoch {}",
        drafter.scope().as_str(),
        drafter.substrate(),
        drafter.window(),
        drafter.epoch()
    );
    if rc_mismatches > 0 {
        println!(
            "note: {rc_mismatches} pool segment refcounts re-derived differently \
             (ephemeral request-local references dropped at save time)"
        );
    }
    match action {
        "inspect" => {
            let s = drafter.index_stats();
            println!(
                "restored index: {} nodes, {} token positions, {} heap bytes, \
                 pool {} segments / {} tokens; {} indexed tokens across shards",
                s.nodes,
                s.token_positions,
                s.heap_bytes,
                s.pool_segments,
                s.pool_tokens,
                drafter.indexed_tokens()
            );
        }
        "verify" => {
            replay_wal(&mut drafter, &wal);
            let s = drafter.index_stats();
            // Emptiness check only where eviction can't explain it: with a
            // bounded window, RollEpoch records later in the tail may
            // legitimately evict every replayed absorb (e.g. a crash right
            // after an epoch roll) — that store is still consistent.
            let evictable = drafter.substrate() == "window" && drafter.window() > 0;
            anyhow::ensure!(
                absorbs == 0 || evictable || drafter.indexed_tokens() > 0,
                "replayed store indexes nothing despite {absorbs} absorb records"
            );
            println!(
                "verify OK: snapshot + {} WAL records replay to {} indexed tokens \
                 ({} nodes / {} token positions)",
                wal.len(),
                drafter.indexed_tokens(),
                s.nodes,
                s.token_positions
            );
        }
        "compact" => {
            replay_wal(&mut drafter, &wal);
            let mut store = HistoryStore::open(Path::new(dir))?;
            store.commit_snapshot(&drafter.save_state())?;
            let after = store.status();
            println!(
                "compacted: snapshot {} -> {} bytes, WAL {} -> 0 bytes",
                st.snapshot_bytes, after.snapshot_bytes, st.wal_bytes
            );
        }
        _ => unreachable!(),
    }
    Ok(())
}

/// `das audit`: run the in-tree static-analysis pass (see `src/analysis/`)
/// and exit nonzero on any finding, so CI can gate on it.
fn cmd_audit(argv: &[String]) -> Result<()> {
    let cmd = Command::new("das audit", "static-analysis gate over the source tree")
        .opt("json", "also write the das-audit-v1 JSON report to this path", None)
        .opt("paths", "root directory to scan", None);
    let args = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    // Default scan root: works from the repo root and from rust/.
    let root = match args.get("paths") {
        Some(p) => PathBuf::from(p),
        None => {
            let from_repo_root = PathBuf::from("rust/src");
            if from_repo_root.is_dir() {
                from_repo_root
            } else {
                PathBuf::from("src")
            }
        }
    };
    anyhow::ensure!(root.is_dir(), "scan root {} is not a directory", root.display());
    let report = das::analysis::run_audit(&root)?;
    print!("{}", report.render());
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json().to_string())?;
        println!("wrote {path}");
    }
    anyhow::ensure!(
        report.findings.is_empty(),
        "{} audit finding(s) — fix the site or add a reasoned \
         `// audit: allow(<rule>) -- <why>` pragma",
        report.findings.len()
    );
    Ok(())
}

fn cmd_config(argv: &[String]) -> Result<()> {
    let cmd = Command::new("das config", "print the resolved configuration")
        .opt("config", "JSON config file", None)
        .opt("preset", "named preset", Some("math_rl"))
        .opt("set", "single key=value override", None)
        .opt("seed", "random seed", None);
    let args = cmd.parse(argv).map_err(anyhow::Error::msg)?;
    let cfg = load_config(&args)?;
    println!("{}", cfg.to_json().to_string());
    Ok(())
}

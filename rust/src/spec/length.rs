//! Dynamic draft budget via runtime length prediction (§4.2.3).
//!
//! Direct length prediction is hopeless — Fig. 9 shows per-problem lengths
//! are wildly dispersed — so the paper uses a hierarchical heuristic:
//!
//! 1. **Length classes** Long / Medium / Short, each mapped to a draft
//!    budget (Short disables speculation — §4.2.2 Obs. 2).
//! 2. **Initialization from history**: a request's initial class is the
//!    argmax of its problem's historical class distribution.
//! 3. **Runtime update**: as the partial length `l` grows, re-classify via
//!    `argmax_c P(c | l, Init)` estimated from historical rollouts — here a
//!    survival-statistics estimate `P(final class = c | L > l)` blended with
//!    the init prior.

use std::collections::HashMap;

use crate::store::{Reader, StoreError, Writer};
use crate::tokens::ProblemId;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LengthClass {
    Short,
    Medium,
    Long,
}

impl LengthClass {
    pub fn all() -> [LengthClass; 3] {
        [LengthClass::Short, LengthClass::Medium, LengthClass::Long]
    }

    pub fn index(self) -> usize {
        match self {
            LengthClass::Short => 0,
            LengthClass::Medium => 1,
            LengthClass::Long => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LengthClass::Short => "short",
            LengthClass::Medium => "medium",
            LengthClass::Long => "long",
        }
    }
}

/// Class thresholds plus historical statistics powering the classifier.
#[derive(Debug, Clone)]
pub struct LengthPolicy {
    /// Lengths < t_short ⇒ Short; < t_long ⇒ Medium; else Long.
    pub t_short: usize,
    pub t_long: usize,
    /// Recent final lengths per problem (bounded).
    history: HashMap<ProblemId, Vec<usize>>,
    /// Global pool of recent final lengths (for survival statistics).
    global: Vec<usize>,
    /// Keep at most this many samples per problem / globally.
    per_problem_cap: usize,
    global_cap: usize,
    /// Decayed per-problem (verification rounds, accepted draft tokens) —
    /// the speculation-quality half of the LPT cost key. Exponential decay
    /// so the estimate follows drafter quality as training drifts.
    accept_hist: HashMap<ProblemId, (f64, f64)>,
    accept_decay: f64,
}

impl LengthPolicy {
    /// Thresholds from quantiles of an initial length sample: Short below
    /// the median, Long above the 85th percentile (the tail that dominates
    /// makespan).
    pub fn from_samples(samples: &[usize]) -> Self {
        let mut v: Vec<usize> = samples.to_vec();
        v.sort_unstable();
        let q = |p: f64| -> usize {
            if v.is_empty() {
                0
            } else {
                v[((v.len() - 1) as f64 * p) as usize]
            }
        };
        LengthPolicy::new(q(0.5).max(1), q(0.85).max(2))
    }

    /// Thresholds derived from the configured generation cap (§4.2.3's
    /// initialization; refined online as real lengths arrive): Long above
    /// cap/4, Short below cap/16. Single source of truth shared by the
    /// rollout engine and the data-parallel coordinator so both classify
    /// lengths identically.
    pub fn from_das(cfg: &crate::config::DasConfig) -> Self {
        let t_long = (cfg.rollout.max_new_tokens / 4).max(2);
        let t_short = (cfg.rollout.max_new_tokens / 16).max(1);
        LengthPolicy::new(t_short, t_long)
    }

    pub fn new(t_short: usize, t_long: usize) -> Self {
        LengthPolicy {
            t_short,
            t_long: t_long.max(t_short + 1),
            history: HashMap::new(),
            global: Vec::new(),
            per_problem_cap: 64,
            global_cap: 4096,
            accept_hist: HashMap::new(),
            accept_decay: 0.9,
        }
    }

    pub fn classify(&self, final_len: usize) -> LengthClass {
        if final_len < self.t_short {
            LengthClass::Short
        } else if final_len < self.t_long {
            LengthClass::Medium
        } else {
            LengthClass::Long
        }
    }

    /// Record a completed rollout's final length.
    pub fn observe(&mut self, problem: ProblemId, final_len: usize) {
        let h = self.history.entry(problem).or_default();
        h.push(final_len);
        if h.len() > self.per_problem_cap {
            h.remove(0);
        }
        self.global.push(final_len);
        if self.global.len() > self.global_cap {
            self.global.remove(0);
        }
    }

    pub fn observations(&self, problem: ProblemId) -> usize {
        self.history.get(&problem).map(|h| h.len()).unwrap_or(0)
    }

    /// Record a finished request's speculation outcome: `rounds`
    /// verification rounds, `accepted` draft tokens kept in total (the
    /// per-problem aggregate of what [`super::AcceptanceEstimator`]
    /// observes per round).
    pub fn observe_acceptance(&mut self, problem: ProblemId, rounds: u64, accepted: u64) {
        if rounds == 0 {
            return;
        }
        let e = self.accept_hist.entry(problem).or_insert((0.0, 0.0));
        e.0 = e.0 * self.accept_decay + rounds as f64;
        e.1 = e.1 * self.accept_decay + accepted as f64;
    }

    /// Mean accepted draft tokens per verification round for this problem
    /// (0 with no speculation history).
    pub fn accepted_per_round(&self, problem: ProblemId) -> f64 {
        match self.accept_hist.get(&problem) {
            Some(&(rounds, accepted)) if rounds > 0.0 => accepted / rounds,
            _ => 0.0,
        }
    }

    /// Step 2: initial class from the problem's historical distribution
    /// (argmax class frequency; Medium when no history).
    pub fn init_class(&self, problem: ProblemId) -> LengthClass {
        let Some(h) = self.history.get(&problem) else {
            return LengthClass::Medium;
        };
        if h.is_empty() {
            return LengthClass::Medium;
        }
        let mut counts = [0usize; 3];
        for &l in h {
            counts[self.classify(l).index()] += 1;
        }
        Self::argmax_class(&counts.map(|c| c as f64))
    }

    /// Step 3: runtime update — `argmax_c P(c | L > partial_len, Init)`.
    ///
    /// `P(c | L > l)` comes from survival counts over the problem's (falling
    /// back to global) historical lengths; the init prior enters as one
    /// pseudo-count, which resolves ties toward the initial class and keeps
    /// the decision stable early in generation.
    pub fn runtime_class(
        &self,
        problem: ProblemId,
        partial_len: usize,
        init: LengthClass,
    ) -> LengthClass {
        // Deterministic fast path: the partial length already proves the
        // class floor — a sequence of length >= t_long IS Long.
        if partial_len >= self.t_long {
            return LengthClass::Long;
        }
        let pool: &[usize] = match self.history.get(&problem) {
            Some(h) if !h.is_empty() => h,
            _ => &self.global,
        };
        let mut counts = [0f64; 3];
        counts[init.index()] += 1.0; // prior pseudo-count
        for &l in pool {
            if l > partial_len {
                counts[self.classify(l).index()] += 1.0;
            }
        }
        // Survivors can't be Short if partial_len >= t_short.
        if partial_len >= self.t_short {
            counts[LengthClass::Short.index()] = 0.0;
        }
        Self::argmax_class(&counts)
    }

    fn argmax_class(counts: &[f64; 3]) -> LengthClass {
        // Ties break toward the LONGER class: under-speculating on a long
        // straggler costs more than over-speculating on a medium one.
        let mut best = LengthClass::Short;
        let mut best_v = f64::MIN;
        for c in LengthClass::all() {
            let v = counts[c.index()];
            if v >= best_v {
                best_v = v;
                best = c;
            }
        }
        best
    }

    /// Map a class to its configured draft budget per round.
    pub fn budget_for(class: LengthClass, cfg: &crate::config::SpecConfig) -> usize {
        match class {
            LengthClass::Short => cfg.budget_short,
            LengthClass::Medium => cfg.budget_medium,
            LengthClass::Long => cfg.budget_long,
        }
    }

    /// Predicted total generation length of a FRESH request of `problem`:
    /// the expected length under its historical init class. This is the
    /// per-job cost key the data-parallel coordinator uses for
    /// longest-predicted-first (LPT) sharding — the paper's makespan
    /// argument (§3) applied across workers instead of across requests.
    pub fn expected_total(&self, problem: ProblemId) -> f64 {
        let class = self.init_class(problem);
        self.expected_remaining(problem, 0, class)
    }

    /// Predicted device cost of one generation job. The single source of
    /// truth for LPT sharding keys (used by both
    /// `RolloutEngine::predict_job_cost` and the data-parallel
    /// coordinator).
    ///
    /// Cost = samples × expected total length ÷ (1 + accepted-per-round):
    /// each verification round commits 1 + accepted tokens, so a problem
    /// that speculates well takes proportionally fewer target forwards per
    /// generated token. Predicting from final lengths alone over-weighted
    /// exactly the long problems DAS accelerates the most, so LPT kept
    /// packing them as if speculation didn't exist. With no acceptance
    /// history the divisor is 1 and the key reduces to the pure
    /// length-based prediction.
    pub fn job_cost(&self, problem: ProblemId, samples: usize) -> f64 {
        let apr = self.accepted_per_round(problem);
        self.expected_total(problem) * samples.max(1) as f64 / (1.0 + apr)
    }

    /// Expected remaining length for a request in a class (used as `l_i` by
    /// the Eq. 7 allocator): mean of historical lengths in that class minus
    /// the partial length, floored at a small positive value.
    pub fn expected_remaining(
        &self,
        problem: ProblemId,
        partial_len: usize,
        class: LengthClass,
    ) -> f64 {
        let pool: &[usize] = match self.history.get(&problem) {
            Some(h) if !h.is_empty() => h,
            _ => &self.global,
        };
        let in_class: Vec<f64> = pool
            .iter()
            .filter(|&&l| self.classify(l) == class && l > partial_len)
            .map(|&l| l as f64)
            .collect();
        let mean_final = if in_class.is_empty() {
            match class {
                LengthClass::Short => self.t_short as f64 * 0.5,
                LengthClass::Medium => (self.t_short + self.t_long) as f64 * 0.5,
                LengthClass::Long => self.t_long as f64 * 1.5,
            }
        } else {
            in_class.iter().sum::<f64>() / in_class.len() as f64
        };
        (mean_final - partial_len as f64).max(1.0)
    }

    /// Serialize the full predictor state (thresholds + length history +
    /// decayed acceptance aggregates) into a wire section. Hash maps are
    /// emitted sorted by problem id so identical states produce identical
    /// bytes — the coordinator checksums this section.
    pub fn save_state(&self, w: &mut Writer) {
        w.str("length-policy");
        w.usize(self.t_short);
        w.usize(self.t_long);
        let mut pids: Vec<ProblemId> = self.history.keys().copied().collect();
        pids.sort_unstable();
        w.usize(pids.len());
        for p in pids {
            w.u32(p);
            let h = &self.history[&p];
            w.usize(h.len());
            for &l in h {
                w.usize(l);
            }
        }
        w.usize(self.global.len());
        for &l in &self.global {
            w.usize(l);
        }
        let mut aids: Vec<ProblemId> = self.accept_hist.keys().copied().collect();
        aids.sort_unstable();
        w.usize(aids.len());
        for p in aids {
            let (rounds, accepted) = self.accept_hist[&p];
            w.u32(p);
            w.f64(rounds);
            w.f64(accepted);
        }
    }

    /// Inverse of [`save_state`](Self::save_state). Caps and decay are code
    /// constants (not persisted); restored series are re-capped so a state
    /// saved by a build with larger caps still loads bounded.
    pub fn load_state(r: &mut Reader) -> Result<LengthPolicy, StoreError> {
        r.expect_str("length-policy", "length policy section")?;
        let t_short = r.usize()?;
        let t_long = r.usize()?;
        let mut policy = LengthPolicy::new(t_short, t_long);
        let n_problems = r.count(12)?;
        for _ in 0..n_problems {
            let p = r.u32()?;
            let n_lens = r.count(8)?;
            let mut lens = Vec::with_capacity(n_lens);
            for _ in 0..n_lens {
                lens.push(r.usize()?);
            }
            let skip = lens.len().saturating_sub(policy.per_problem_cap);
            policy.history.insert(p, lens.split_off(skip));
        }
        let n_global = r.count(8)?;
        let mut global = Vec::with_capacity(n_global);
        for _ in 0..n_global {
            global.push(r.usize()?);
        }
        let skip = global.len().saturating_sub(policy.global_cap);
        policy.global = global.split_off(skip);
        let n_accept = r.count(20)?;
        for _ in 0..n_accept {
            let p = r.u32()?;
            let rounds = r.f64()?;
            let accepted = r.f64()?;
            policy.accept_hist.insert(p, (rounds, accepted));
        }
        Ok(policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> LengthPolicy {
        LengthPolicy::new(100, 400)
    }

    #[test]
    fn classify_thresholds() {
        let p = policy();
        assert_eq!(p.classify(10), LengthClass::Short);
        assert_eq!(p.classify(99), LengthClass::Short);
        assert_eq!(p.classify(100), LengthClass::Medium);
        assert_eq!(p.classify(399), LengthClass::Medium);
        assert_eq!(p.classify(400), LengthClass::Long);
    }

    #[test]
    fn from_samples_quantiles() {
        let samples: Vec<usize> = (1..=100).collect();
        let p = LengthPolicy::from_samples(&samples);
        assert_eq!(p.t_short, 50);
        assert_eq!(p.t_long, 85);
    }

    #[test]
    fn init_class_follows_history() {
        let mut p = policy();
        for _ in 0..5 {
            p.observe(7, 800);
        }
        p.observe(7, 50);
        assert_eq!(p.init_class(7), LengthClass::Long);
        assert_eq!(p.init_class(99), LengthClass::Medium); // unseen problem
    }

    #[test]
    fn runtime_class_long_once_past_threshold() {
        let p = policy();
        assert_eq!(
            p.runtime_class(1, 400, LengthClass::Short),
            LengthClass::Long
        );
    }

    #[test]
    fn runtime_class_excludes_short_after_t_short() {
        let mut p = policy();
        for _ in 0..10 {
            p.observe(3, 50); // history says short...
        }
        // ...but we've already generated 150 tokens.
        let c = p.runtime_class(3, 150, LengthClass::Short);
        assert_ne!(c, LengthClass::Short);
    }

    #[test]
    fn runtime_class_uses_survival_statistics() {
        let mut p = policy();
        // Problem 5: most rollouts are medium (~200), a few are very long.
        for _ in 0..8 {
            p.observe(5, 200);
        }
        for _ in 0..2 {
            p.observe(5, 900);
        }
        // Early on, survivors are mostly medium.
        assert_eq!(
            p.runtime_class(5, 10, LengthClass::Medium),
            LengthClass::Medium
        );
        // Past 200, only the long ones survive.
        assert_eq!(
            p.runtime_class(5, 250, LengthClass::Medium),
            LengthClass::Long
        );
    }

    #[test]
    fn history_capped() {
        let mut p = policy();
        for i in 0..200 {
            p.observe(1, i);
        }
        assert_eq!(p.observations(1), 64);
    }

    #[test]
    fn expected_remaining_positive_and_decreasing() {
        let mut p = policy();
        for _ in 0..10 {
            p.observe(2, 600);
        }
        let a = p.expected_remaining(2, 0, LengthClass::Long);
        let b = p.expected_remaining(2, 300, LengthClass::Long);
        assert!(a > b);
        assert!(b >= 1.0);
        // No data at all: falls back to threshold-derived guesses.
        let c = p.expected_remaining(77, 0, LengthClass::Medium);
        assert!(c > 0.0);
    }

    #[test]
    fn expected_total_tracks_problem_history() {
        let mut p = policy();
        for _ in 0..10 {
            p.observe(1, 800); // long problem
        }
        for _ in 0..10 {
            p.observe(2, 20); // short problem
        }
        assert!(p.expected_total(1) > p.expected_total(2));
        // Unseen problems fall back to the Medium-class prior.
        let fresh = p.expected_total(777);
        assert!(fresh > 0.0);
    }

    #[test]
    fn acceptance_history_discounts_job_cost() {
        // Two problems with identical length history; one speculates well.
        let mut p = policy();
        for _ in 0..10 {
            p.observe(1, 600);
            p.observe(2, 600);
        }
        let base = p.job_cost(1, 2);
        assert!((base - p.job_cost(2, 2)).abs() < 1e-9, "same history, same cost");
        // Problem 1 accepts ~3 draft tokens per round → ~4× fewer forwards.
        for _ in 0..5 {
            p.observe_acceptance(1, 100, 300);
        }
        let fast = p.job_cost(1, 2);
        assert!(
            fast < base * 0.3,
            "well-speculating problem must stop being over-weighted: {fast} vs {base}"
        );
        assert!((p.job_cost(2, 2) - base).abs() < 1e-9, "no-history problem unchanged");
        assert!((p.accepted_per_round(1) - 3.0).abs() < 1e-9);
        assert_eq!(p.accepted_per_round(99), 0.0);
    }

    #[test]
    fn acceptance_history_decays_with_drift() {
        let mut p = policy();
        for _ in 0..20 {
            p.observe_acceptance(7, 10, 30); // apr 3.0
        }
        assert!(p.accepted_per_round(7) > 2.9);
        // Drafter went stale: rounds keep coming, nothing accepted.
        for _ in 0..40 {
            p.observe_acceptance(7, 10, 0);
        }
        assert!(p.accepted_per_round(7) < 0.2, "apr={}", p.accepted_per_round(7));
        // Zero-round observations are ignored.
        p.observe_acceptance(8, 0, 0);
        assert_eq!(p.accepted_per_round(8), 0.0);
    }

    #[test]
    fn state_roundtrips_with_identical_job_costs() {
        let mut p = policy();
        for i in 0..12u32 {
            for k in 0..(5 + i as usize) {
                p.observe(i, 30 + 60 * k);
            }
            p.observe_acceptance(i, 10 + i as u64, 2 * i as u64);
        }
        let mut w = Writer::new();
        p.save_state(&mut w);
        let bytes = w.into_bytes();
        // Deterministic bytes: saving the same state twice is bit-identical.
        let mut w2 = Writer::new();
        p.save_state(&mut w2);
        assert_eq!(bytes, w2.into_bytes());
        let q = LengthPolicy::load_state(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(q.t_short, p.t_short);
        assert_eq!(q.t_long, p.t_long);
        for i in 0..12u32 {
            assert_eq!(q.observations(i), p.observations(i));
            for samples in [1, 2, 8] {
                let (a, b) = (p.job_cost(i, samples), q.job_cost(i, samples));
                assert!((a - b).abs() < 1e-12, "job_cost({i},{samples}): {a} vs {b}");
            }
            assert!((p.accepted_per_round(i) - q.accepted_per_round(i)).abs() < 1e-12);
        }
        // Unseen problems agree too (global pool restored).
        assert!((p.job_cost(999, 2) - q.job_cost(999, 2)).abs() < 1e-12);
    }

    #[test]
    fn truncated_state_is_an_error_not_a_panic() {
        let mut p = policy();
        p.observe(1, 50);
        p.observe_acceptance(1, 4, 8);
        let mut w = Writer::new();
        p.save_state(&mut w);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            assert!(
                LengthPolicy::load_state(&mut Reader::new(&bytes[..cut])).is_err(),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn budget_mapping() {
        let cfg = crate::config::DasConfig::default().spec;
        assert_eq!(LengthPolicy::budget_for(LengthClass::Short, &cfg), cfg.budget_short);
        assert_eq!(LengthPolicy::budget_for(LengthClass::Long, &cfg), cfg.budget_long);
    }
}

//! Saturating acceptance model (§4.2.2 Eq. 3, Appendix C).
//!
//! `A_i(p) = k_i · l_i · (1 − e^{−α_i p / l_i})` — the total number of
//! accepted tokens for request `i` as a function of its proposed-token
//! budget `p`, saturating at `k_i·l_i` (the intrinsic drafter/target
//! mismatch limit). [`AcceptanceEstimator`] fits `(α, k)` online from the
//! observed (proposed, accepted) pairs of recent verification rounds, so the
//! budget optimizer tracks the drafter's actual quality as training evolves.

/// Per-request acceptance-curve parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AcceptanceParams {
    /// Draft efficiency `α > 0`: how fast acceptance accrues with budget.
    pub alpha: f64,
    /// Capacity factor `k ∈ (0, 1]`: max achievable accepted fraction.
    pub k: f64,
}

impl Default for AcceptanceParams {
    fn default() -> Self {
        // Conservative prior: a mediocre drafter.
        AcceptanceParams { alpha: 1.0, k: 0.5 }
    }
}

impl AcceptanceParams {
    /// Eq. 3: expected accepted tokens given total proposed budget `p` for a
    /// request with target length `l`.
    pub fn accepted(&self, p: f64, l: f64) -> f64 {
        if l <= 0.0 {
            return 0.0;
        }
        self.k * l * (1.0 - (-self.alpha * p / l).exp())
    }

    /// Remaining tokens after speculation: `l − A(p)` (pre-Eq. 4 identity).
    pub fn remaining(&self, p: f64, l: f64) -> f64 {
        l * (1.0 - self.k + self.k * (-self.alpha * p / l).exp())
    }
}

/// Online estimator of `(α, k)` from verification-round outcomes.
///
/// Each round contributes one `(d, a)` point: `d` tokens proposed, `a`
/// accepted (a ≤ d). In the small-budget regime Eq. 3 is `A ≈ α·p`, so α is
/// estimated from the per-round acceptance ratio; `k` is estimated from the
/// empirical ceiling — the high-quantile of per-round acceptance fractions —
/// since rounds that keep accepting everything indicate a high mismatch
/// limit. Exponentially decayed so the estimate follows policy drift.
#[derive(Debug, Clone)]
pub struct AcceptanceEstimator {
    /// Decayed sums for the linear-regime α fit.
    sum_d: f64,
    sum_a: f64,
    /// Decayed count of rounds that were fully accepted vs total.
    full_rounds: f64,
    rounds: f64,
    /// Decay per observation.
    decay: f64,
}

impl Default for AcceptanceEstimator {
    fn default() -> Self {
        Self::new(0.98)
    }
}

impl AcceptanceEstimator {
    pub fn new(decay: f64) -> Self {
        AcceptanceEstimator {
            sum_d: 0.0,
            sum_a: 0.0,
            full_rounds: 0.0,
            rounds: 0.0,
            decay,
        }
    }

    /// Record one verification round: `proposed` draft tokens, `accepted` of
    /// them kept.
    pub fn observe(&mut self, proposed: usize, accepted: usize) {
        debug_assert!(accepted <= proposed);
        if proposed == 0 {
            return;
        }
        self.sum_d = self.sum_d * self.decay + proposed as f64;
        self.sum_a = self.sum_a * self.decay + accepted as f64;
        self.rounds = self.rounds * self.decay + 1.0;
        if accepted == proposed {
            self.full_rounds = self.full_rounds * self.decay + 1.0;
        } else {
            self.full_rounds *= self.decay;
        }
    }

    pub fn observations(&self) -> f64 {
        self.rounds
    }

    /// Current `(α, k)` estimate (prior when too few observations).
    pub fn params(&self) -> AcceptanceParams {
        if self.rounds < 3.0 || self.sum_d <= 0.0 {
            return AcceptanceParams::default();
        }
        let ratio = (self.sum_a / self.sum_d).clamp(0.01, 0.99);
        // Linear regime: A ≈ α p  ⇒  α ≈ accept ratio (per proposed token).
        let alpha = ratio;
        // Ceiling: fraction of rounds that were fully accepted lifts k above
        // the mean ratio; never below the observed mean ratio itself.
        let full_frac = (self.full_rounds / self.rounds).clamp(0.0, 1.0);
        let k = (ratio + (1.0 - ratio) * full_frac).clamp(0.05, 1.0);
        AcceptanceParams { alpha, k }
    }

    /// Mean per-round acceptance ratio (diagnostic; Figs. 4/6/7 series).
    pub fn mean_ratio(&self) -> f64 {
        if self.sum_d <= 0.0 {
            0.0
        } else {
            self.sum_a / self.sum_d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepted_saturates_at_k_l() {
        let p = AcceptanceParams { alpha: 2.0, k: 0.8 };
        let l = 100.0;
        assert!(p.accepted(0.0, l).abs() < 1e-12);
        let huge = p.accepted(1e6, l);
        assert!((huge - 80.0).abs() < 1e-6, "saturation at k*l, got {huge}");
        // Monotone in p.
        assert!(p.accepted(10.0, l) < p.accepted(20.0, l));
    }

    #[test]
    fn remaining_complements_accepted() {
        let p = AcceptanceParams { alpha: 1.5, k: 0.7 };
        let (bud, l) = (30.0, 200.0);
        assert!((p.accepted(bud, l) + p.remaining(bud, l) - l).abs() < 1e-9);
    }

    #[test]
    fn estimator_tracks_good_drafter() {
        let mut e = AcceptanceEstimator::default();
        for _ in 0..50 {
            e.observe(8, 8); // everything accepted
        }
        let p = e.params();
        assert!(p.k > 0.9, "k={}", p.k);
        assert!(p.alpha > 0.9, "alpha={}", p.alpha);
    }

    #[test]
    fn estimator_tracks_weak_drafter() {
        let mut e = AcceptanceEstimator::default();
        for _ in 0..50 {
            e.observe(8, 1);
        }
        let p = e.params();
        assert!(p.k < 0.4, "k={}", p.k);
        assert!(p.alpha < 0.2, "alpha={}", p.alpha);
    }

    #[test]
    fn estimator_adapts_to_drift() {
        let mut e = AcceptanceEstimator::new(0.9);
        for _ in 0..100 {
            e.observe(8, 8);
        }
        for _ in 0..100 {
            e.observe(8, 1); // drafter went stale
        }
        assert!(e.params().k < 0.4);
    }

    #[test]
    fn few_observations_fall_back_to_prior() {
        let mut e = AcceptanceEstimator::default();
        e.observe(4, 4);
        assert_eq!(e.params(), AcceptanceParams::default());
    }

    #[test]
    fn zero_proposed_ignored() {
        let mut e = AcceptanceEstimator::default();
        e.observe(0, 0);
        assert_eq!(e.observations(), 0.0);
    }
}

//! Length-aware speculation policy and lossless verification (§4.2).
//!
//! * [`acceptance`] — the saturating acceptance model (Eq. 3) and its online
//!   `(α, k)` estimator.
//! * [`budget`] — the optimal speculative-token budget (Eq. 5–9; with a
//!   documented correction to the printed Eq. 7).
//! * [`length`] — Long/Medium/Short length classes with history-initialized,
//!   survival-updated runtime classification (§4.2.3).
//! * [`verify`] — exact speculative-sampling verification (lossless).

pub mod acceptance;
pub mod budget;
pub mod lenience;
pub mod length;
pub mod verify;

pub use acceptance::{AcceptanceEstimator, AcceptanceParams};
pub use budget::{solve as solve_budget, BudgetRequest, BudgetSolution};
pub use length::{LengthClass, LengthPolicy};
pub use verify::{verify_greedy, verify_sampling, VerifyOutcome};

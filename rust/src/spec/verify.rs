//! Lossless draft verification (speculative sampling).
//!
//! DAS is a *lossless* acceleration: verification must preserve the target
//! model's output distribution exactly (the paper's "identical training
//! curves" claim rests on this). Our drafter is nonparametric and proposes a
//! deterministic token sequence — a point-mass proposal `q`. For point-mass
//! proposals the Leviathan-style accept/resample rule specializes to:
//!
//! * accept draft token `y` with probability `p(y)`;
//! * on rejection, sample from `p` restricted to `x ≠ y`, renormalized
//!   (`norm(max(p − q, 0))` with `q = δ_y`).
//!
//! Summing the two branches returns exactly `p` — verified distributionally
//! in the tests below. At temperature 0 verification degenerates to "accept
//! while the draft equals the argmax", which makes speculative greedy decode
//! *bit-identical* to non-speculative greedy decode (a property test in
//! `rollout::engine` enforces this end-to-end).
//!
//! Every round emits at least one token: either the first correction or, if
//! the whole draft is accepted, a bonus token sampled from the last
//! distribution — the standard "draft K, get up to K+1" guarantee.

use crate::tokens::TokenId;
use crate::util::rng::Rng;

/// Result of verifying one draft block for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    /// Number of draft tokens accepted (prefix of the draft).
    pub accepted: usize,
    /// Emitted tokens: the accepted draft prefix plus exactly one extra
    /// (correction on rejection, bonus on full acceptance).
    pub tokens: Vec<TokenId>,
}

/// Argmax with deterministic tie-breaking (lowest token id), so greedy
/// decode is reproducible across runs and backends.
pub fn greedy_token(probs: &[f32]) -> TokenId {
    let mut best = 0usize;
    let mut best_p = f32::MIN;
    for (i, &p) in probs.iter().enumerate() {
        if p > best_p {
            best_p = p;
            best = i;
        }
    }
    best as TokenId
}

/// Temperature softmax over raw logits (T = 0 handled by callers via
/// [`greedy_token`]). Numerically stabilized.
pub fn softmax_with_temperature(logits: &[f32], temperature: f64) -> Vec<f32> {
    let t = temperature.max(1e-6) as f32;
    let m = logits.iter().fold(f32::MIN, |a, &b| a.max(b));
    let mut out: Vec<f32> = logits.iter().map(|&l| ((l - m) / t).exp()).collect();
    let s: f32 = out.iter().sum();
    if s > 0.0 {
        for p in &mut out {
            *p /= s;
        }
    } else {
        let u = 1.0 / out.len() as f32;
        for p in &mut out {
            *p = u;
        }
    }
    out
}

/// Sample a token from a normalized distribution.
pub fn sample(probs: &[f32], rng: &mut Rng) -> TokenId {
    rng.categorical_f32(probs).unwrap_or(0) as TokenId
}

/// Sample from `p` with token `banned` excluded and the rest renormalized —
/// the residual distribution `norm(max(p − δ_banned, 0))` for a point-mass
/// proposal.
pub fn sample_residual(probs: &[f32], banned: TokenId, rng: &mut Rng) -> TokenId {
    let total: f64 = probs
        .iter()
        .enumerate()
        .filter(|(i, _)| *i as TokenId != banned)
        .map(|(_, &p)| p as f64)
        .sum();
    if total <= 0.0 {
        // Degenerate: p was a point mass on the banned token. Emit it — the
        // residual is empty only when p(banned) = 1, in which case emitting
        // `banned` is still a sample from p.
        return banned;
    }
    let mut u = rng.next_f64() * total;
    for (i, &p) in probs.iter().enumerate() {
        if i as TokenId == banned {
            continue;
        }
        u -= p as f64;
        if u < 0.0 {
            return i as TokenId;
        }
    }
    // Fallback for fp rounding.
    probs
        .iter()
        .enumerate()
        .rev()
        .find(|(i, &p)| *i as TokenId != banned && p > 0.0)
        .map(|(i, _)| i as TokenId)
        .unwrap_or(banned)
}

/// Greedy (T = 0) verification: accept while the draft matches the argmax;
/// emit the argmax correction on mismatch, or the bonus argmax when the
/// whole draft holds. `dists[t]` is the target distribution at draft
/// position `t`; `dists.len() == draft.len() + 1`.
pub fn verify_greedy(draft: &[TokenId], dists: &[Vec<f32>]) -> VerifyOutcome {
    assert_eq!(dists.len(), draft.len() + 1, "need K+1 distributions");
    let mut tokens = Vec::with_capacity(draft.len() + 1);
    for (t, &d) in draft.iter().enumerate() {
        let top = greedy_token(&dists[t]);
        if top == d {
            tokens.push(d);
        } else {
            tokens.push(top);
            return VerifyOutcome { accepted: t, tokens };
        }
    }
    tokens.push(greedy_token(&dists[draft.len()]));
    VerifyOutcome {
        accepted: draft.len(),
        tokens,
    }
}

/// Stochastic verification for a point-mass proposal (see module docs).
/// `dists` are already temperature-adjusted probability vectors.
pub fn verify_sampling(draft: &[TokenId], dists: &[Vec<f32>], rng: &mut Rng) -> VerifyOutcome {
    assert_eq!(dists.len(), draft.len() + 1, "need K+1 distributions");
    let mut tokens = Vec::with_capacity(draft.len() + 1);
    for (t, &d) in draft.iter().enumerate() {
        let p_d = dists[t].get(d as usize).copied().unwrap_or(0.0) as f64;
        if rng.next_f64() < p_d {
            tokens.push(d);
        } else {
            tokens.push(sample_residual(&dists[t], d, rng));
            return VerifyOutcome { accepted: t, tokens };
        }
    }
    tokens.push(sample(&dists[draft.len()], rng));
    VerifyOutcome {
        accepted: draft.len(),
        tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn dist(ps: &[f32]) -> Vec<f32> {
        ps.to_vec()
    }

    #[test]
    fn greedy_accepts_matching_prefix() {
        let draft = [2u32, 0, 1];
        let dists = vec![
            dist(&[0.1, 0.2, 0.7]), // argmax 2 == draft ✓
            dist(&[0.9, 0.05, 0.05]), // argmax 0 == draft ✓
            dist(&[0.2, 0.3, 0.5]), // argmax 2 != draft(1) ✗ -> emit 2
            dist(&[1.0, 0.0, 0.0]), // unused
        ];
        let out = verify_greedy(&draft, &dists);
        assert_eq!(out.accepted, 2);
        assert_eq!(out.tokens, vec![2, 0, 2]);
    }

    #[test]
    fn greedy_full_acceptance_gets_bonus() {
        let draft = [1u32];
        let dists = vec![dist(&[0.0, 1.0]), dist(&[1.0, 0.0])];
        let out = verify_greedy(&draft, &dists);
        assert_eq!(out.accepted, 1);
        assert_eq!(out.tokens, vec![1, 0]); // draft + bonus argmax
    }

    #[test]
    fn greedy_tie_breaks_low_token() {
        assert_eq!(greedy_token(&[0.5, 0.5]), 0);
    }

    #[test]
    fn empty_draft_emits_one_token() {
        let out = verify_greedy(&[], &[dist(&[0.0, 1.0])]);
        assert_eq!(out.accepted, 0);
        assert_eq!(out.tokens, vec![1]);
        let mut rng = Rng::seed_from_u64(1);
        let out = verify_sampling(&[], &[dist(&[0.0, 1.0])], &mut rng);
        assert_eq!(out.tokens, vec![1]);
    }

    #[test]
    fn softmax_temperature_sharpens() {
        let logits = [1.0f32, 2.0, 3.0];
        let hot = softmax_with_temperature(&logits, 2.0);
        let cold = softmax_with_temperature(&logits, 0.25);
        assert!(cold[2] > hot[2]);
        let s: f32 = hot.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn residual_excludes_banned() {
        let mut rng = Rng::seed_from_u64(3);
        let p = dist(&[0.5, 0.3, 0.2]);
        for _ in 0..200 {
            assert_ne!(sample_residual(&p, 0, &mut rng), 0);
        }
    }

    #[test]
    fn residual_degenerate_point_mass() {
        let mut rng = Rng::seed_from_u64(3);
        let p = dist(&[1.0, 0.0, 0.0]);
        assert_eq!(sample_residual(&p, 0, &mut rng), 0);
    }

    /// The heart of losslessness: for ANY draft token, the marginal
    /// distribution of the first emitted token equals the target
    /// distribution p.
    #[test]
    fn spec_sampling_preserves_target_distribution() {
        let p = dist(&[0.55, 0.25, 0.15, 0.05]);
        for draft_tok in 0..4u32 {
            let mut rng = Rng::seed_from_u64(1000 + draft_tok as u64);
            let n = 200_000;
            let mut counts = [0usize; 4];
            for _ in 0..n {
                let out = verify_sampling(&[draft_tok], &[p.clone(), p.clone()], &mut rng);
                counts[out.tokens[0] as usize] += 1;
            }
            for (i, &c) in counts.iter().enumerate() {
                let emp = c as f64 / n as f64;
                assert!(
                    (emp - p[i] as f64).abs() < 0.01,
                    "draft={draft_tok} token={i}: emp={emp} want={}",
                    p[i]
                );
            }
        }
    }

    /// Multi-position drafts: the JOINT first-two-token distribution must
    /// match ancestral sampling from p1 then p2.
    #[test]
    fn spec_sampling_preserves_joint_distribution() {
        let p1 = dist(&[0.6, 0.4]);
        let p2 = dist(&[0.3, 0.7]);
        let draft = [0u32, 0u32];
        let mut rng = Rng::seed_from_u64(77);
        let n = 300_000;
        let mut joint = [[0usize; 2]; 2];
        for _ in 0..n {
            let out = verify_sampling(&draft, &[p1.clone(), p2.clone(), p2.clone()], &mut rng);
            if out.tokens.len() >= 2 {
                joint[out.tokens[0] as usize][out.tokens[1] as usize] += 1;
            } else {
                // Rejected at position 0: only one token emitted; second
                // token would come from a fresh round. Count the marginal.
                joint[out.tokens[0] as usize][0] += 0; // not part of joint test
            }
        }
        // When two tokens are emitted, first token must be the accepted
        // draft (0); check P(second=j | first=0) == p2[j].
        let total: usize = joint[0].iter().sum();
        if total > 10_000 {
            for j in 0..2 {
                let emp = joint[0][j] as f64 / total as f64;
                assert!(
                    (emp - p2[j] as f64).abs() < 0.01,
                    "cond dist mismatch: {emp} vs {}",
                    p2[j]
                );
            }
        }
    }

    #[test]
    fn prop_outcome_shape_invariants() {
        prop::check(128, |g| {
            let vocab = 2 + g.usize_in(0, 6);
            let k = g.usize_in(0, 6);
            let draft: Vec<u32> = (0..k).map(|_| g.rng.below(vocab) as u32).collect();
            let dists: Vec<Vec<f32>> = (0..=k)
                .map(|_| {
                    let mut v: Vec<f32> = (0..vocab).map(|_| g.rng.next_f32() + 1e-3).collect();
                    let s: f32 = v.iter().sum();
                    v.iter_mut().for_each(|x| *x /= s);
                    v
                })
                .collect();
            let mut rng = g.rng.fork(9);
            for out in [
                verify_greedy(&draft, &dists),
                verify_sampling(&draft, &dists, &mut rng),
            ] {
                prop::require(out.accepted <= draft.len(), "accepted <= draft len")?;
                prop::require_eq(out.tokens.len(), out.accepted + 1, "emit accepted+1 tokens")?;
                prop::require(
                    out.tokens[..out.accepted] == draft[..out.accepted],
                    "emitted prefix equals accepted draft prefix",
                )?;
                prop::require(
                    out.tokens.iter().all(|&t| (t as usize) < vocab),
                    "tokens in vocab",
                )?;
            }
            Ok(())
        });
    }
}

//! Optimal speculative-token budget allocation (§4.2.2, Eq. 5–9).
//!
//! Given a batch of requests with predicted lengths `l_i` and acceptance
//! parameters `(α_i, k_i)`, the rollout-latency objective (Eq. 5)
//!
//! ```text
//! J(p) = c_base · max_i[ l_i (1 − k_i + k_i e^{−α_i p_i / l_i}) ]
//!        + c_tok · Σ_i p_i + C
//! ```
//!
//! has, at optimality, a tight constraint for every active request. Solving
//! `l(1−k+k·e^{−αp/l}) = N_fwd` for `p` gives
//!
//! ```text
//! p_i* = −(l_i/α_i) · ln( (N_fwd/l_i − 1 + k_i) / k_i )   for N_fwd < l_i
//! p_i* = 0                                                otherwise
//! ```
//!
//! **Paper erratum:** the paper's Eq. 7 prints the argument of the log as
//! `1 − k_i(1 − N_fwd/l_i)` — missing the division by `k_i`. The two forms
//! coincide at `k = 1` but the printed one does not satisfy the tight
//! constraint of Eq. 6 for `k < 1` (substituting it back into the
//! remaining-length expression does not return `N_fwd`). We implement the
//! consistent form; the qualitative observations (1)–(4) of §4.2.2 are
//! unchanged and are unit-tested below. See DESIGN.md §5.
//!
//! The resulting single-variable objective `J(N_fwd)` is minimized by
//! bisection on its derivative: `J'(N) → −∞` as `N` approaches the largest
//! saturation floor `l_i(1−k_i)` (budget blows up), `J'(max l_i) = c_base >
//! 0`, and `J'` is monotone non-decreasing in between, so a unique crossing
//! exists.

use super::acceptance::AcceptanceParams;
use crate::cost::LatencyModel;

/// One request as seen by the allocator.
#[derive(Debug, Clone, Copy)]
pub struct BudgetRequest {
    /// Predicted (remaining) generation length `l_i`.
    pub length: f64,
    pub accept: AcceptanceParams,
}

/// Solution of the allocation problem.
#[derive(Debug, Clone)]
pub struct BudgetSolution {
    /// Optimal effective forward-pass count `N_fwd`.
    pub n_fwd: f64,
    /// Per-request total speculative budgets `p_i*` (same order as input).
    pub budgets: Vec<f64>,
    /// Modeled objective value `J` (Eq. 8), in seconds.
    pub objective: f64,
}

/// Corrected Eq. 7 for a single request at a given `n_fwd`.
pub fn closed_form_budget(req: &BudgetRequest, n_fwd: f64) -> f64 {
    let l = req.length;
    if n_fwd >= l || l <= 0.0 {
        return 0.0;
    }
    let AcceptanceParams { alpha, k } = req.accept;
    let inner = (n_fwd / l - 1.0 + k) / k;
    if inner <= 0.0 {
        // n_fwd is at/below this request's saturation floor l(1−k): no
        // finite budget reaches it.
        return f64::INFINITY;
    }
    -(l / alpha) * inner.ln()
}

/// The paper's literal Eq. 7 (kept for the ablation in `figures::fig12` and
/// for documenting the erratum; do not use for allocation).
pub fn paper_eq7_budget(req: &BudgetRequest, n_fwd: f64) -> f64 {
    let l = req.length;
    if n_fwd >= l || l <= 0.0 {
        return 0.0;
    }
    let AcceptanceParams { alpha, k } = req.accept;
    let inner = 1.0 - k * (1.0 - n_fwd / l);
    if inner <= 0.0 {
        return f64::INFINITY;
    }
    -(l / alpha) * inner.ln()
}

/// `dJ/dN` (corrected Eq. 9): `c_base − c_tok · Σ_{l_i > N} (l_i/α_i) /
/// (N − l_i(1−k_i))`.
fn objective_derivative(reqs: &[BudgetRequest], cost: &LatencyModel, n_fwd: f64) -> f64 {
    let mut sum = 0.0;
    for r in reqs {
        if r.length > n_fwd {
            let AcceptanceParams { alpha, k } = r.accept;
            let denom = n_fwd - r.length * (1.0 - k);
            if denom > 0.0 {
                sum += (r.length / alpha) / denom;
            } else {
                return f64::NEG_INFINITY;
            }
        }
    }
    cost.c_base - cost.c_tok * sum
}

/// Eq. 8: the single-variable objective at `n_fwd`.
pub fn objective(reqs: &[BudgetRequest], cost: &LatencyModel, n_fwd: f64) -> f64 {
    let mut j = cost.c_base * n_fwd + cost.c_step;
    for r in reqs {
        let p = closed_form_budget(r, n_fwd);
        if p.is_finite() {
            j += cost.c_tok * p;
        } else {
            return f64::INFINITY;
        }
    }
    j
}

/// Escalate a per-round speculative budget for a request resumed after
/// preemption. A migrated request is a *known* straggler landing on an
/// otherwise-idle worker, where deeper drafting is nearly free (the
/// EfficientRollout observation), so its budget is multiplied by `boost`
/// and clamped: never below the un-escalated budget (a boost < 1 cannot
/// sneak a shrink past validation) and never above `cap`
/// (`spec.budget_cap` — the same ceiling every other budget respects).
pub fn escalate(budget: usize, boost: f64, cap: usize) -> usize {
    if budget == 0 {
        // Zero means "do not speculate" (short class / degraded request);
        // escalation must not conjure speculation out of nothing.
        return 0;
    }
    let boosted = if boost.is_finite() && boost > 1.0 {
        (budget as f64 * boost).round() as usize
    } else {
        budget
    };
    boosted.max(budget).min(cap.max(budget))
}

/// Solve for the optimal `N_fwd` and per-request budgets.
pub fn solve(reqs: &[BudgetRequest], cost: &LatencyModel) -> BudgetSolution {
    if reqs.is_empty() {
        return BudgetSolution {
            n_fwd: 0.0,
            budgets: Vec::new(),
            objective: cost.c_step,
        };
    }
    // Feasible domain: strictly above every saturation floor l_i(1−k_i);
    // never useful above the longest request.
    let floor = reqs
        .iter()
        .map(|r| r.length * (1.0 - r.accept.k))
        .fold(0.0_f64, f64::max);
    let n_hi = reqs.iter().map(|r| r.length).fold(0.0_f64, f64::max);
    let n_lo = (floor + 1e-9).min(n_hi);
    if n_hi <= n_lo + 1e-12 {
        let budgets = reqs.iter().map(|r| closed_form_budget(r, n_hi)).collect();
        return BudgetSolution {
            n_fwd: n_hi,
            budgets,
            objective: objective(reqs, cost, n_hi),
        };
    }
    // J'(n_lo⁺) = −∞, J'(n_hi) = c_base > 0; bisect the monotone derivative.
    let mut lo = n_lo;
    let mut hi = n_hi;
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if objective_derivative(reqs, cost, mid) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let n = 0.5 * (lo + hi);
    BudgetSolution {
        n_fwd: n,
        budgets: reqs.iter().map(|r| closed_form_budget(r, n)).collect(),
        objective: objective(reqs, cost, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(l: f64, alpha: f64, k: f64) -> BudgetRequest {
        BudgetRequest {
            length: l,
            accept: AcceptanceParams { alpha, k },
        }
    }

    fn paper_cost() -> LatencyModel {
        LatencyModel {
            c_base: 20e-3,
            c_tok: 0.15e-3,
            c_step: 0.0,
        }
    }

    #[test]
    fn closed_form_matches_constraint() {
        // Substituting p* back into the remaining-length expression must give
        // exactly n_fwd (the tight constraint of Eq. 6). This is the test the
        // paper's printed Eq. 7 fails for k < 1 (see module docs).
        let r = req(500.0, 0.7, 0.85);
        for n in [100.0, 200.0, 400.0] {
            let p = closed_form_budget(&r, n);
            let remaining = r.accept.remaining(p, r.length);
            assert!(
                (remaining - n).abs() < 1e-6,
                "constraint not tight: rem={remaining} n={n}"
            );
        }
    }

    #[test]
    fn paper_eq7_violates_constraint_for_k_lt_1() {
        let r = req(500.0, 0.7, 0.85);
        let p = paper_eq7_budget(&r, 200.0);
        let remaining = r.accept.remaining(p, r.length);
        assert!((remaining - 200.0).abs() > 1.0, "erratum unexpectedly tight");
        // …and the forms agree at k = 1.
        let r1 = req(500.0, 0.7, 1.0);
        assert!((paper_eq7_budget(&r1, 200.0) - closed_form_budget(&r1, 200.0)).abs() < 1e-9);
    }

    #[test]
    fn observation_1_longer_requests_get_bigger_budgets() {
        // §4.2.2 Obs. 1: p* grows with l; similar lengths → similar budgets.
        let reqs = vec![
            req(100.0, 0.8, 0.8),
            req(400.0, 0.8, 0.8),
            req(1600.0, 0.8, 0.8),
            req(1550.0, 0.8, 0.8),
        ];
        let sol = solve(&reqs, &paper_cost());
        assert!(sol.budgets[0] <= sol.budgets[1]);
        assert!(sol.budgets[1] <= sol.budgets[2]);
        let rel = (sol.budgets[2] - sol.budgets[3]).abs() / sol.budgets[2].max(1.0);
        assert!(rel < 0.15, "similar lengths should get similar budgets");
    }

    #[test]
    fn observation_2_short_requests_skip_speculation() {
        // Requests with l_i <= N_fwd get p* = 0.
        let reqs = vec![req(2000.0, 0.8, 0.8), req(50.0, 0.8, 0.8)];
        let sol = solve(&reqs, &paper_cost());
        assert!(sol.n_fwd > 50.0, "n_fwd={}", sol.n_fwd);
        assert_eq!(sol.budgets[1], 0.0);
        assert!(sol.budgets[0] > 0.0);
    }

    #[test]
    fn observation_3_weak_drafter_shrinks_budget_value() {
        let strong = solve(&[req(1000.0, 0.8, 0.9)], &paper_cost());
        let weak = solve(&[req(1000.0, 0.8, 0.2)], &paper_cost());
        // Weak drafter can't push N_fwd down nearly as far.
        assert!(weak.n_fwd > strong.n_fwd);
        // And its achievable objective is worse.
        assert!(weak.objective > strong.objective);
    }

    #[test]
    fn observation_4_base_dominant_drives_nfwd_down() {
        let base_heavy = LatencyModel {
            c_base: 100e-3,
            c_tok: 0.01e-3,
            c_step: 0.0,
        };
        let tok_heavy = LatencyModel {
            c_base: 1e-3,
            c_tok: 1e-3,
            c_step: 0.0,
        };
        let reqs = vec![req(1000.0, 0.8, 0.9)];
        let a = solve(&reqs, &base_heavy);
        let b = solve(&reqs, &tok_heavy);
        assert!(
            a.n_fwd < b.n_fwd,
            "base-dominant should cut N_fwd harder: {} vs {}",
            a.n_fwd,
            b.n_fwd
        );
    }

    #[test]
    fn escalate_multiplies_and_clamps() {
        assert_eq!(escalate(8, 2.0, 64), 16);
        assert_eq!(escalate(8, 1.0, 64), 8, "no-op boost");
        assert_eq!(escalate(8, 2.5, 64), 20, "rounded, not truncated");
        assert_eq!(escalate(40, 4.0, 64), 64, "budget_cap ceiling");
        assert_eq!(escalate(8, 0.5, 64), 8, "never shrinks");
        assert_eq!(escalate(8, f64::NAN, 64), 8, "non-finite is a no-op");
        assert_eq!(escalate(0, 4.0, 64), 0, "zero budget stays zero");
        assert_eq!(escalate(10, 2.0, 4), 10, "cap below budget keeps budget");
    }

    #[test]
    fn empty_batch() {
        let sol = solve(&[], &paper_cost());
        assert_eq!(sol.n_fwd, 0.0);
        assert!(sol.budgets.is_empty());
    }

    #[test]
    fn infeasible_floor_returns_infinite_budget() {
        // n_fwd below the saturation floor l(1-k) = 500*0.5 = 250.
        let r = req(500.0, 1.0, 0.5);
        assert!(closed_form_budget(&r, 100.0).is_infinite());
    }

    #[test]
    fn prop_solution_is_stationary_and_feasible() {
        prop::check(128, |g| {
            let n = 1 + g.usize_in(0, 6);
            let reqs: Vec<BudgetRequest> = (0..n)
                .map(|_| {
                    req(
                        g.f64_in(50.0, 3000.0),
                        g.f64_in(0.2, 1.5),
                        g.f64_in(0.1, 0.99),
                    )
                })
                .collect();
            let cost = LatencyModel {
                c_base: g.f64_in(1e-3, 100e-3),
                c_tok: g.f64_in(0.01e-3, 1e-3),
                c_step: 0.0,
            };
            let sol = solve(&reqs, &cost);
            // Budgets finite and non-negative.
            for p in &sol.budgets {
                prop::require(p.is_finite() && *p >= 0.0, "budget finite & >= 0")?;
            }
            // No probed neighbor of N_fwd does better (optimality of the
            // bisected stationary point).
            let j0 = objective(&reqs, &cost, sol.n_fwd);
            prop::require(j0.is_finite(), "objective finite at optimum")?;
            let floor = reqs
                .iter()
                .map(|r| r.length * (1.0 - r.accept.k))
                .fold(0.0_f64, f64::max);
            let n_hi = reqs.iter().map(|r| r.length).fold(0.0_f64, f64::max);
            for d in [-1.0, 1.0, -10.0, 10.0, -100.0, 100.0] {
                let n2 = sol.n_fwd + d;
                if n2 > floor + 1e-6 && n2 <= n_hi {
                    let j2 = objective(&reqs, &cost, n2);
                    prop::require(j0 <= j2 + 1e-6 * j2.abs().max(1.0), "J(N*) must be minimal")?;
                }
            }
            Ok(())
        });
    }
}

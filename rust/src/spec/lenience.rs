//! Lenient (LOSSY) verification — the SPEC-RL-style baseline DAS defines
//! itself against.
//!
//! Related work (§2): SPEC-RL reuses prior trajectories as drafts but
//! "introduces a lenience parameter for acceptance that changes the output
//! distribution … it does not recover non-SD-level accuracy". This module
//! implements that acceptance rule so the claim is testable: a draft token
//! is accepted when `p(x) ≥ (1 − lenience) · max_y p(y)` — at lenience 0
//! this is greedy-strict; as lenience grows, off-policy draft tokens leak
//! into the output and the effective sampling distribution shifts toward
//! whatever the (stale) draft source proposes.
//!
//! DAS never uses this path; it exists for the ablation
//! (`figures`/tests) demonstrating WHY losslessness matters: lenient
//! acceptance inflates speedup but biases rollouts — on the simulator the
//! bias shows up directly as reward distortion.

use super::verify::{greedy_token, VerifyOutcome};
use crate::tokens::TokenId;
use crate::util::rng::Rng;

/// Lenient verification of a point-mass draft. `lenience ∈ [0, 1)`:
/// 0 ⇒ accept only when the draft token IS (tied-)argmax; larger values
/// accept increasingly improbable draft tokens. Rejection falls back to
/// sampling from the true distribution.
pub fn verify_lenient(
    draft: &[TokenId],
    dists: &[Vec<f32>],
    lenience: f64,
    rng: &mut Rng,
) -> VerifyOutcome {
    assert_eq!(dists.len(), draft.len() + 1, "need K+1 distributions");
    let thresh_scale = (1.0 - lenience).clamp(0.0, 1.0) as f32;
    let mut tokens = Vec::with_capacity(draft.len() + 1);
    for (t, &d) in draft.iter().enumerate() {
        let dist = &dists[t];
        let top = dist.iter().cloned().fold(f32::MIN, f32::max);
        let p_d = dist.get(d as usize).copied().unwrap_or(0.0);
        if p_d >= thresh_scale * top && p_d > 0.0 {
            // LOSSY: accepted even when p(d) < max — the distribution shift.
            tokens.push(d);
        } else {
            tokens.push(super::verify::sample(dist, rng));
            return VerifyOutcome { accepted: t, tokens };
        }
    }
    tokens.push(super::verify::sample(&dists[draft.len()], rng));
    VerifyOutcome {
        accepted: draft.len(),
        tokens,
    }
}

/// Expected acceptance gain of lenience on a distribution: fraction of
/// probability mass whose tokens clear the lenient threshold (diagnostic).
pub fn lenient_acceptance_mass(dist: &[f32], lenience: f64) -> f64 {
    let top = dist.iter().cloned().fold(f32::MIN, f32::max);
    let thresh = (1.0 - lenience) as f32 * top;
    dist.iter().filter(|&&p| p >= thresh).map(|&p| p as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dist(ps: &[f32]) -> Vec<f32> {
        ps.to_vec()
    }

    #[test]
    fn zero_lenience_is_greedy_strict() {
        let d = dist(&[0.5, 0.3, 0.2]);
        let mut rng = Rng::seed_from_u64(1);
        // Draft = argmax: accepted.
        let out = verify_lenient(&[0], &[d.clone(), d.clone()], 0.0, &mut rng);
        assert_eq!(out.accepted, 1);
        assert_eq!(out.tokens[0], greedy_token(&d));
        // Draft = non-argmax: rejected.
        let out = verify_lenient(&[1], &[d.clone(), d.clone()], 0.0, &mut rng);
        assert_eq!(out.accepted, 0);
    }

    #[test]
    fn lenience_accepts_off_policy_tokens() {
        let d = dist(&[0.5, 0.4, 0.1]);
        let mut rng = Rng::seed_from_u64(2);
        // Token 1 (p=0.4) clears 0.3 = (1-0.4)*0.5 at lenience 0.4.
        let out = verify_lenient(&[1], &[d.clone(), d.clone()], 0.4, &mut rng);
        assert_eq!(out.accepted, 1, "lenient rule must accept p=0.4 vs top=0.5");
        // Token 2 (p=0.1) still rejected.
        let out = verify_lenient(&[2], &[d.clone(), d.clone()], 0.4, &mut rng);
        assert_eq!(out.accepted, 0);
    }

    #[test]
    fn lenience_is_biased_greedy_exact_is_not() {
        // THE distinction: under lenient verification the emitted-token
        // distribution depends on the DRAFT; under exact verification it
        // does not (tested distributionally in spec::verify). Here: a
        // stale drafter that always proposes token 1 drags the lenient
        // output toward token 1 far beyond its true probability.
        let d = dist(&[0.5, 0.4, 0.1]);
        let n = 100_000;
        let mut rng = Rng::seed_from_u64(3);
        let mut lenient_count = 0usize;
        let mut exact_count = 0usize;
        for _ in 0..n {
            let out = verify_lenient(&[1], &[d.clone(), d.clone()], 0.4, &mut rng);
            if out.tokens[0] == 1 {
                lenient_count += 1;
            }
            let out = crate::spec::verify::verify_sampling(&[1], &[d.clone(), d.clone()], &mut rng);
            if out.tokens[0] == 1 {
                exact_count += 1;
            }
        }
        let lenient_p = lenient_count as f64 / n as f64;
        let exact_p = exact_count as f64 / n as f64;
        assert!(lenient_p > 0.99, "lenient always accepts the proposal: {lenient_p}");
        assert!(
            (exact_p - 0.4).abs() < 0.01,
            "exact verification preserves p(1)=0.4: {exact_p}"
        );
    }

    #[test]
    fn acceptance_mass_monotone_in_lenience() {
        let d = dist(&[0.5, 0.3, 0.15, 0.05]);
        let m0 = lenient_acceptance_mass(&d, 0.0);
        let m4 = lenient_acceptance_mass(&d, 0.4);
        let m9 = lenient_acceptance_mass(&d, 0.9);
        assert!(m0 <= m4 && m4 <= m9);
        assert!((m0 - 0.5).abs() < 1e-6);
        assert!((m9 - 1.0).abs() < 0.06);
    }
}

//! Workload generators — the RL datasets (DESIGN.md §3 substitutions).
//!
//! * **math** — DSR-sub/DeepScaleR analog: verifiable-answer problems with
//!   long-tailed canonical solution lengths. On the sim backend the answer
//!   is the problem's (drift-stable) canonical suffix; on the PJRT backend
//!   the answer is a deterministic function of the prompt, so a real model
//!   can actually learn it.
//! * **code** — DeepCoder analog: each problem is a set of unit tests for
//!   the token stack-VM; the canonical trajectory IS a correct program, so
//!   rewards are real program executions.
//! * **trace** — rollout-only serving workload (no reward semantics).

use crate::rl::vm::{self, TestCase};
use crate::tokens::{ProblemId, TokenId};
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub enum TaskSpec {
    /// Reward = rollout ends with these tokens (before EOS).
    MatchAnswer { answer: Vec<TokenId> },
    /// Reward = first generated token equals (sum of prompt) mod modulus.
    SumMod { modulus: u32 },
    /// Reward = unit-test pass fraction of the generated program.
    UnitTests { tests: Vec<TestCase>, fuel: usize },
    /// No reward (serving trace).
    None,
}

#[derive(Debug, Clone)]
pub struct Problem {
    pub id: ProblemId,
    pub prompt: Vec<TokenId>,
    pub task: TaskSpec,
    /// A known-good generation for this problem (used to seed the sim
    /// model's canonical trajectory; None = let the sim invent one).
    pub canonical: Option<Vec<TokenId>>,
    /// Drift-eligible positions of `canonical` (see `SimModel::set_canonical`).
    pub mutable: Option<Vec<bool>>,
}

#[derive(Debug, Clone)]
pub struct Workload {
    pub problems: Vec<Problem>,
}

impl Workload {
    pub fn from_config(cfg: &crate::config::DasConfig) -> Workload {
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x0A7A_5E7);
        match cfg.workload.kind.as_str() {
            "math" => {
                if cfg.model.backend == "pjrt" {
                    math_pjrt(&mut rng, cfg.workload.n_problems, cfg.model.vocab_size)
                } else {
                    math_sim(&mut rng, cfg.workload.n_problems, cfg.model.vocab_size)
                }
            }
            "code" => code(
                &mut rng,
                cfg.workload.n_problems,
                cfg.model.vocab_size,
                cfg.workload.len_mu,
                cfg.workload.len_sigma,
                cfg.rollout.max_new_tokens,
            ),
            "trace" => trace(&mut rng, cfg.workload.n_problems, cfg.model.vocab_size),
            other => panic!("unknown workload kind '{other}'"),
        }
    }
}

/// Sim-backend math: prompts are short id-bearing headers; the answer lives
/// in the sim's canonical trajectory (queried at reward time).
fn math_sim(rng: &mut Rng, n: usize, vocab: usize) -> Workload {
    let problems = (0..n)
        .map(|i| {
            let plen = 3 + rng.below(4);
            let prompt: Vec<TokenId> = (0..plen)
                .map(|_| rng.below(vocab.saturating_sub(2).max(2)) as u32)
                .collect();
            Problem {
                id: i as ProblemId,
                prompt,
                task: TaskSpec::MatchAnswer { answer: Vec::new() }, // filled by trainer
                canonical: None,
                mutable: None,
            }
        })
        .collect();
    Workload { problems }
}

/// PJRT-backend math: answer = (Σ prompt tokens) mod modulus — small enough
/// for the tiny transformer to learn via REINFORCE.
fn math_pjrt(rng: &mut Rng, n: usize, vocab: usize) -> Workload {
    let modulus = (vocab as u32 - 2).min(16);
    let problems = (0..n)
        .map(|i| {
            let plen = 3 + rng.below(3);
            let prompt: Vec<TokenId> =
                (0..plen).map(|_| rng.below(modulus as usize) as u32).collect();
            Problem {
                id: i as ProblemId,
                prompt,
                task: TaskSpec::SumMod { modulus },
                canonical: None,
                mutable: None,
            }
        })
        .collect();
    Workload { problems }
}

/// Code workload: canonical = a correct program for the generated tests.
/// Program lengths follow the configured log-normal so the long-tail
/// structure (Insight-1) holds for code too.
fn code(
    rng: &mut Rng,
    n: usize,
    vocab: usize,
    len_mu: f64,
    len_sigma: f64,
    max_len: usize,
) -> Workload {
    assert!(vocab as u32 > vm::OP_MAX, "vocab too small for VM opcodes");
    let problems = (0..n)
        .map(|i| {
            let target_len = (rng.lognormal(len_mu, len_sigma) as usize)
                .clamp(8, max_len.saturating_sub(4).max(8));
            let (program, tests) = vm::random_program(rng, target_len, 5);
            // Interleave no-op "comment" tokens (ids in [OP_MAX, vocab-2)):
            // the VM ignores them, so the canonical trajectory can drift
            // lexically (Insight-3) while staying a CORRECT program — the
            // reasoning text changes, the answer doesn't.
            let filler_lo = vm::OP_MAX;
            let filler_hi = (vocab - 1) as u32; // exclusive; vocab-1 is EOS
            let mut canonical = Vec::with_capacity(program.len() * 2);
            let mut mutable = Vec::with_capacity(program.len() * 2);
            for &t in &program {
                while rng.chance(0.35) {
                    canonical.push(filler_lo + rng.below((filler_hi - filler_lo) as usize) as u32);
                    mutable.push(true);
                }
                canonical.push(t);
                mutable.push(false);
            }
            let prompt = vec![
                vm::OP_MAX + 1 + (i as u32 % 8), // task marker tokens
                (i as u32 / 8) % 8 + vm::OP_MAX + 9,
            ];
            Problem {
                id: i as ProblemId,
                prompt,
                task: TaskSpec::UnitTests { tests, fuel: 10_000 },
                canonical: Some(canonical),
                mutable: Some(mutable),
            }
        })
        .collect();
    Workload { problems }
}

fn trace(rng: &mut Rng, n: usize, vocab: usize) -> Workload {
    let problems = (0..n)
        .map(|i| {
            let plen = 2 + rng.below(6);
            Problem {
                id: i as ProblemId,
                prompt: (0..plen).map(|_| rng.below(vocab - 1) as u32).collect(),
                task: TaskSpec::None,
                canonical: None,
                mutable: None,
            }
        })
        .collect();
    Workload { problems }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DasConfig;

    #[test]
    fn math_sim_workload_shape() {
        let cfg = DasConfig::default();
        let w = Workload::from_config(&cfg);
        assert_eq!(w.problems.len(), cfg.workload.n_problems);
        for p in &w.problems {
            assert!(!p.prompt.is_empty());
            assert!(matches!(p.task, TaskSpec::MatchAnswer { .. }));
        }
    }

    #[test]
    fn code_workload_programs_pass_their_tests() {
        let mut cfg = DasConfig::default();
        cfg.workload.kind = "code".into();
        cfg.workload.n_problems = 8;
        let w = Workload::from_config(&cfg);
        for p in &w.problems {
            let prog = p.canonical.as_ref().unwrap();
            let TaskSpec::UnitTests { tests, fuel } = &p.task else {
                panic!("code problems carry tests")
            };
            assert!((vm::pass_fraction(prog, tests, *fuel) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn code_lengths_long_tailed() {
        let mut cfg = DasConfig::default();
        cfg.workload.kind = "code".into();
        cfg.workload.n_problems = 128;
        let w = Workload::from_config(&cfg);
        let lens: Vec<f64> = w
            .problems
            .iter()
            .map(|p| p.canonical.as_ref().unwrap().len() as f64)
            .collect();
        let mean = crate::util::stats::mean(&lens);
        let max = lens.iter().cloned().fold(0.0, f64::max);
        assert!(max > 2.0 * mean, "tail expected: mean={mean} max={max}");
    }

    #[test]
    fn pjrt_math_answers_learnable() {
        let mut cfg = DasConfig::default();
        cfg.model.backend = "pjrt".into();
        cfg.model.vocab_size = 64;
        let w = Workload::from_config(&cfg);
        for p in &w.problems {
            let TaskSpec::SumMod { modulus } = p.task else {
                panic!("expected SumMod")
            };
            assert!(modulus >= 2);
            assert!(p.prompt.iter().all(|&t| t < modulus));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = DasConfig::default();
        let a = Workload::from_config(&cfg);
        let b = Workload::from_config(&cfg);
        assert_eq!(a.problems.len(), b.problems.len());
        for (x, y) in a.problems.iter().zip(&b.problems) {
            assert_eq!(x.prompt, y.prompt);
        }
    }
}

//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute_b`. Parameters live as **device buffers** and are threaded
//! through every call; after a train step the returned buffers simply
//! replace them (no host round-trip on the weight path).

pub mod checkpoint;
pub mod meta;
pub mod pjrt_model;

pub use checkpoint::{load as load_checkpoint, save as save_checkpoint, CheckpointMeta};
pub use meta::ArtifactMeta;
pub use pjrt_model::PjrtModel;

use anyhow::{Context, Result};
use std::path::Path;

/// Compile one HLO-text artifact on the given client.
pub fn compile_artifact(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("artifact path not utf-8")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

/// Read a raw little-endian f32 parameter dump written by `aot.py`.
pub fn read_param_bin(path: &Path, expect_elems: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading param file {}", path.display()))?;
    anyhow::ensure!(
        bytes.len() == expect_elems * 4,
        "param {} has {} bytes, expected {}",
        path.display(),
        bytes.len(),
        expect_elems * 4
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_param_bin_roundtrip() {
        let dir = std::env::temp_dir().join("das_test_param");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let vals = [1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_param_bin(&p, 3).unwrap(), vals);
        assert!(read_param_bin(&p, 4).is_err());
    }
}

//! `artifacts/meta.json` loader — the contract between `aot.py` and the
//! Rust runtime (geometry, parameter inventory, executable names).

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
}

impl ParamSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub max_seq_len: usize,
    pub batch: usize,
    /// K+1: logit rows produced per verify call (max draft = spec_block-1).
    pub spec_block: usize,
    pub params: Vec<ParamSpec>,
    pub calibration_lens: Vec<usize>,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let j = Json::parse(&text).context("parsing meta.json")?;
        let get_usize = |path: &str| -> Result<usize> {
            j.get_path(path)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("meta.json missing {path}"))
        };
        let params = j
            .get("params")
            .and_then(|p| p.as_arr())
            .context("meta.json missing params")?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(|v| v.as_str())
                        .context("param name")?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().context("shape dim"))
                        .collect::<Result<_>>()?,
                    file: p
                        .get("file")
                        .and_then(|v| v.as_str())
                        .context("param file")?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let calibration_lens = j
            .get("calibration_lens")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default();
        Ok(ArtifactMeta {
            dir: dir.to_path_buf(),
            vocab_size: get_usize("model.vocab_size")?,
            d_model: get_usize("model.d_model")?,
            n_layers: get_usize("model.n_layers")?,
            n_heads: get_usize("model.n_heads")?,
            max_seq_len: get_usize("model.max_seq_len")?,
            batch: get_usize("model.batch")?,
            spec_block: get_usize("model.spec_block")?,
            params,
            calibration_lens,
        })
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{
              "model": {"vocab_size": 64, "d_model": 64, "n_layers": 2,
                        "n_heads": 4, "max_seq_len": 128, "batch": 8,
                        "spec_block": 8},
              "params": [{"name": "embed", "shape": [64, 64],
                          "file": "params/embed.bin"}],
              "artifacts": {"decode": "decode.hlo.txt"},
              "calibration_lens": [32, 64, 128],
              "seed": 0
            }"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_fixture() {
        let dir = std::env::temp_dir().join("das_meta_fixture");
        write_fixture(&dir);
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.vocab_size, 64);
        assert_eq!(m.spec_block, 8);
        assert_eq!(m.params.len(), 1);
        assert_eq!(m.params[0].elems(), 4096);
        assert_eq!(m.calibration_lens, vec![32, 64, 128]);
        assert!(m.artifact_path("decode").ends_with("decode.hlo.txt"));
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactMeta::load(Path::new("/nonexistent_das")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}

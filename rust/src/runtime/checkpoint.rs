//! Checkpointing: save/restore the PJRT policy weights + training cursor.
//!
//! Format mirrors the AOT artifact layout (raw little-endian f32 per
//! parameter + a JSON manifest), so a checkpoint directory is loadable
//! either as a resume point or as fresh `artifacts/params` for a new run.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::PjrtModel;

#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub step: u32,
    pub epoch: u32,
    pub train_steps: u64,
}

/// Write the model's current weights + cursor into `dir`.
pub fn save(model: &PjrtModel, dir: &Path, meta: &CheckpointMeta) -> Result<()> {
    std::fs::create_dir_all(dir.join("params"))?;
    let host = model.params_to_host()?;
    for (spec, values) in model.meta.params.iter().zip(&host) {
        let bytes: Vec<u8> = values.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join(&spec.file), bytes)
            .with_context(|| format!("writing {}", spec.name))?;
    }
    let manifest = Json::obj(vec![
        ("step", Json::num(meta.step as f64)),
        ("epoch", Json::num(meta.epoch as f64)),
        ("train_steps", Json::num(meta.train_steps as f64)),
        (
            "params",
            Json::Arr(
                model
                    .meta
                    .params
                    .iter()
                    .map(|p| Json::str(&p.name))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(dir.join("checkpoint.json"), manifest.to_string())?;
    Ok(())
}

/// Restore weights from `dir` into the model; returns the saved cursor.
pub fn load(model: &mut PjrtModel, dir: &Path) -> Result<CheckpointMeta> {
    let text = std::fs::read_to_string(dir.join("checkpoint.json"))
        .with_context(|| format!("no checkpoint at {}", dir.display()))?;
    let j = Json::parse(&text).context("parsing checkpoint.json")?;
    let get = |k: &str| -> Result<u64> {
        j.get(k)
            .and_then(|v| v.as_i64())
            .map(|v| v as u64)
            .with_context(|| format!("checkpoint.json missing {k}"))
    };
    let meta = CheckpointMeta {
        step: get("step")? as u32,
        epoch: get("epoch")? as u32,
        train_steps: get("train_steps")?,
    };
    let mut host = Vec::with_capacity(model.meta.params.len());
    for spec in model.meta.params.clone() {
        host.push(super::read_param_bin(&dir.join(&spec.file), spec.elems())?);
    }
    model.set_params_from_host(&host)?;
    Ok(meta)
}

#[cfg(test)]
mod tests {
    // Round-trip is covered by rust/tests/pjrt_integration.rs (needs real
    // artifacts); the manifest codec is exercised here.
    use super::*;

    #[test]
    fn manifest_roundtrip() {
        let meta = CheckpointMeta {
            step: 7,
            epoch: 2,
            train_steps: 40,
        };
        let j = Json::obj(vec![
            ("step", Json::num(meta.step as f64)),
            ("epoch", Json::num(meta.epoch as f64)),
            ("train_steps", Json::num(meta.train_steps as f64)),
        ]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("step").unwrap().as_usize(), Some(7));
        assert_eq!(parsed.get("train_steps").unwrap().as_usize(), Some(40));
    }
}

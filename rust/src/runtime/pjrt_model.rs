//! The real policy backend: AOT-compiled JAX/Pallas transformer on PJRT.
//!
//! Implements [`TargetModel`] over the `decode.hlo.txt` verify executable
//! and exposes `train_step` for the GRPO trainer. Weights are device
//! buffers updated in place after each learner step — the policy the engine
//! decodes with is always the current one, so drafter staleness (Insight-3)
//! is physically real in this stack, not simulated.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::meta::ArtifactMeta;
use super::{compile_artifact, read_param_bin};
use crate::cost::{fit, LatencyModel};
use crate::model::{StepInput, StepOutput, TargetModel};
use crate::spec::verify::softmax_with_temperature;
use crate::tokens::TokenId;

pub struct PjrtModel {
    client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    decode: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    /// Device-resident parameters, in meta.params order.
    params: Vec<xla::PjRtBuffer>,
    latency: LatencyModel,
    clock: f64,
    n_fwd: u64,
    pub train_steps: u64,
}

impl PjrtModel {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let meta = ArtifactMeta::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let decode = compile_artifact(&client, &meta.artifact_path("decode"))?;
        let train = compile_artifact(&client, &meta.artifact_path("train_step"))?;
        let mut params = Vec::with_capacity(meta.params.len());
        for spec in &meta.params {
            let host = read_param_bin(&meta.dir.join(&spec.file), spec.elems())?;
            params.push(
                client
                    .buffer_from_host_buffer(&host, &spec.shape, None)
                    .with_context(|| format!("uploading param {}", spec.name))?,
            );
        }
        Ok(PjrtModel {
            client,
            meta,
            decode,
            train,
            params,
            latency: LatencyModel {
                // Pre-calibration defaults; `calibrate()` refits.
                c_base: 5e-3,
                c_tok: 5e-6,
                c_step: 1e-3,
            },
            clock: 0.0,
            n_fwd: 0,
            train_steps: 0,
        })
    }

    /// Max draft tokens per verify call (the compiled block minus the
    /// guaranteed extra token).
    pub fn max_draft(&self) -> usize {
        self.meta.spec_block - 1
    }

    pub fn batch_capacity(&self) -> usize {
        self.meta.batch
    }

    fn upload<T: xla::ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        // NOTE: must be buffer_from_host_buffer (kImmutableOnlyDuringCall —
        // synchronous copy). buffer_from_host_literal transfers lazily and
        // does not await, so the host literal can be freed mid-transfer.
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Raw verify call: padded tokens `[B, S]`, starts `[B]` → logits
    /// `[B, spec_block, V]` flattened row-major.
    pub fn decode_raw(&mut self, tokens: &[i32], q_start: &[i32]) -> Result<Vec<f32>> {
        let b = self.meta.batch;
        let s = self.meta.max_seq_len;
        anyhow::ensure!(tokens.len() == b * s, "tokens must be [B,S]");
        anyhow::ensure!(q_start.len() == b, "q_start must be [B]");
        let tok_buf = self.upload(tokens, &[b, s])?;
        let qs_buf = self.upload(q_start, &[b])?;
        let mut inputs: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        inputs.push(&tok_buf);
        inputs.push(&qs_buf);
        // audit: allow(wall-clock-determinism) -- real-hardware latency gauge; never replayed
        let t0 = Instant::now();
        let result = self.decode.execute_b(&inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        self.clock += t0.elapsed().as_secs_f64();
        self.n_fwd += 1;
        let out = lit.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// One GRPO SGD step. `tokens` `[B,S]` (prompt+generation, padded),
    /// `mask` `[B,S]` (1.0 on generated positions), `adv` `[B]`. Updates the
    /// device-resident weights; returns the loss.
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        mask: &[f32],
        adv: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let b = self.meta.batch;
        let s = self.meta.max_seq_len;
        anyhow::ensure!(tokens.len() == b * s && mask.len() == b * s && adv.len() == b);
        let tok_buf = self.upload(tokens, &[b, s])?;
        let mask_buf = self.upload(mask, &[b, s])?;
        let adv_buf = self.upload(adv, &[b])?;
        let lr_buf = self.upload(&[lr], &[])?;
        let mut inputs: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        inputs.push(&tok_buf);
        inputs.push(&mask_buf);
        inputs.push(&adv_buf);
        inputs.push(&lr_buf);
        let result = self.train.execute_b(&inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let mut elems = lit.to_tuple()?;
        anyhow::ensure!(
            elems.len() == self.params.len() + 1,
            "train_step returned {} outputs, expected {}",
            elems.len(),
            self.params.len() + 1
        );
        let loss = elems.pop().unwrap().to_vec::<f32>()?[0];
        // Re-upload the updated weights (tuple outputs come back as one
        // literal; a device-side split API isn't exposed by this crate).
        let mut new_params = Vec::with_capacity(self.params.len());
        for (spec, lit) in self.meta.params.iter().zip(elems) {
            let host = lit.to_vec::<f32>()?;
            new_params.push(self.client.buffer_from_host_buffer(&host, &spec.shape, None)?);
        }
        self.params = new_params;
        self.train_steps += 1;
        Ok(loss)
    }

    /// Fig. 8 calibration: run the `decode_len{S}` variants and fit the
    /// linear latency model to (tokens processed, seconds) samples.
    pub fn calibrate(&mut self, reps: usize) -> Result<crate::cost::CalibrationReport> {
        let mut samples = Vec::new();
        for &s in &self.meta.calibration_lens.clone() {
            let exe = compile_artifact(
                &self.client,
                &self.meta.artifact_path(&format!("decode_len{s}")),
            )?;
            let b = self.meta.batch;
            let tokens = vec![0i32; b * s];
            let q_start = vec![0i32; b];
            let tok_buf = self.upload(&tokens, &[b, s])?;
            let qs_buf = self.upload(&q_start, &[b])?;
            let mut inputs: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
            inputs.push(&tok_buf);
            inputs.push(&qs_buf);
            // Warmup.
            let _ = exe.execute_b(&inputs)?[0][0].to_literal_sync()?;
            for _ in 0..reps.max(3) {
                // audit: allow(wall-clock-determinism) -- calibrating the latency model itself
                let t0 = Instant::now();
                let _ = exe.execute_b(&inputs)?[0][0].to_literal_sync()?;
                samples.push((b * s, t0.elapsed().as_secs_f64()));
            }
        }
        let report = fit(&samples);
        self.latency = LatencyModel {
            c_step: self.latency.c_step,
            ..report.model
        };
        Ok(report)
    }

    /// Replace the device-resident weights from host arrays (checkpoint
    /// restore). Order/shapes must match `meta.params`.
    pub fn set_params_from_host(&mut self, host: &[Vec<f32>]) -> Result<()> {
        anyhow::ensure!(host.len() == self.meta.params.len(), "param count mismatch");
        let mut new_params = Vec::with_capacity(host.len());
        for (spec, values) in self.meta.params.iter().zip(host) {
            anyhow::ensure!(values.len() == spec.elems(), "param {} size mismatch", spec.name);
            new_params.push(self.client.buffer_from_host_buffer(values, &spec.shape, None)?);
        }
        self.params = new_params;
        Ok(())
    }

    /// Download current weights (checkpointing / tests).
    pub fn params_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.params
            .iter()
            .map(|b| Ok(b.to_literal_sync()?.to_vec::<f32>()?))
            .collect()
    }
}

impl TargetModel for PjrtModel {
    fn vocab_size(&self) -> usize {
        self.meta.vocab_size
    }

    fn eos(&self) -> TokenId {
        (self.meta.vocab_size - 1) as TokenId
    }

    fn forward(&mut self, batch: &[StepInput], temperature: f64) -> Vec<StepOutput> {
        let b = self.meta.batch;
        let s = self.meta.max_seq_len;
        let kp1 = self.meta.spec_block;
        let v = self.meta.vocab_size;
        assert!(batch.len() <= b, "batch {} exceeds compiled capacity {b}", batch.len());
        let mut tokens = vec![0i32; b * s];
        let mut q_start = vec![0i32; b];
        for (i, el) in batch.iter().enumerate() {
            let total = el.context.len() + el.draft.len();
            assert!(
                total <= s,
                "context+draft ({total}) exceeds compiled seq len ({s})"
            );
            assert!(el.draft.len() < kp1, "draft exceeds spec block");
            assert!(!el.context.is_empty(), "context must be non-empty");
            for (j, &t) in el.context.iter().chain(el.draft.iter()).enumerate() {
                tokens[i * s + j] = t as i32;
            }
            // Query rows start at the last committed token: row r predicts
            // the token after context+r.
            q_start[i] = (el.context.len() - 1) as i32;
        }
        let logits = self
            .decode_raw(&tokens, &q_start)
            .expect("decode execution failed");
        let mut outs = Vec::with_capacity(batch.len());
        for (i, el) in batch.iter().enumerate() {
            let need = el.draft.len() + 1;
            let mut dists = Vec::with_capacity(need);
            for r in 0..need {
                let base = (i * kp1 + r) * v;
                let row = &logits[base..base + v];
                if temperature <= 0.0 {
                    // Greedy callers only need the argmax; hand back the raw
                    // logits as "probabilities" (argmax-invariant).
                    dists.push(row.to_vec());
                } else {
                    dists.push(softmax_with_temperature(row, temperature));
                }
            }
            outs.push(dists);
        }
        outs
    }

    fn elapsed(&self) -> f64 {
        self.clock
    }

    fn reset_clock(&mut self) {
        self.clock = 0.0;
    }

    fn latency_model(&self) -> LatencyModel {
        self.latency
    }

    fn forward_passes(&self) -> u64 {
        self.n_fwd
    }
}

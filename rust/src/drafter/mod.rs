//! Drafters — the proposal side of speculative decoding (§4.1).
//!
//! Two layers:
//!
//! * [`DraftSource`] — a retrieval *substrate*: draft-from-context,
//!   absorb-rollout, epoch-roll. Implemented by every suffix structure in
//!   the crate ([`crate::suffix::WindowedIndex`],
//!   [`crate::suffix::SuffixTree`], [`crate::suffix::SuffixArrayIndex`],
//!   [`crate::suffix::SuffixTrieIndex`]) and by the frozen
//!   [`StaticNgramDrafter`]. The rollout engine's speculation path never
//!   names a concrete substrate — everything downstream of the [`Drafter`]
//!   routing layer flows through this trait, so swapping the fused
//!   windowed trie for a Ukkonen tree or the rebuild-per-insert suffix
//!   array (`spec.substrate`) is a config change, not a code path.
//! * [`Drafter`] — the request/problem *routing* policy above the sources:
//!   which shard to query, request-local state, scope rules.
//!
//! Concurrency: each substrate also *publishes* an immutable
//! [`DraftSnapshot`] ([`DraftSource::snapshot`]) — a lock-free read view
//! drafting threads can query while the owning writer keeps absorbing
//! rollouts. [`DraftSnapshot::draft_from`] is bit-identical to the
//! substrate's own `draft_from` at the publish point; a snapshot never
//! changes after publication (staleness, not tearing, is the only
//! divergence mode). Trie-backed substrates publish cheap chunk-shared
//! views; the tree/array baselines publish whole-structure clones (they
//! pay O(n) per absorb anyway, so the clone does not change their
//! complexity class).
//!
//! Drafters:
//! * [`SuffixDrafter`] — the paper's adaptive nonparametric drafter:
//!   per-problem (or global) sliding-window shards, optionally combined
//!   with a request-local index ("+request" scopes of Fig. 6) and a
//!   prefix-trie router; every shard is a `Box<dyn DraftSource>`.
//! * [`StaticNgramDrafter`] — the frozen parametric baseline standing in
//!   for EAGLE: calibrated once on epoch-0 rollouts, never updated, so its
//!   acceptance stays flat while the policy drifts (Fig. 4).
//! * [`NoneDrafter`] — the VeRL no-speculation baseline.

mod static_ngram;
mod suffix_drafter;

pub use static_ngram::StaticNgramDrafter;
use suffix_drafter::SuffixDrafterSnapshot;
pub use suffix_drafter::{HistoryScope, SuffixDrafter};

use std::sync::Arc;

use crate::store::wire::{Reader, StoreError, Writer};
use crate::suffix::{
    SharedPool, SuffixArrayIndex, SuffixTree, SuffixTrieIndex, SuffixTrieSnapshot, WindowSnapshot,
    WindowedIndex,
};
use crate::tokens::{Epoch, ProblemId, RequestId, Rollout, TokenId};

/// Size gauges of one retrieval index (and, summed by the drafter, of the
/// whole history) — the node/segment/byte telemetry that makes the
/// path-compression win observable instead of asserted.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IndexStats {
    /// Explicit (compressed) trie nodes; tree nodes for the Ukkonen tree.
    pub nodes: usize,
    /// What a one-node-per-token trie would allocate for the same content
    /// (0 for substrates where the notion doesn't apply). The compression
    /// ratio is `token_positions / nodes`.
    pub token_positions: usize,
    /// Structure heap bytes (arena + per-node stores), excluding the
    /// shared segment pool.
    pub heap_bytes: usize,
    /// Live interned segments in the shared pool (drafter-level only —
    /// per-source stats leave these 0 so a shared pool isn't double
    /// counted).
    pub pool_segments: usize,
    /// Live tokens held by the shared pool.
    pub pool_tokens: usize,
    /// Approximate heap bytes of the shared pool (live + not-yet-compacted
    /// dead).
    pub pool_bytes: usize,
    /// Exact suffix-link rebuilds the trie cores have run (compaction
    /// sweeps plus the insert-count-triggered refresh that keeps
    /// never-compacting tries — `window_all`, the plain counting trie —
    /// on exact links). 0 for substrates without suffix links.
    pub link_rebuilds: u64,
    /// Distinct snapshots this index has published ([`DraftSource::snapshot`]
    /// cache misses — repeated publishes between mutations are coalesced and
    /// not counted). 0 for substrates that publish by whole-structure clone.
    pub snapshot_publishes: u64,
}

impl IndexStats {
    pub fn add(&mut self, other: &IndexStats) {
        self.nodes += other.nodes;
        self.token_positions += other.token_positions;
        self.heap_bytes += other.heap_bytes;
        self.pool_segments += other.pool_segments;
        self.pool_tokens += other.pool_tokens;
        self.pool_bytes += other.pool_bytes;
        self.link_rebuilds += other.link_rebuilds;
        self.snapshot_publishes += other.snapshot_publishes;
    }
}

/// A proposed draft block.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Draft {
    pub tokens: Vec<TokenId>,
    /// Empirical per-token confidence (drafter's own estimate; diagnostic).
    pub confidence: Vec<f32>,
    /// Length of the context suffix the draft was retrieved from.
    pub match_len: usize,
}

impl Draft {
    pub fn empty() -> Self {
        Draft::default()
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// An immutable, lock-free draft view of one substrate, published at an
/// absorb/epoch boundary by [`DraftSource::snapshot`].
///
/// Cloning is cheap (`Arc` bumps), the value is `Send + Sync`, and
/// [`DraftSnapshot::draft_from`] takes `&self` with no interior locking —
/// any number of reader threads can draft from one snapshot while the
/// owning writer keeps mutating its substrate. Every variant's drafting is
/// bit-identical to the corresponding live substrate's `draft_from` at the
/// moment of publication; afterwards the snapshot is frozen and can only
/// go *stale* (answers the old history), never torn.
#[derive(Debug, Clone)]
pub enum DraftSnapshot {
    /// Fused sliding-window trie: chunk-shared arena + pool snapshot.
    Window(Arc<WindowSnapshot>),
    /// Ukkonen tree baseline: whole-structure clone (pure reader).
    Tree(Arc<SuffixTree>),
    /// Suffix-array baseline: whole-structure clone (pure reader).
    Array(Arc<SuffixArrayIndex>),
    /// Plain counting trie: chunk-shared arena + pool snapshot.
    Trie(Arc<SuffixTrieSnapshot>),
    /// Frozen n-gram baseline: its trie snapshot plus the order clamp the
    /// live drafter applies to `max_match`.
    Static {
        index: Arc<SuffixTrieSnapshot>,
        order: usize,
    },
    /// Remote shard behind a `das serve-drafts` daemon: a pinned
    /// server-published snapshot id plus the session to reach it. The
    /// bytes live server-side; "lock-free" here means the publish-time
    /// pinning contract holds (readers see the pinned server state), not
    /// that no I/O happens.
    Remote(Arc<crate::draftsvc::RemoteShardSnapshot>),
}

// The whole point of the snapshot path: it must be shareable across draft
// worker threads without locks. Compile-time pin.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<DraftSnapshot>();
    assert_send_sync::<DrafterSnapshot>();
};

impl DraftSnapshot {
    /// Lock-free equivalent of [`DraftSource::draft_from`] over the
    /// published state. The per-variant mappings replicate the live trait
    /// impls exactly (window: score-ranked epoch walk; tree/array: copied
    /// continuation with unit confidence; trie/static: frequency weights).
    pub fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        match self {
            DraftSnapshot::Window(s) => match s.draft(context, max_match, budget) {
                Some(d) => Draft {
                    tokens: d.tokens,
                    confidence: d.confidence,
                    match_len: d.match_len,
                },
                None => Draft::empty(),
            },
            DraftSnapshot::Tree(t) => {
                let (tokens, match_len) = t.draft_with_match(context, max_match, budget);
                let confidence = vec![1.0; tokens.len()];
                Draft {
                    tokens,
                    confidence,
                    match_len,
                }
            }
            DraftSnapshot::Array(a) => {
                let (tokens, match_len) = a.draft_with_match(context, max_match, budget);
                let confidence = vec![1.0; tokens.len()];
                Draft {
                    tokens,
                    confidence,
                    match_len,
                }
            }
            DraftSnapshot::Trie(t) => {
                let (tokens, confidence, match_len) =
                    t.draft_weighted_with_match(context, max_match, budget);
                Draft {
                    tokens,
                    confidence,
                    match_len,
                }
            }
            DraftSnapshot::Static { index, order } => {
                let (tokens, confidence, match_len) =
                    index.draft_weighted_with_match(context, max_match.min(*order), budget);
                Draft {
                    tokens,
                    confidence,
                    match_len,
                }
            }
            DraftSnapshot::Remote(r) => r.draft(context, max_match, budget),
        }
    }

    /// Structure gauges carried by the publication itself — stamped once at
    /// publish time for trie-backed substrates, so reading them costs
    /// nothing per step (this is what retired the engine's interval-cached
    /// index-gauge refresh). Pool fields stay 0, mirroring per-source
    /// [`DraftSource::index_stats`].
    pub fn index_stats(&self) -> IndexStats {
        match self {
            DraftSnapshot::Window(s) => {
                let st = s.stats();
                IndexStats {
                    nodes: st.nodes,
                    token_positions: st.token_positions,
                    heap_bytes: st.heap_bytes,
                    link_rebuilds: st.link_rebuilds,
                    ..IndexStats::default()
                }
            }
            DraftSnapshot::Tree(t) => IndexStats {
                nodes: t.node_count(),
                heap_bytes: t.approx_bytes(),
                ..IndexStats::default()
            },
            DraftSnapshot::Array(a) => IndexStats {
                heap_bytes: a.len_tokens() * 20,
                ..IndexStats::default()
            },
            DraftSnapshot::Trie(t) | DraftSnapshot::Static { index: t, .. } => {
                let st = t.stats();
                IndexStats {
                    nodes: st.nodes,
                    token_positions: st.token_positions,
                    heap_bytes: st.heap_bytes,
                    link_rebuilds: st.link_rebuilds,
                    ..IndexStats::default()
                }
            }
            // The structure lives server-side; the client handle has no
            // gauges of its own.
            DraftSnapshot::Remote(_) => IndexStats::default(),
        }
    }
}

/// A retrieval substrate speculation can draw from: the §4.1 suffix
/// structures behind one interface. A source knows nothing about requests,
/// problems or scopes — that routing lives in [`Drafter`] impls above it.
pub trait DraftSource: Send {
    fn source_name(&self) -> &'static str;

    /// Propose up to `budget` tokens continuing `context`, matching at most
    /// `max_match` trailing context tokens against the index.
    fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft;

    /// Publish the immutable lock-free read view of this substrate as of
    /// now. `&mut self` lets trie-backed substrates reuse a cached view
    /// until the next mutation invalidates it (repeat publishes between
    /// absorbs are `Arc` clones, and only cache misses count toward
    /// [`IndexStats::snapshot_publishes`]).
    fn snapshot(&mut self) -> DraftSnapshot;

    /// Absorb one rollout produced at `epoch`. Unwindowed substrates
    /// (tree, array, plain trie) ignore the epoch: their history is
    /// unbounded by construction.
    fn absorb(&mut self, epoch: Epoch, tokens: &[TokenId]);

    /// A new epoch started (window maintenance). Default: no-op.
    fn on_epoch(&mut self, _epoch: Epoch) {}

    /// Tokens currently indexed (diagnostics; the Fig. 6-right
    /// "bigger index = slower" effect is real work here).
    fn indexed_tokens(&self) -> usize;

    /// Structure-size gauges (nodes / uncompressed-equivalent positions /
    /// bytes). Pool fields stay 0 here; the drafter reports its shared
    /// pool once. Default: all zero (substrates without a size story).
    fn index_stats(&self) -> IndexStats {
        IndexStats::default()
    }

    /// Serialize this substrate's complete state as one `das-store-v1`
    /// source blob (trie-backed substrates write pool `SegRef`s — the pool
    /// itself is saved once by the owning drafter). The blob is tagged, so
    /// [`DraftSource::load_state`] rejects a blob written by a different
    /// substrate instead of misreading it. Default: a tagged empty blob
    /// (stateless substrate).
    fn save_state(&self, w: &mut Writer) {
        w.str(self.source_name());
        w.u8(0);
    }

    /// Restore from [`DraftSource::save_state`]'s blob. The receiver must
    /// be a freshly constructed substrate of the same kind and config —
    /// and, for trie-backed substrates, built on the pool that already
    /// holds the snapshot's segments.
    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        r.expect_str(self.source_name(), "source blob tag")?;
        if r.u8()? != 0 {
            return Err(StoreError::Corrupt("stateless source with a payload".into()));
        }
        Ok(())
    }
}

/// The production substrate: fused epoch-tagged sliding-window trie.
impl DraftSource for WindowedIndex {
    fn source_name(&self) -> &'static str {
        "window"
    }

    fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        match self.draft(context, max_match, budget) {
            Some(d) => Draft {
                tokens: d.tokens,
                confidence: d.confidence,
                match_len: d.match_len,
            },
            None => Draft::empty(),
        }
    }

    fn snapshot(&mut self) -> DraftSnapshot {
        DraftSnapshot::Window(self.publish())
    }

    fn absorb(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        self.insert(epoch, tokens);
    }

    fn on_epoch(&mut self, epoch: Epoch) {
        self.roll_epoch(epoch);
    }

    fn indexed_tokens(&self) -> usize {
        self.tokens_indexed()
    }

    fn index_stats(&self) -> IndexStats {
        IndexStats {
            nodes: self.node_count(),
            token_positions: self.token_positions(),
            heap_bytes: self.approx_bytes(),
            link_rebuilds: self.link_rebuilds(),
            snapshot_publishes: self.snapshot_publishes(),
            ..IndexStats::default()
        }
    }

    fn save_state(&self, w: &mut Writer) {
        WindowedIndex::save_state(self, w);
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        WindowedIndex::load_state(self, r)
    }
}

/// Ukkonen-tree substrate: exact retrieval drafting, unbounded history.
/// Retrieval copies one stored continuation, so there is no frequency
/// estimate — confidence is reported as 1.0 per token.
impl DraftSource for SuffixTree {
    fn source_name(&self) -> &'static str {
        "tree"
    }

    fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        let (tokens, match_len) = self.draft_with_match(context, max_match, budget);
        let confidence = vec![1.0; tokens.len()];
        Draft {
            tokens,
            confidence,
            match_len,
        }
    }

    /// Whole-structure clone: the tree is a pure reader after construction,
    /// and absorb is already O(n)-ish, so the clone keeps the baseline's
    /// complexity class. No publish cache — the engine snapshots once per
    /// absorb round.
    fn snapshot(&mut self) -> DraftSnapshot {
        DraftSnapshot::Tree(Arc::new(self.clone()))
    }

    fn absorb(&mut self, _epoch: Epoch, tokens: &[TokenId]) {
        self.insert(tokens);
    }

    fn indexed_tokens(&self) -> usize {
        self.text_len()
    }

    fn index_stats(&self) -> IndexStats {
        IndexStats {
            nodes: self.node_count(),
            heap_bytes: self.approx_bytes(),
            ..IndexStats::default()
        }
    }

    /// The persistence payload is the build INPUT (raw sentinel-terminated
    /// text): Ukkonen construction is deterministic, so replaying it on
    /// load yields a structurally identical tree.
    fn save_state(&self, w: &mut Writer) {
        w.str("tree");
        w.tokens(self.text());
        w.u32(self.sentinel_cursor());
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        r.expect_str("tree", "source blob tag")?;
        let text = r.tokens()?;
        let sentinel = r.u32()?;
        *self = SuffixTree::from_text(&text, sentinel);
        Ok(())
    }
}

/// Suffix-array substrate — the Fig. 5 strawman: queries are fine, but
/// every absorb pays a FULL index rebuild (suffix arrays are static).
impl DraftSource for SuffixArrayIndex {
    fn source_name(&self) -> &'static str {
        "array"
    }

    fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        let (tokens, match_len) = self.draft_with_match(context, max_match, budget);
        let confidence = vec![1.0; tokens.len()];
        Draft {
            tokens,
            confidence,
            match_len,
        }
    }

    /// Whole-structure clone — the array rebuilds fully on every absorb
    /// anyway (the Fig. 5 strawman), so cloning does not change its cost
    /// profile.
    fn snapshot(&mut self) -> DraftSnapshot {
        DraftSnapshot::Array(Arc::new(self.clone()))
    }

    fn absorb(&mut self, _epoch: Epoch, tokens: &[TokenId]) {
        self.insert(tokens);
    }

    fn indexed_tokens(&self) -> usize {
        self.len_tokens()
    }

    fn index_stats(&self) -> IndexStats {
        IndexStats {
            // text + suffix array + LCP, all ∝ corpus length.
            heap_bytes: self.len_tokens() * 20,
            ..IndexStats::default()
        }
    }

    /// Persist the corpus only — SA + LCP are derived and rebuilt once on
    /// load (one build, not one per historical insert).
    fn save_state(&self, w: &mut Writer) {
        w.str("array");
        w.tokens(self.corpus());
        w.u32(self.sentinel_cursor());
        w.usize(self.rebuilds);
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        r.expect_str("array", "source blob tag")?;
        let corpus = r.tokens()?;
        let sentinel = r.u32()?;
        let rebuilds = r.usize()?;
        *self = SuffixArrayIndex::from_parts(corpus, sentinel, rebuilds);
        Ok(())
    }
}

/// Plain counting-trie substrate (also the request-local index of the
/// "+request" scopes): frequency-weighted drafts, unbounded history.
impl DraftSource for SuffixTrieIndex {
    fn source_name(&self) -> &'static str {
        "trie"
    }

    fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        let (tokens, confidence, match_len) =
            self.draft_weighted_with_match(context, max_match, budget);
        Draft {
            tokens,
            confidence,
            match_len,
        }
    }

    fn snapshot(&mut self) -> DraftSnapshot {
        DraftSnapshot::Trie(Arc::new(self.publish()))
    }

    fn absorb(&mut self, _epoch: Epoch, tokens: &[TokenId]) {
        self.insert(tokens);
    }

    fn indexed_tokens(&self) -> usize {
        self.tokens_indexed()
    }

    fn index_stats(&self) -> IndexStats {
        IndexStats {
            nodes: self.node_count(),
            token_positions: self.token_positions(),
            heap_bytes: self.approx_bytes(),
            link_rebuilds: self.link_rebuilds(),
            ..IndexStats::default()
        }
    }

    fn save_state(&self, w: &mut Writer) {
        SuffixTrieIndex::save_state(self, w);
    }

    fn load_state(&mut self, r: &mut Reader<'_>) -> Result<(), StoreError> {
        SuffixTrieIndex::load_state(self, r)
    }
}

/// Build one history substrate per `spec.substrate`. `window`/`max_depth`
/// parameterize the windowed substrate; the unwindowed alternatives (the
/// Fig. 5 subjects) keep unbounded history by construction.
pub fn source_from_substrate(
    substrate: &str,
    window: usize,
    max_depth: usize,
) -> Box<dyn DraftSource> {
    source_from_substrate_pooled(substrate, window, max_depth, None)
}

/// [`source_from_substrate`] with an optional shared label-segment pool:
/// every trie-backed shard built on the same pool stores common rollout
/// content (same-problem resamples, boilerplate prefixes) exactly once.
/// Tree/array substrates have no edge labels to intern and ignore it.
pub fn source_from_substrate_pooled(
    substrate: &str,
    window: usize,
    max_depth: usize,
    pool: Option<&SharedPool>,
) -> Box<dyn DraftSource> {
    match substrate {
        "window" => Box::new(match pool {
            Some(p) => WindowedIndex::with_pool(window, max_depth, p.clone()),
            None => WindowedIndex::new(window, max_depth),
        }),
        "tree" => Box::new(SuffixTree::new()),
        "array" => Box::new(SuffixArrayIndex::new()),
        // Config validate() rejects unknown substrates before any engine spins up; reaching
        // this arm is a coordinator bug worth a loud abort, not a run with the wrong index.
        // audit: allow(panic-path) -- unreachable after config validation; abort surfaces the bug
        other => panic!("unknown substrate '{other}' (validate() should have caught this)"),
    }
}

/// How a drafter-level draft was answered. Snapshot drafting cannot bump
/// the drafter's own hit/miss diagnostics (the snapshot is immutable and
/// shared across threads), so [`DrafterSnapshot::draft`] reports the
/// outcome alongside the draft and the engine folds the counts back in
/// via [`Drafter::apply_draft_outcomes`] after the round joins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftOutcome {
    /// Answered from the request-local index.
    Local,
    /// Answered from a history shard (routed or own-problem).
    Shard,
    /// Queried history but found nothing above thresholds.
    Miss,
    /// Drafting skipped (zero budget / empty context / no-speculation
    /// drafter) — no counter moves, matching the serial early returns.
    Skipped,
}

/// An immutable snapshot of a whole [`Drafter`] — routing policy plus the
/// published [`DraftSnapshot`] of every shard, request-local index, and
/// the prefix router — for lock-free concurrent drafting. `draft` takes
/// `&self` and acquires no lock; worker threads share one `Arc` of this
/// while the owning drafter keeps absorbing rollouts on the writer thread.
#[derive(Debug, Clone)]
pub struct DrafterSnapshot {
    /// The epoch the drafter was last rolled to when this was published —
    /// the reference point for the `draft_snapshot_lag_epochs` gauge.
    epoch: Epoch,
    inner: DrafterSnapInner,
}

#[derive(Debug, Clone)]
enum DrafterSnapInner {
    /// Never drafts (no-speculation baselines).
    Empty,
    /// One substrate, no routing (the frozen static baseline — its
    /// [`DraftSnapshot::Static`] variant carries the order clamp).
    Single(DraftSnapshot),
    /// The full adaptive-drafter routing state.
    Suffix(SuffixDrafterSnapshot),
}

impl DrafterSnapshot {
    /// Snapshot of a drafter that never proposes anything.
    pub fn empty(epoch: Epoch) -> Self {
        DrafterSnapshot {
            epoch,
            inner: DrafterSnapInner::Empty,
        }
    }

    /// Snapshot of a single-substrate drafter without routing.
    pub fn single(epoch: Epoch, snap: DraftSnapshot) -> Self {
        DrafterSnapshot {
            epoch,
            inner: DrafterSnapInner::Single(snap),
        }
    }

    pub(crate) fn suffix(epoch: Epoch, snap: SuffixDrafterSnapshot) -> Self {
        DrafterSnapshot {
            epoch,
            inner: DrafterSnapInner::Suffix(snap),
        }
    }

    /// Drafter epoch at publication.
    pub fn epoch(&self) -> Epoch {
        self.epoch
    }

    /// Lock-free equivalent of [`Drafter::draft`] over the published
    /// state, with the same scope rules, routing, and minimum-match
    /// thresholds — bit-identical to the serial path at the publish point.
    pub fn draft(
        &self,
        request: RequestId,
        problem: ProblemId,
        context: &[TokenId],
        budget: usize,
    ) -> (Draft, DraftOutcome) {
        if budget == 0 || context.is_empty() {
            return (Draft::empty(), DraftOutcome::Skipped);
        }
        match &self.inner {
            DrafterSnapInner::Empty => (Draft::empty(), DraftOutcome::Skipped),
            DrafterSnapInner::Single(s) => {
                let d = s.draft_from(context, usize::MAX, budget);
                let outcome = if d.is_empty() {
                    DraftOutcome::Miss
                } else {
                    DraftOutcome::Shard
                };
                (d, outcome)
            }
            DrafterSnapInner::Suffix(s) => s.draft(request, problem, context, budget),
        }
    }

    /// Raw shard-level draft: query one history shard (`None` = the
    /// global shard) with NO routing and NO minimum-match gating. This is
    /// the draft service's read path — the serving side answers raw shard
    /// content and the *client* drafter applies its own scope rules and
    /// thresholds, so remote drafts stay bit-identical to local ones.
    pub fn shard_draft(
        &self,
        shard: Option<ProblemId>,
        context: &[TokenId],
        max_match: usize,
        budget: usize,
    ) -> Draft {
        match &self.inner {
            DrafterSnapInner::Empty | DrafterSnapInner::Single(_) => Draft::empty(),
            DrafterSnapInner::Suffix(s) => s.shard_draft(shard, context, max_match, budget),
        }
    }
}

/// Common interface for all drafters (the routing layer above
/// [`DraftSource`]).
pub trait Drafter: Send {
    fn name(&self) -> &'static str;

    /// Propose up to `budget` tokens continuing `context` for a request of
    /// the given problem.
    ///
    /// Fault contract: drafts are *advisory*. The rollout engine runs this
    /// under `catch_unwind` and treats a panic as "no draft" — it degrades
    /// the request to plain decoding (outputs unchanged at temperature 0,
    /// `StepMetrics::degraded_requests` incremented) rather than letting a
    /// drafter bug take down the worker. Implementations therefore never
    /// need to pre-validate their index state defensively, but they also
    /// must not rely on being called again for a degraded request.
    fn draft(
        &mut self,
        request: RequestId,
        problem: ProblemId,
        context: &[TokenId],
        budget: usize,
    ) -> Draft;

    /// Publish an immutable [`DrafterSnapshot`] for lock-free concurrent
    /// drafting, or `None` if this drafter only supports the serial
    /// `&mut self` path (the engine then keeps drafting inline).
    /// Implementations cache the snapshot until the next mutation, so
    /// repeat calls between absorbs are `Arc` clones. Default: `None`.
    fn snapshot(&mut self) -> Option<Arc<DrafterSnapshot>> {
        None
    }

    /// Fold the outcome counts of a concurrent draft round back into the
    /// drafter's diagnostics ([`DraftOutcome`] per draft, summed by the
    /// engine after the round joins). Default: ignore (drafters without
    /// hit/miss counters).
    fn apply_draft_outcomes(&mut self, _local_hits: u64, _shard_hits: u64, _misses: u64) {}

    /// Feed freshly *committed* (verified) tokens of an in-flight request —
    /// powers the "+request" scopes. Default: ignore.
    fn observe_partial(
        &mut self,
        _request: RequestId,
        _problem: ProblemId,
        _new_tokens: &[TokenId],
    ) {
    }

    /// A request finished; drop any request-local state. Default: ignore.
    fn end_request(&mut self, _request: RequestId) {}

    /// A rollout completed and was added to history (drafters that adapt
    /// index it here). Default: ignore (static baselines).
    fn observe_rollout(&mut self, _rollout: &Rollout) {}

    /// A new training epoch started (window maintenance). Default: ignore.
    fn roll_epoch(&mut self, _epoch: Epoch) {}

    /// Size gauges of everything this drafter has indexed (history shards,
    /// request-local indexes, shared segment pool). Default: all zero.
    fn index_stats(&self) -> IndexStats {
        IndexStats::default()
    }

    /// Whether this drafter carries history worth persisting. Gates the
    /// whole store machinery: the engine opens no [`crate::store`] files
    /// for stateless drafters (none/static baselines).
    fn persistent(&self) -> bool {
        false
    }

    /// Serialize the drafter's complete history as the `das-store-v1`
    /// snapshot payload. Empty for non-persistent drafters.
    fn save_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore history from a [`Drafter::save_state`] payload (warm
    /// start). Implementations must verify the payload's parameters
    /// against their live configuration and answer
    /// [`StoreError::Mismatch`] instead of silently reinterpreting a
    /// snapshot taken under different settings.
    fn load_state(&mut self, _bytes: &[u8]) -> Result<(), StoreError> {
        Err(StoreError::Unsupported("this drafter keeps no persistent state"))
    }

    /// Replay hook for standalone router registrations
    /// ([`crate::store::WalRecord::Register`]). Default: ignore (drafters
    /// without a prefix router).
    fn register_route(&mut self, _shard: u32, _tokens: &[TokenId]) {}

    /// Drain the remote-drafting telemetry accumulated since the last
    /// call (`substrate = "remote"` only). The engine stamps this onto
    /// the step's `remote_draft_*` gauges. Default: `None` — this
    /// drafter speaks no network.
    fn remote_stats(&mut self) -> Option<crate::draftsvc::RemoteDraftStats> {
        None
    }

    /// Chaos seam (`kill-draftsvc` fault directive): abruptly kill the
    /// remote draft server this drafter talks to, proving the run
    /// survives by degradation. Default: no-op.
    fn kill_remote(&mut self) {}
}

/// The no-speculation baseline: always proposes nothing.
#[derive(Debug, Default, Clone)]
pub struct NoneDrafter;

impl Drafter for NoneDrafter {
    fn name(&self) -> &'static str {
        "none"
    }

    fn draft(&mut self, _r: RequestId, _p: ProblemId, _c: &[TokenId], _b: usize) -> Draft {
        Draft::empty()
    }
}

/// Build a drafter from config.
pub fn from_config(cfg: &crate::config::DasConfig) -> Box<dyn Drafter> {
    match cfg.spec.drafter.as_str() {
        "das" => Box::new(SuffixDrafter::from_config(&cfg.spec)),
        "static" => Box::new(StaticNgramDrafter::new(4)),
        "none" => Box::new(NoneDrafter),
        // Config validate() rejects unknown drafter names up front; an unknown name here
        // means the validation layer itself broke, which must not be papered over.
        // audit: allow(panic-path) -- unreachable after config validation; abort surfaces the bug
        other => panic!("unknown drafter '{other}' (validate() should have caught this)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_drafter_proposes_nothing() {
        let mut d = NoneDrafter;
        assert!(d.draft(1, 1, &[1, 2, 3], 8).is_empty());
        assert_eq!(d.name(), "none");
    }

    #[test]
    fn from_config_dispatch() {
        let mut cfg = crate::config::DasConfig::default();
        assert_eq!(from_config(&cfg).name(), "das-suffix");
        cfg.spec.drafter = "static".into();
        assert_eq!(from_config(&cfg).name(), "static-ngram");
        cfg.spec.drafter = "none".into();
        assert_eq!(from_config(&cfg).name(), "none");
    }

    #[test]
    fn all_sources_share_the_interface() {
        // Same corpus through every substrate: all must retrieve the seen
        // continuation with a consistent match_len, via the trait alone.
        let corpus: &[u32] = &[1, 2, 3, 4, 5];
        let mut sources: Vec<Box<dyn DraftSource>> = vec![
            source_from_substrate("window", 4, 16),
            source_from_substrate("tree", 4, 16),
            source_from_substrate("array", 4, 16),
            Box::new(crate::suffix::SuffixTrieIndex::new(16)),
        ];
        for s in &mut sources {
            s.absorb(0, corpus);
            let d = s.draft_from(&[2, 3], 8, 2);
            assert_eq!(d.tokens, vec![4, 5], "substrate {}", s.source_name());
            assert_eq!(d.match_len, 2, "substrate {}", s.source_name());
            assert_eq!(d.confidence.len(), 2, "substrate {}", s.source_name());
            assert!(s.indexed_tokens() >= corpus.len(), "substrate {}", s.source_name());
            let stats = s.index_stats();
            assert!(stats.heap_bytes > 0, "substrate {}", s.source_name());
            assert_eq!(stats.pool_tokens, 0, "per-source stats never report the pool");
            let miss = s.draft_from(&[9, 9], 8, 2);
            assert!(miss.is_empty(), "substrate {}", s.source_name());
            s.on_epoch(1); // must be accepted by every substrate
        }
    }

    #[test]
    fn pooled_sources_share_segments() {
        let pool = SharedPool::new();
        let mut a = source_from_substrate_pooled("window", 4, 16, Some(&pool));
        let mut b = source_from_substrate_pooled("window", 4, 16, Some(&pool));
        let corpus: Vec<u32> = (0..24).map(|i| i % 9).collect();
        a.absorb(0, &corpus);
        let after_a = pool.stats().live_tokens;
        assert!(after_a > 0);
        b.absorb(0, &corpus);
        assert_eq!(
            pool.stats().live_tokens,
            after_a,
            "identical rollout content interns to one segment across shards"
        );
        assert_eq!(a.draft_from(&[0, 1], 8, 2).tokens, b.draft_from(&[0, 1], 8, 2).tokens);
    }

    #[test]
    fn snapshots_draft_bit_identical_to_live_sources_and_freeze() {
        // The substrate-level acceptance property: for every one of the
        // five substrates, a published DraftSnapshot answers draft_from
        // bit-identically to the live source at the publish point, carries
        // the same size gauges, and is frozen — later absorbs change the
        // live answers but never the snapshot's.
        let mut sources: Vec<Box<dyn DraftSource>> = vec![
            source_from_substrate("window", 4, 16),
            source_from_substrate("tree", 4, 16),
            source_from_substrate("array", 4, 16),
            Box::new(crate::suffix::SuffixTrieIndex::new(16)),
            Box::new(StaticNgramDrafter::new(8)),
        ];
        let corpora: [&[u32]; 3] = [&[1, 2, 3, 4, 5], &[1, 2, 3, 9, 9], &[6, 1, 2, 3, 4]];
        let probes: [&[u32]; 5] = [&[2, 3], &[1, 2, 3], &[9], &[4, 5], &[8, 8]];
        for s in &mut sources {
            let name = s.source_name();
            for c in corpora {
                s.absorb(0, c);
            }
            let snap = s.snapshot();
            for p in probes {
                let live = s.draft_from(p, 8, 4);
                let shot = snap.draft_from(p, 8, 4);
                assert_eq!(live.tokens, shot.tokens, "{name} probe {p:?}");
                assert_eq!(live.confidence, shot.confidence, "{name} probe {p:?}");
                assert_eq!(live.match_len, shot.match_len, "{name} probe {p:?}");
            }
            let (ls, ss) = (s.index_stats(), snap.index_stats());
            assert_eq!(ls.nodes, ss.nodes, "{name}: nodes");
            assert_eq!(ls.token_positions, ss.token_positions, "{name}: positions");
            assert_eq!(ls.heap_bytes, ss.heap_bytes, "{name}: heap bytes");
            assert_eq!(ls.link_rebuilds, ss.link_rebuilds, "{name}: link rebuilds");
            // Freeze: absorb a diverging continuation of a probed context.
            let before = snap.draft_from(&[2, 3], 8, 2);
            s.absorb(0, &[2, 3, 77, 77]);
            let stale = snap.draft_from(&[2, 3], 8, 2);
            assert_eq!(stale.tokens, before.tokens, "{name}: snapshot froze");
            assert_eq!(stale.match_len, before.match_len, "{name}: snapshot froze");
        }
    }

    #[test]
    fn republish_without_mutation_is_cached_for_trie_substrates() {
        let mut s = source_from_substrate("window", 4, 16);
        s.absorb(0, &[1, 2, 3, 4]);
        let _ = s.snapshot();
        let _ = s.snapshot(); // cache hit — not a new publication
        assert_eq!(s.index_stats().snapshot_publishes, 1);
        s.absorb(0, &[5, 6, 7]);
        let _ = s.snapshot();
        assert_eq!(s.index_stats().snapshot_publishes, 2);
    }

    #[test]
    fn concurrent_snapshot_readers_match_publish_time_answers() {
        // Satellite stress at the substrate boundary: 4 reader threads keep
        // drafting from whatever snapshot is currently published while the
        // writer absorbs rollouts and republishes. Every read must
        // reproduce the answer the live (locked, single-threaded reference)
        // source gave at that snapshot's publish point — any torn read or
        // cross-publish smearing breaks the equality.
        use std::sync::Mutex;
        let probe: &[u32] = &[3, 4];
        let mut src = source_from_substrate("window", 4, 16);
        src.absorb(0, &[3, 4, 5, 6]);
        let first = (0u64, src.snapshot(), src.draft_from(probe, 8, 3));
        let cell = Mutex::new(first);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..400 {
                        let (gen, snap, want) = {
                            let g = cell.lock().unwrap_or_else(|e| e.into_inner());
                            (g.0, g.1.clone(), g.2.clone())
                        };
                        let got = snap.draft_from(probe, 8, 3);
                        assert_eq!(got.tokens, want.tokens, "publish {gen}");
                        assert_eq!(got.confidence, want.confidence, "publish {gen}");
                        assert_eq!(got.match_len, want.match_len, "publish {gen}");
                    }
                });
            }
            for i in 1..=48u32 {
                src.absorb(0, &[3, 4, 10 + (i % 7), 20 + (i % 5)]);
                let snap = src.snapshot();
                let want = src.draft_from(probe, 8, 3);
                *cell.lock().unwrap_or_else(|e| e.into_inner()) = (u64::from(i), snap, want);
            }
        });
        assert_eq!(src.index_stats().snapshot_publishes, 49);
    }

    #[test]
    fn poisoned_publish_lock_still_serves_readers() {
        // Regression for the `.lock().unwrap()` hazard the poisoned-lock
        // audit rule now bans: a drafter panic under catch_unwind while
        // holding a shared mutex poisons it; the into_inner idiom must keep
        // every later reader working (supervised engines recover panicked
        // workers, so a poisoned publish cell would otherwise take down the
        // surviving ones).
        use std::sync::Mutex;
        let mut src = source_from_substrate("window", 4, 16);
        src.absorb(0, &[3, 4, 5, 6]);
        let want = src.draft_from(&[3, 4], 8, 3);
        let cell = Mutex::new((src.snapshot(), want.clone()));
        let panicked = std::panic::catch_unwind(|| {
            let _held = cell.lock().unwrap_or_else(|e| e.into_inner());
            panic!("drafter dies while holding the publish lock");
        });
        assert!(panicked.is_err());
        assert!(cell.is_poisoned(), "the panic must actually poison the cell");
        let g = cell.lock().unwrap_or_else(|e| e.into_inner());
        let got = g.0.draft_from(&[3, 4], 8, 3);
        assert_eq!(got.tokens, g.1.tokens, "post-poison read still serves the snapshot");
        assert_eq!(got.tokens, want.tokens);
    }

    #[test]
    fn windowed_source_evicts_via_trait_epochs() {
        let mut s = source_from_substrate("window", 2, 16);
        s.absorb(0, &[1, 2, 3]);
        s.on_epoch(1);
        s.on_epoch(2);
        assert!(s.draft_from(&[1, 2], 8, 2).is_empty(), "windowed source forgets");
        // The unwindowed tree keeps everything under the same driving.
        let mut t = source_from_substrate("tree", 2, 16);
        t.absorb(0, &[1, 2, 3]);
        t.on_epoch(1);
        t.on_epoch(2);
        assert_eq!(t.draft_from(&[1, 2], 8, 2).tokens, vec![3]);
    }
}

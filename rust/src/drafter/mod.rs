//! Drafters — the proposal side of speculative decoding (§4.1).
//!
//! * [`SuffixDrafter`] — the paper's adaptive nonparametric drafter:
//!   per-problem (or global) sliding-window suffix indexes, optionally
//!   combined with a request-local index ("+request" scopes of Fig. 6) and a
//!   prefix-trie router.
//! * [`StaticNgramDrafter`] — the frozen parametric baseline standing in for
//!   EAGLE: calibrated once on epoch-0 rollouts, never updated, so its
//!   acceptance stays flat while the policy drifts (Fig. 4).
//! * [`NoneDrafter`] — the VeRL no-speculation baseline.

mod static_ngram;
mod suffix_drafter;

pub use static_ngram::StaticNgramDrafter;
pub use suffix_drafter::{HistoryScope, SuffixDrafter};

use crate::tokens::{Epoch, ProblemId, RequestId, Rollout, TokenId};

/// A proposed draft block.
#[derive(Debug, Clone, Default)]
pub struct Draft {
    pub tokens: Vec<TokenId>,
    /// Empirical per-token confidence (drafter's own estimate; diagnostic).
    pub confidence: Vec<f32>,
    /// Length of the context suffix the draft was retrieved from.
    pub match_len: usize,
}

impl Draft {
    pub fn empty() -> Self {
        Draft::default()
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Common interface for all drafters.
pub trait Drafter: Send {
    fn name(&self) -> &'static str;

    /// Propose up to `budget` tokens continuing `context` for a request of
    /// the given problem.
    fn draft(
        &mut self,
        request: RequestId,
        problem: ProblemId,
        context: &[TokenId],
        budget: usize,
    ) -> Draft;

    /// Feed freshly *committed* (verified) tokens of an in-flight request —
    /// powers the "+request" scopes. Default: ignore.
    fn observe_partial(
        &mut self,
        _request: RequestId,
        _problem: ProblemId,
        _new_tokens: &[TokenId],
    ) {
    }

    /// A request finished; drop any request-local state. Default: ignore.
    fn end_request(&mut self, _request: RequestId) {}

    /// A rollout completed and was added to history (drafters that adapt
    /// index it here). Default: ignore (static baselines).
    fn observe_rollout(&mut self, _rollout: &Rollout) {}

    /// A new training epoch started (window maintenance). Default: ignore.
    fn roll_epoch(&mut self, _epoch: Epoch) {}
}

/// The no-speculation baseline: always proposes nothing.
#[derive(Debug, Default, Clone)]
pub struct NoneDrafter;

impl Drafter for NoneDrafter {
    fn name(&self) -> &'static str {
        "none"
    }

    fn draft(&mut self, _r: RequestId, _p: ProblemId, _c: &[TokenId], _b: usize) -> Draft {
        Draft::empty()
    }
}

/// Build a drafter from config.
pub fn from_config(cfg: &crate::config::DasConfig) -> Box<dyn Drafter> {
    match cfg.spec.drafter.as_str() {
        "das" => Box::new(SuffixDrafter::from_config(&cfg.spec)),
        "static" => Box::new(StaticNgramDrafter::new(4)),
        "none" => Box::new(NoneDrafter),
        other => panic!("unknown drafter '{other}' (validate() should have caught this)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_drafter_proposes_nothing() {
        let mut d = NoneDrafter;
        assert!(d.draft(1, 1, &[1, 2, 3], 8).is_empty());
        assert_eq!(d.name(), "none");
    }

    #[test]
    fn from_config_dispatch() {
        let mut cfg = crate::config::DasConfig::default();
        assert_eq!(from_config(&cfg).name(), "das-suffix");
        cfg.spec.drafter = "static".into();
        assert_eq!(from_config(&cfg).name(), "static-ngram");
        cfg.spec.drafter = "none".into();
        assert_eq!(from_config(&cfg).name(), "none");
    }
}

//! Frozen n-gram drafter — the parametric-baseline stand-in for EAGLE
//! (§4.1.1, Fig. 4).
//!
//! EAGLE's failure mode in RL training is *calibration freeze*: the drafter
//! head is trained against one policy checkpoint and goes stale as the
//! policy drifts, so its acceptance curve stays flat (or decays) while the
//! DAS drafter's keeps rising. We reproduce that mechanism with a
//! nonparametric proxy trained the same way EAGLE would be deployed: fit
//! once on the FIRST epoch's rollouts, then never update. Using the same
//! index machinery as the adaptive drafter (the arena [`SuffixTrieIndex`])
//! isolates the variable that matters — *whether the drafter tracks the
//! policy* — from incidental representation differences.
//!
//! The freeze logic lives in the [`DraftSource`] impl (absorb-rollout +
//! epoch-roll), so this drafter slots into the same substrate interface as
//! every suffix structure; the [`Drafter`] impl is pure delegation.

use std::sync::Arc;

use super::{Draft, DraftSnapshot, DraftSource, Drafter, DrafterSnapshot, IndexStats};
use crate::suffix::trie::SuffixTrieIndex;
use crate::tokens::{Epoch, ProblemId, RequestId, Rollout, TokenId};

pub struct StaticNgramDrafter {
    index: SuffixTrieIndex,
    /// Epoch whose rollouts we train on (0 = the first observed epoch).
    train_epoch: Option<Epoch>,
    frozen: bool,
    order: usize,
    /// Last epoch rolled to (snapshot-staleness reference only — the
    /// drafter itself is frozen by design).
    epoch: Epoch,
}

impl StaticNgramDrafter {
    /// `order` = maximum n-gram context length used for matching.
    pub fn new(order: usize) -> Self {
        StaticNgramDrafter {
            index: SuffixTrieIndex::new(order + 64),
            train_epoch: None,
            frozen: false,
            order,
            epoch: 0,
        }
    }

    /// Pre-train on a calibration corpus (alternative to observing epoch 0).
    pub fn train(&mut self, corpus: &[Vec<TokenId>]) {
        for seq in corpus {
            self.index.insert(seq);
        }
        self.frozen = true;
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen
    }
}

impl DraftSource for StaticNgramDrafter {
    fn source_name(&self) -> &'static str {
        "static-ngram"
    }

    fn draft_from(&self, context: &[TokenId], max_match: usize, budget: usize) -> Draft {
        let (tokens, confidence, match_len) =
            self.index
                .draft_weighted_with_match(context, max_match.min(self.order), budget);
        Draft {
            tokens,
            confidence,
            match_len,
        }
    }

    /// Snapshot of the calibration index plus the order clamp. Once the
    /// drafter freezes (its designed steady state) the underlying trie
    /// never mutates again, so repeated publishes are pure chunk-table
    /// clones of an unchanged arena.
    fn snapshot(&mut self) -> DraftSnapshot {
        DraftSnapshot::Static {
            index: Arc::new(self.index.publish()),
            order: self.order,
        }
    }

    fn absorb(&mut self, epoch: Epoch, tokens: &[TokenId]) {
        // Calibration phase only: absorb the first epoch, then freeze.
        if self.frozen {
            return;
        }
        match self.train_epoch {
            None => {
                self.train_epoch = Some(epoch);
                self.index.insert(tokens);
            }
            Some(e) if epoch == e => self.index.insert(tokens),
            Some(_) => self.frozen = true,
        }
    }

    fn on_epoch(&mut self, epoch: Epoch) {
        if let Some(e) = self.train_epoch {
            if epoch > e {
                self.frozen = true;
            }
        }
    }

    fn indexed_tokens(&self) -> usize {
        self.index.tokens_indexed()
    }

    fn index_stats(&self) -> IndexStats {
        IndexStats {
            nodes: self.index.node_count(),
            token_positions: self.index.token_positions(),
            heap_bytes: self.index.approx_bytes(),
            link_rebuilds: self.index.link_rebuilds(),
            ..IndexStats::default()
        }
    }
}

impl Drafter for StaticNgramDrafter {
    fn name(&self) -> &'static str {
        "static-ngram"
    }

    fn draft(
        &mut self,
        _request: RequestId,
        _problem: ProblemId,
        context: &[TokenId],
        budget: usize,
    ) -> Draft {
        if budget == 0 || context.is_empty() {
            return Draft::empty();
        }
        self.draft_from(context, self.order, budget)
    }

    fn snapshot(&mut self) -> Option<Arc<DrafterSnapshot>> {
        Some(Arc::new(DrafterSnapshot::single(
            self.epoch,
            DraftSource::snapshot(self),
        )))
    }

    fn observe_rollout(&mut self, rollout: &Rollout) {
        self.absorb(rollout.epoch, &rollout.tokens);
    }

    fn roll_epoch(&mut self, epoch: Epoch) {
        self.epoch = epoch;
        self.on_epoch(epoch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rollout(epoch: Epoch, tokens: Vec<TokenId>) -> Rollout {
        Rollout {
            problem: 1,
            epoch,
            step: 0,
            tokens,
            reward: 0.0,
        }
    }

    #[test]
    fn drafts_from_calibration_corpus() {
        let mut d = StaticNgramDrafter::new(4);
        d.train(&[vec![1, 2, 3, 4, 5]]);
        let draft = Drafter::draft(&mut d, 0, 0, &[2, 3], 2);
        assert_eq!(draft.tokens, vec![4, 5]);
    }

    #[test]
    fn freezes_after_first_epoch() {
        let mut d = StaticNgramDrafter::new(4);
        d.observe_rollout(&rollout(0, vec![1, 2, 3]));
        assert!(!d.is_frozen());
        Drafter::roll_epoch(&mut d, 1);
        assert!(d.is_frozen());
        // Later rollouts are ignored — the drafter is stale by design.
        d.observe_rollout(&rollout(1, vec![7, 8, 9]));
        assert!(Drafter::draft(&mut d, 0, 0, &[7, 8], 1).is_empty());
        // Epoch-0 patterns still work.
        assert_eq!(Drafter::draft(&mut d, 0, 0, &[1, 2], 1).tokens, vec![3]);
    }

    #[test]
    fn stale_after_policy_drift() {
        // The Fig. 4 mechanism in miniature: policy continuations change,
        // frozen drafter keeps proposing the old ones.
        let mut d = StaticNgramDrafter::new(4);
        d.observe_rollout(&rollout(0, vec![1, 2, 3, 4]));
        Drafter::roll_epoch(&mut d, 5);
        // New policy would continue [1,2] with 30 — the static drafter
        // still proposes 3.
        assert_eq!(Drafter::draft(&mut d, 0, 0, &[1, 2], 1).tokens, vec![3]);
    }

    #[test]
    fn works_as_a_plain_draft_source() {
        let mut d = StaticNgramDrafter::new(4);
        d.absorb(0, &[1, 2, 3, 4]);
        assert_eq!(d.draft_from(&[1, 2], 4, 2).tokens, vec![3, 4]);
        assert_eq!(d.indexed_tokens(), 4);
        d.on_epoch(1);
        assert!(d.is_frozen());
        d.absorb(1, &[7, 8]); // ignored once frozen
        assert!(d.draft_from(&[7], 4, 1).is_empty());
    }
}
